"""HLO structural analyzer + cost models: validated against ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo
from repro.analysis.params import param_count
from repro.configs import ALL_ARCHS, get_config
from repro.launch.specs import params_shapes


def test_scan_trip_count_scaling():
    """dot FLOPs of a scanned program == unrolled (cost_analysis misses 8x)."""
    def body(x, w):
        return x @ w, None

    def f_scan(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    comp = jax.jit(f_scan).lower(x, ws).compile()
    s = analyze_hlo(comp.as_text())
    expected = 2 * 128 * 256 * 256 * 8
    assert abs(s.dot_flops - expected) / expected < 0.05
    ca = comp.cost_analysis()
    if isinstance(ca, list):        # older jax returns [dict], newer a dict
        ca = ca[0]
    raw = ca["flops"]
    assert raw < expected / 4                      # proves the undercount


def test_collective_wire_bytes():
    """all-gather over 4 devices: wire = out_bytes * 3/4 per device."""
    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices (run under dryrun env)")
    from jax.sharding import PartitionSpec as P, NamedSharding
    mesh = jax.make_mesh((4,), ("x",))
    xs = jax.ShapeDtypeStruct((1024, 64), jnp.float32,
                              sharding=NamedSharding(mesh, P("x", None)))

    def f(x):
        return jax.lax.with_sharding_constraint(x, P(None, None)) * 2.0

    comp = jax.jit(f).lower(xs).compile()
    s = analyze_hlo(comp.as_text())
    out_bytes = 1024 * 64 * 4
    assert abs(s.collective_bytes.get("all-gather", 0)
               - out_bytes * 3 / 4) / out_bytes < 0.26


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_analytic_param_count_matches_eval_shape(arch):
    cfg = get_config(arch)
    shapes = params_shapes(cfg)
    actual = sum(int(np.prod(x.shape))
                 for x in jax.tree_util.tree_leaves(shapes))
    predicted = param_count(cfg)
    # analytic model skips norms/biases/pos-embeds/conv kernels (<2%)
    assert abs(predicted - actual) / actual < 0.05, (predicted, actual)


def test_headline_param_counts():
    """Sanity: the archs are the size their names claim."""
    expect = {"tinyllama-1.1b": (0.9e9, 1.3e9),
              "llama3.2-3b": (2.8e9, 3.8e9),
              "mamba2-1.3b": (1.1e9, 1.55e9),
              "mixtral-8x22b": (125e9, 150e9),
              "nemotron-4-15b": (13e9, 17e9)}
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch))
        assert lo < n < hi, (arch, n)
