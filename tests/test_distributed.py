"""Distributed train-step parity (subprocess with 8 fake CPU devices)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
# deepseek was xfailed here for ~1e-2 two-step divergence.  Root cause was
# NOT top-k tie-breaks: the shard_map MoE pooled expert capacity per data
# shard while the single-device path pooled it per dispatch group, so the
# two layouts dropped different tokens.  With group boundaries aligned (and
# expert selection keyed on bf16-rounded probs) the step-1 loss is
# bit-identical; the remaining two-step gap is AdamW amplifying ulp-level
# gradient summation-order noise and is pinned per-arch in
# distributed_parity_main.py rather than xfailed.
@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b", "deepseek-v2-lite-16b", "mamba2-1.3b", "zamba2-1.2b"])
def test_train_step_parity_1_vs_8_devices(arch):
    """FSDP + TP + activation constraints + shard_map MoE must reproduce the
    single-device loss to fp32-accumulation tolerance."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed_parity_main.py"),
         arch],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-1500:])
    assert "PARITY OK" in out.stdout
