"""Elastic fleet: end-to-end multi-job run with host failure (subprocess
with 8 fake CPU devices so the session's device count stays untouched)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_deadline_fleet_end_to_end():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "deadline_fleet.py"),
         "--steps", "8", "--fail-after", "3.0"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
    assert "FAILED; affected=" in out.stdout        # host failure happened
    assert "recovered" in out.stdout                # ...and was recovered


def test_chip_pool_aq_rq():
    from repro.elastic import ChipPool

    class FakeDev:
        pass

    pool = ChipPool([FakeDev() for _ in range(8)], chips_per_host=4)
    got = pool.allocate("a", 6, preferred_hosts=(0,))
    assert len(got) == 6
    assert {pool.host_of(c) for c in got[:4]} == {0}   # locality preference
    pool.park_grow("b", host=1)
    pool.release([got[-1]])                            # a chip on host 1
    grants = pool.match()
    assert grants == [("b", got[-1])]
    affected = pool.fail_host(0)
    assert affected == ["a"]
    assert all(pool.owner[c] is None for c in range(4))


def test_estimator_bridge_monotone():
    from repro.elastic import EstimatorBridge
    tight = EstimatorBridge.demand(100, 1.0, 4, time_left=50.0, total_chips=64)
    loose = EstimatorBridge.demand(100, 1.0, 4, time_left=500.0, total_chips=64)
    assert tight > loose
