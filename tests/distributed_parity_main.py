"""Subprocess body for tests/test_distributed.py: train-step parity between
a single device and an 8-device (data=4, model=2) mesh, exercising FSDP
gathers, TP partial sums, activation constraints, shard_map MoE and the
flash custom-VJP under GSPMD.  Run with
XLA_FLAGS=--xla_force_host_platform_device_count=8."""
import os
import sys

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.steps import make_train_step
from repro.models.common import get_model
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.activations import set_activation_sharding, clear
from repro.parallel.sharding import ShardingPolicy, make_param_specs
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Two-step tolerance, per arch.  Default: fp32-accumulation noise only.
# deepseek (MoE): step-1 losses are bit-identical — dispatch-group capacity
# boundaries and the bf16-keyed expert selection are layout-invariant — but
# the step-1 *gradients* carry ulp-level reduction-order noise (FSDP
# reduce-scatter vs a single fused einsum), and AdamW's first-step
# normalization (update ≈ lr·sign(g) out of zero optimizer state) amplifies
# every near-zero-gradient sign flip to a full ±lr: max |Δparam| after step
# 1 is exactly 2·lr.  The step-2 loss feels that at ~6e-3.  This is
# optimizer amplification of summation order, not a routing/dispatch bug,
# so it is pinned at its measured magnitude instead of xfailed.
STEP2_TOL = {"deepseek-v2-lite-16b": 2e-2}
STEP1_TOL = 1e-6


def run(arch: str) -> float:
    cfg = get_smoke_config(arch)
    # d_ff/vocab must divide model=2; smoke configs do
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    opt = adamw_init(params)
    B, S = 8, 64
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab_size)}
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0)

    # --- single device ----------------------------------------------------
    clear()
    step1 = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=2))
    p1, o1, m1 = step1(params, opt, batch)
    p1, o1, m2_single = step1(p1, o1, batch)

    # --- 8-device mesh ------------------------------------------------------
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    pol = ShardingPolicy(fsdp=True)
    set_activation_sharding(dp="data", dp_size=4, tp="model", tp_size=2,
                            mesh=mesh, fsdp="data")
    pspecs = make_param_specs(cfg, jax.eval_shape(lambda p: p, params), mesh, pol)
    ps = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs)
    os_ = adamw_init(ps)
    bs = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
          for k, v in batch.items()}
    with mesh:
        stepN = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=2,
                                        dp_entry="data", grad_specs=pspecs))
        pN, oN, mN1 = stepN(ps, os_, bs)
        pN, oN, mN = stepN(pN, oN, bs)
    clear()

    s1, sN = float(m1["loss"]), float(mN1["loss"])
    rel1 = abs(s1 - sN) / max(abs(s1), 1e-9)
    l1, lN = float(m2_single["loss"]), float(mN["loss"])
    rel = abs(l1 - lN) / max(abs(l1), 1e-9)
    print(f"{arch}: step1 rel={rel1:.2e}  "
          f"step2 single={l1:.6f} dist={lN:.6f} rel={rel:.2e}")
    # step 1 runs from identical params: any noticeable gap here is a
    # layout-dependent forward (e.g. dispatch-group capacity drops), not
    # accumulated optimizer noise — hold it to near-bit-exact
    assert rel1 < STEP1_TOL, f"{arch}: step-1 layout divergence {rel1}"
    tol = STEP2_TOL.get(arch, 5e-3)
    assert rel < tol, f"{arch}: distributed parity broken: {rel} >= {tol}"
    return rel


if __name__ == "__main__":
    archs = sys.argv[1:] or ["tinyllama-1.1b", "deepseek-v2-lite-16b",
                             "mamba2-1.3b"]
    for a in archs:
        run(a)
    print("PARITY OK")
