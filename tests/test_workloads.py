"""Unit coverage for the simcluster workload helpers."""
import math
import random

import pytest

from repro.core.types import ClusterSpec
from repro.simcluster.workloads import (PAPER_TABLE2_ROWS, WORKLOADS,
                                        default_deadline, make_job,
                                        n_map_tasks, n_reduce_tasks,
                                        paper_cluster, paper_job_mix,
                                        paper_table2_jobs, place_blocks)


def test_n_map_tasks_block_math():
    assert n_map_tasks(1.0) == 8          # 128 MB blocks: 8 per GB
    assert n_map_tasks(10.0) == 80
    assert n_map_tasks(1.01) == 9         # partial block => extra map task
    assert n_map_tasks(0.05) == 1         # tiny inputs still get one task
    assert n_map_tasks(0.0) == 1


def test_n_reduce_tasks_ratio_and_floor():
    for w in WORKLOADS:
        assert n_reduce_tasks(w, 0.05) >= 1
    # sort: v_r = 0.5 * u_m
    assert n_reduce_tasks("sort", 10.0) == 40
    # permutation is reduce-heavy relative to grep at equal size
    assert n_reduce_tasks("permutation", 4.0) > n_reduce_tasks("grep", 4.0)


def test_default_deadline_monotone_in_size_and_slack():
    for w in WORKLOADS:
        d_small = default_deadline(w, 2.0)
        d_big = default_deadline(w, 10.0)
        assert 0 < d_small < d_big
        assert default_deadline(w, 2.0, slack=4.0) > d_small


def test_make_job_fields_and_placement():
    spec = paper_cluster()
    rng = random.Random(0)
    job = make_job("j0", "wordcount", 5.0, 520.0, spec, rng,
                   submit_time=30.0, skew=1.0)
    assert job.job_id == "j0"
    assert job.profile is WORKLOADS["wordcount"]
    assert job.u_m == n_map_tasks(5.0)
    assert job.v_r == n_reduce_tasks("wordcount", 5.0)
    assert job.deadline == 520.0 and job.submit_time == 30.0
    assert job.input_size_gb == 5.0
    assert len(job.block_placement) == job.u_m
    for placement in job.block_placement:
        # paper cluster: per-VM virtual disks => replication 1
        assert len(placement) == 1
        assert 0 <= placement[0] < spec.num_nodes


def test_place_blocks_replication_and_distinctness():
    spec = ClusterSpec(num_machines=4, vms_per_machine=2, replication=3)
    rng = random.Random(1)
    for skew in (0.0, 1.0):
        placements = place_blocks(16, spec, rng, skew=skew)
        assert len(placements) == 16
        for p in placements:
            assert len(p) == 3 == len(set(p))       # distinct replicas
            assert all(0 <= n < spec.num_nodes for n in p)
    # replication capped by cluster size
    tiny = ClusterSpec(num_machines=1, vms_per_machine=2, replication=3)
    for p in place_blocks(4, tiny, random.Random(0)):
        assert len(p) == 2


def test_place_blocks_skew_concentrates_load():
    spec = ClusterSpec(num_machines=20, vms_per_machine=2, replication=1)
    rng = random.Random(7)
    flat = place_blocks(400, spec, rng, skew=0.0)
    hot = place_blocks(400, spec, rng, skew=2.0)

    def top_share(placements):
        counts = {}
        for p in placements:
            counts[p[0]] = counts.get(p[0], 0) + 1
        return max(counts.values()) / len(placements)

    assert top_share(hot) > 2 * top_share(flat)


def test_paper_job_mix_construction():
    spec = paper_cluster()
    jobs = paper_job_mix(spec, seed=0)
    assert len(jobs) == 25                      # 5 sizes x 5 workloads
    assert len({j.job_id for j in jobs}) == 25
    submits = [j.submit_time for j in jobs]
    assert submits == sorted(submits) and submits[0] == 0.0
    assert submits[1] - submits[0] == 15.0      # stagger
    sizes = sorted({j.input_size_gb for j in jobs})
    assert sizes == [2, 4, 6, 8, 10]
    # deterministic per seed
    again = paper_job_mix(spec, seed=0)
    assert [j.block_placement for j in again] == [j.block_placement for j in jobs]


def test_paper_table2_jobs_match_rows():
    spec = paper_cluster()
    jobs = paper_table2_jobs(spec, seed=0)
    assert [(j.profile.name, j.input_size_gb, j.deadline) for j in jobs] \
        == [(w, float(gb), dl) for (w, gb, dl) in PAPER_TABLE2_ROWS]
    assert all(j.submit_time == 0.0 for j in jobs)
