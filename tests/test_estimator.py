"""Resource-estimation model (paper Eqs. 1-10): exact + property tests."""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.estimator import (OnlineEstimator, completion_time,
                                  mean_task_length, min_slots)
from repro.core.types import JobRuntime, JobSpec, WorkloadProfile

prof = WorkloadProfile(name="t", map_time=20, reduce_time=10,
                       shuffle_time_per_pair=0.01)


def _job(u_m=40, v_r=10, deadline=600.0):
    return JobRuntime(spec=JobSpec(job_id="j", profile=prof, u_m=u_m, v_r=v_r,
                                   deadline=deadline))


def test_mean_task_length_eq1():
    assert mean_task_length([]) is None
    assert mean_task_length([2.0, 4.0]) == 3.0


def test_closed_form_matches_paper_shape():
    # n_m/n_r must equal sqrt(A/B) (Lagrange solution structure)
    d = min_slots(u_m=80, v_r=20, t_m=20, t_r=20, t_s=0.01, deadline=600)
    assert d.feasible
    ratio = d.n_m_cont / d.n_r_cont
    assert math.isclose(ratio, math.sqrt((80 * 20) / (20 * 20)), rel_tol=1e-9)


@given(u_m=st.integers(1, 300), v_r=st.integers(1, 100),
       t_m=st.floats(0.5, 120), t_s=st.floats(0, 0.05),
       slack=st.floats(1.05, 20))
@settings(max_examples=200, deadline=None)
def test_continuous_solution_meets_deadline_exactly(u_m, v_r, t_m, t_s, slack):
    """At the continuous Lagrange point, Eq. 9 holds with equality."""
    A, B = u_m * t_m, v_r * t_m
    shuffle = u_m * v_r * t_s
    deadline = shuffle + slack * (A + B) / max(u_m + v_r, 1)
    d = min_slots(u_m, v_r, t_m, t_m, t_s, deadline)
    if not d.feasible or not math.isfinite(d.n_m_cont):
        return
    C = deadline - shuffle
    lhs = A / d.n_m_cont + B / d.n_r_cont
    assert math.isclose(lhs, C, rel_tol=1e-6)
    # integer allocation (ceil) can only be faster
    t_int = completion_time(u_m, v_r, t_m, t_m, t_s, d.n_m, d.n_r)
    assert t_int <= deadline * (1 + 1e-9) or d.n_m == u_m or d.n_r == v_r


@given(u_m=st.integers(2, 200), v_r=st.integers(2, 60),
       t_m=st.floats(1, 60), t_s=st.floats(0, 0.02))
@settings(max_examples=100, deadline=None)
def test_lagrange_rounding_near_integer_optimum(u_m, v_r, t_m, t_s):
    """Eq. 10 is the *continuous* optimum; after ceil-rounding the allocation
    must (a) meet the deadline and (b) cost at most +2 slots over the true
    integer optimum (found by grid search)."""
    deadline = u_m * v_r * t_s + (u_m * t_m + v_r * t_m) / 6.0
    d = min_slots(u_m, v_r, t_m, t_m, t_s, deadline)
    if not d.feasible:
        return
    assert (completion_time(u_m, v_r, t_m, t_m, t_s, d.n_m, d.n_r)
            <= deadline * (1 + 1e-9)) or d.n_m == u_m or d.n_r == v_r
    C = deadline - u_m * v_r * t_s
    best = None
    for nm in range(1, u_m + 1):
        rem = C - (u_m * t_m) / nm
        if rem <= 0:
            continue
        nr = math.ceil((v_r * t_m) / rem - 1e-12)
        if 1 <= nr <= v_r:
            tot = nm + nr
            best = tot if best is None else min(best, tot)
    if best is not None:
        assert d.n_m + d.n_r <= best + 2, (d.n_m, d.n_r, best)


@given(st.floats(0.1, 50))
@settings(max_examples=50, deadline=None)
def test_tighter_deadline_needs_more_slots(t_m):
    loose = min_slots(50, 10, t_m, t_m, 0.001, deadline=40 * t_m)
    tight = min_slots(50, 10, t_m, t_m, 0.001, deadline=15 * t_m)
    assert tight.n_m >= loose.n_m
    assert tight.n_r >= loose.n_r


def test_infeasible_shuffle_dominates():
    d = min_slots(100, 50, 10, 10, t_s=1.0, deadline=100.0)   # shuffle=5000s
    assert not d.feasible


def test_online_reestimation_raises_demand_near_deadline():
    est = OnlineEstimator()
    job = _job(u_m=40, v_r=10, deadline=500)
    job.map_durations.extend([20.0] * 5)
    job.completed_map.update(range(5))
    early = est.demand(job, now=50.0)
    late = est.demand(job, now=350.0)
    assert early is not None and late is not None
    assert late.n_m >= early.n_m


def test_bootstrap_returns_none_without_samples():
    est = OnlineEstimator()
    assert est.demand(_job(), now=0.0) is None


def test_table2_style_output():
    """Sanity on the Table-2 benchmark path: grep 10GB @650s."""
    d = min_slots(u_m=80, v_r=12, t_m=20.0, t_r=20.0, t_s=0.0024,
                  deadline=650.0)
    assert d.feasible
    assert 1 <= d.n_m <= 80 and 1 <= d.n_r <= 12
    assert d.n_m > d.n_r      # map-heavy job demands more map slots
