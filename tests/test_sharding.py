"""Sharding rules: divisibility fallbacks and mesh-legal specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.specs import batch_specs, cache_specs, params_shapes
from repro.parallel.sharding import (ShardingPolicy, _fit, make_batch_specs,
                                     make_cache_specs, make_param_specs)


@pytest.fixture(scope="module")
def mesh():
    # 1-device "production-shaped" mesh is impossible on CPU tests; use the
    # spec-level API with a fake mesh shape via jax.sharding.Mesh abstract:
    import numpy as np
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


class FakeMesh:
    """Duck-typed mesh exposing .shape for spec construction."""
    shape = {"data": 16, "model": 16}


def test_fit_respects_divisibility():
    m = FakeMesh()
    assert _fit(m, (128256, 3072), ["model", "data"]) == P("model", "data")
    # kv_heads = 4 not divisible by 16 -> dropped; batch 32 shards fine
    assert _fit(m, (22, 32, 4, 64, 128), [None, "data", "model", None, None]
                ) == P(None, "data")
    # one axis never used twice
    spec = _fit(m, (32, 32), [["model"], ["model", "data"]])
    assert spec == P("model", "data")


def test_param_specs_cover_all_archs():
    m = FakeMesh()
    pol = ShardingPolicy()
    for arch in ("llama3.2-3b", "mixtral-8x22b", "deepseek-v2-lite-16b",
                 "mamba2-1.3b", "zamba2-1.2b", "whisper-large-v3"):
        cfg = get_config(arch)
        shapes = params_shapes(cfg)
        specs = make_param_specs(cfg, shapes, m, pol)
        flat_shapes = jax.tree_util.tree_leaves(shapes)
        flat_specs = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_shapes) == len(flat_specs)
        for s, spec in zip(flat_shapes, flat_specs):
            # every assignment divides
            for dim, entry in zip(s.shape, tuple(spec)):
                if entry is None:
                    continue
                size = 1
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    size *= m.shape[a]
                assert dim % size == 0, (arch, s.shape, spec)


def test_big_tensors_actually_sharded():
    """No >64 MiB parameter may end up fully replicated."""
    m = FakeMesh()
    pol = ShardingPolicy()
    for arch in ("mixtral-8x22b", "nemotron-4-15b"):
        cfg = get_config(arch)
        shapes = params_shapes(cfg)
        specs = make_param_specs(cfg, shapes, m, pol)
        for (path, s), spec in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
            nbytes = 2 * int(jnp.prod(jnp.array(s.shape)))
            if nbytes > 64 * 2**20:
                assert tuple(spec), (arch, path, s.shape)


def test_cache_specs_long_context_batch1():
    """long_500k (B=1): batch unshardable -> heads/seq take the axes."""
    m = FakeMesh()
    pol = ShardingPolicy()
    cfg = get_config("zamba2-1.2b")
    shapes = cache_specs(cfg, "long_500k")
    specs = make_cache_specs(cfg, shapes, m, pol)
    spec_k = specs["attn_k"]
    assert "model" in str(spec_k) or "data" in str(spec_k)
