"""Surrogate engine: the differential calibration wall that pins the fluid
model to the event oracle, plus property pins on the batched kernel.

The wall is the contract behind ``CALIBRATED``: for every allowlisted
(preset, shape, policy) the surrogate's policy-vs-fair throughput gain must
fall inside the event oracle's 95% paired-bootstrap CI on identical
(trace, seed) cells.  A preset enters the allowlist only by passing here —
and drifts out loudly, not silently, when either engine changes."""
import json

import numpy as np
import pytest

from repro.core.policies import PolicySpec, partition_policies
from repro.core.types import ClusterSpec
from repro.experiments.runner import ExperimentSpec, TraceRef
from repro.experiments.surrogate import (CALIBRATED, CALIBRATION_SEEDS,
                                         calibrate, run_surrogate,
                                         surrogate_descriptor,
                                         surrogate_hash)
from repro.simcluster.surrogate import (SURROGATE_ENGINE_ID,
                                        SurrogateUnsupported, build_cell,
                                        lower_policy, run_batch, run_cell,
                                        surrogate_supported)
from repro.simcluster.traces import PRESETS, generate_trace

_CLUSTER = ClusterSpec(num_machines=6, vms_per_machine=2, replication=1)


def _cell(policy="proposed", seed=0, preset="mix_small", trace_seed=0,
          cluster=_CLUSTER):
    trace = generate_trace(PRESETS[preset], seed=trace_seed)
    return build_cell(trace, cluster, policy, seed)


def _fingerprint(res):
    """Every float the RunRecord surface consumes, exact — the comparison
    basis for all bit-identity pins below."""
    return (res.makespan, res.jobs_total, res.jobs_finished,
            res.deadlines_met, res.locality_rate, res.latched_steps,
            tuple((j.job_id, j.finish_time, j.completion_time,
                   j.deadline_met, j.local_map_launches,
                   j.remote_map_launches) for j in res.jobs))


# ---------------------------------------------------------------------------
# the differential calibration wall
# ---------------------------------------------------------------------------

def test_allowlist_is_pinned():
    """The calibrated set is a reviewed artifact: growing or shrinking it
    requires re-running the wall, not editing a dict."""
    assert CALIBRATED == {
        ("heavy_tail", "20x2"): ("proposed", "delay", "edf_nopark"),
        ("diurnal", "20x2"): ("proposed", "delay", "fifo", "edf_nopark"),
        ("bursty", "20x2"): ("fifo", "edf_nopark"),
        ("shuffle_heavy", "20x2"): ("delay", "fifo", "edf_nopark"),
        ("saturated", "20x2"): ("fifo", "edf_nopark"),
    }
    assert CALIBRATION_SEEDS == (0, 1, 2, 3)


@pytest.mark.parametrize("preset,shape", sorted(CALIBRATED))
def test_calibration_wall(preset, shape, tmp_path):
    """Surrogate + oracle on identical (trace, seed) cells; every
    allowlisted policy's surrogate gain inside the oracle's paired CI."""
    report = calibrate(preset, shape, tmp_path, workers=4)
    assert report.seeds == CALIBRATION_SEEDS
    assert {p.policy for p in report.policies} == set(
        CALIBRATED[(preset, shape)])
    for p in report.policies:
        assert p.allowlisted
        assert p.inside, (
            f"{preset}/{shape}/{p.policy}: surrogate gain "
            f"{p.surrogate_gain_pct:+.2f}% outside oracle CI "
            f"[{p.oracle.ci_lo_pct:+.2f}, {p.oracle.ci_hi_pct:+.2f}]")
    assert report.wall_green


def test_calibrate_extra_policy_not_allowlisted(tmp_path):
    """A policy under evaluation reports its differential without joining
    the gate: wall_green ignores non-allowlisted entries."""
    report = calibrate("heavy_tail", "20x2", tmp_path, seeds=(0,),
                       policies=("proposed", "fifo"), workers=4)
    flags = {p.policy: p.allowlisted for p in report.policies}
    assert flags == {"proposed": True, "fifo": False}


# ---------------------------------------------------------------------------
# sweep harness: cache behaviour and the lowering gate
# ---------------------------------------------------------------------------

def _small_spec(schedulers=("proposed", "fair"), seeds=(0, 1)):
    return ExperimentSpec(
        name="sur-t", traces=(TraceRef(preset="mix_small", seed=0),),
        clusters=(_CLUSTER,), schedulers=schedulers, seeds=seeds)


def test_surrogate_rerun_hits_cache(tmp_path):
    first = run_surrogate(_small_spec(), tmp_path)
    assert first.simulated == 4 and first.cached == 0
    again = run_surrogate(_small_spec(), tmp_path)
    assert again.simulated == 0 and again.cached == 4
    strip = lambda r: {k: v for k, v in r.to_dict().items()
                       if k != "wall_time_s"}
    assert [strip(r) for r in first.records] == \
        [strip(r) for r in again.records]


def test_surrogate_descriptor_carries_engine_id(tmp_path):
    spec = _small_spec(seeds=(0,))
    run_surrogate(spec, tmp_path)
    for cell in spec.cells():
        meta = json.loads(
            (tmp_path / surrogate_hash(cell) / "meta.json").read_text())
        assert meta["engine"] == SURROGATE_ENGINE_ID
        d = surrogate_descriptor(cell)
        d.pop("engine")
        assert d == cell.descriptor()


def test_unsupported_grid_rejected_before_any_work(tmp_path):
    spec = _small_spec(schedulers=("proposed", "adaptive"))
    with pytest.raises(SurrogateUnsupported):
        run_surrogate(spec, tmp_path)
    assert list(tmp_path.iterdir()) == []


# ---------------------------------------------------------------------------
# property pins (fuzz tier)
# ---------------------------------------------------------------------------

@pytest.mark.fuzz
@pytest.mark.parametrize("policy", ["proposed", "fair", "fifo", "delay",
                                    "edf_nopark"])
def test_batch_of_one_matches_run_cell(policy):
    cell = _cell(policy=policy)
    assert _fingerprint(run_batch([cell])[0]) == \
        _fingerprint(run_cell(cell))


@pytest.mark.fuzz
def test_batch_order_and_size_invariance():
    """Results depend only on each cell's own inputs — never on batch
    composition.  Mixed presets force mixed padding buckets."""
    cells = [_cell(policy=p, seed=s, preset=pr)
             for p, s, pr in [("proposed", 0, "mix_small"),
                              ("fair", 1, "mix_small"),
                              ("delay", 2, "heavy_tail"),
                              ("fifo", 0, "heavy_tail"),
                              ("edf_nopark", 3, "mix_small"),
                              ("proposed", 1, "heavy_tail")]]
    base = [_fingerprint(r) for r in run_batch(cells)]
    flipped = [_fingerprint(r) for r in run_batch(cells[::-1])][::-1]
    assert base == flipped
    chunked = [_fingerprint(r) for chunk in (cells[:2], cells[2:5], cells[5:])
               for r in run_batch(chunk)]
    assert base == chunked


@pytest.mark.fuzz
def test_max_batch_override_is_result_invariant(monkeypatch):
    """The sub-batch cap is a pure performance knob: kwarg and env-var
    overrides resplit the vmap without moving a single byte of output."""
    import repro.simcluster.surrogate as sg
    assert sg._MAX_BATCH == 64                       # pinned default
    cells = [_cell(policy=p, seed=s)
             for p, s in [("proposed", 0), ("fair", 1), ("fifo", 2),
                          ("delay", 0), ("proposed", 3)]]
    base = [_fingerprint(r) for r in run_batch(cells)]
    for cap in (1, 2, 3):
        assert base == [_fingerprint(r)
                        for r in run_batch(cells, max_batch=cap)], cap
    monkeypatch.setenv("REPRO_SURROGATE_MAX_BATCH", "2")
    assert base == [_fingerprint(r) for r in run_batch(cells)]
    # the explicit kwarg wins over the env var
    assert base == [_fingerprint(r) for r in run_batch(cells, max_batch=4)]


def test_max_batch_resolution_precedence(monkeypatch):
    from repro.simcluster.surrogate import _resolve_max_batch
    monkeypatch.delenv("REPRO_SURROGATE_MAX_BATCH", raising=False)
    assert _resolve_max_batch() == 64
    assert _resolve_max_batch(7) == 7
    monkeypatch.setenv("REPRO_SURROGATE_MAX_BATCH", "16")
    assert _resolve_max_batch() == 16
    assert _resolve_max_batch(3) == 3                # kwarg beats env
    with pytest.raises(ValueError, match=">= 1"):
        _resolve_max_batch(0)
    monkeypatch.setenv("REPRO_SURROGATE_MAX_BATCH", "-5")
    with pytest.raises(ValueError, match=">= 1"):
        _resolve_max_batch()


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", [0, 7])
def test_byte_determinism_per_config_seed(seed):
    """Two fresh integrations of the same (config, seed) — including a
    fresh XLA trace — agree byte-for-byte on cpu."""
    import repro.simcluster.surrogate as sg
    a = _fingerprint(run_cell(_cell(seed=seed)))
    sg._KERNEL_CACHE.clear()
    b = _fingerprint(run_cell(_cell(seed=seed)))
    assert a == b


@pytest.mark.fuzz
def test_seed_and_policy_actually_move_the_result():
    base = _fingerprint(run_cell(_cell(policy="proposed", seed=0)))
    assert _fingerprint(run_cell(_cell(policy="proposed", seed=1))) != base
    assert _fingerprint(run_cell(_cell(policy="fifo", seed=0))) != base


@pytest.mark.fuzz
def test_every_unsupported_registry_policy_raises():
    """The registry partitions cleanly: the adaptive pressure EWMAs (and
    the harvest preset built on them) are the only oracle-only
    components, and each rejection is typed + attributed rather than a
    silent approximation."""
    supported, rejected = partition_policies(surrogate_supported)
    assert supported == ["proposed", "fair", "fifo", "delay", "edf_nopark"]
    assert rejected == ["adaptive", "adaptive_ra", "harvest"]
    for name in rejected:
        with pytest.raises(SurrogateUnsupported) as exc:
            lower_policy(PolicySpec.parse(name))
        assert exc.value.axis in ("park", "overload")
        assert exc.value.label == name
    for name in supported:
        lower_policy(name)
