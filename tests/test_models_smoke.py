"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models.common import get_model
from repro.optim import AdamWConfig, adamw_init


def _batch(cfg, B=2, S=32):
    if cfg.family == "encdec":
        return {"enc_embeds": jnp.zeros((B, S, cfg.d_model), jnp.float32),
                "tokens": jnp.ones((B, S // 4), jnp.int32),
                "labels": jnp.ones((B, S // 4), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32) * 3,
            "labels": jnp.ones((B, S), jnp.int32) * 5}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    table = {
        "mamba2-1.3b": dict(num_layers=48, d_model=2048, vocab_size=50280,
                            ssm_state=128),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, n_heads=32,
                            n_kv_heads=32, d_ff=8192, vocab_size=32000,
                            ssm_state=64),
        "nemotron-4-15b": dict(num_layers=32, d_model=6144, n_heads=48,
                               n_kv_heads=8, d_ff=24576, vocab_size=256000),
        "llama3.2-3b": dict(num_layers=28, d_model=3072, n_heads=24,
                            n_kv_heads=8, d_ff=8192, vocab_size=128256),
        "tinyllama-1.1b": dict(num_layers=22, d_model=2048, n_heads=32,
                               n_kv_heads=4, d_ff=5632, vocab_size=32000),
        "stablelm-3b": dict(num_layers=32, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=6912, vocab_size=50304),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, n_heads=48,
                              n_kv_heads=8, vocab_size=32768, n_experts=8,
                              top_k=2, window=4096),
        "deepseek-v2-lite-16b": dict(num_layers=27, d_model=2048, n_heads=16,
                                     vocab_size=102400, n_experts=64, top_k=6,
                                     kv_lora_rank=512),
        "whisper-large-v3": dict(enc_layers=32, dec_layers=32, d_model=1280,
                                 n_heads=20, d_ff=5120, vocab_size=51866),
        "qwen2-vl-2b": dict(num_layers=28, d_model=1536, n_heads=12,
                            n_kv_heads=2, d_ff=8960, vocab_size=151936),
    }[arch]
    for k, v in table.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    loss, metrics = jax.jit(lambda p, b: model.loss(cfg, p, b))(
        params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert 2.0 < float(loss) < 12.0           # ~ln(vocab) at init


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=0),
                           grad_accum=2)
    batch = _batch(cfg, B=4)
    p1, o1, m1 = jax.jit(step)(params, opt, batch)
    p2, o2, m2 = jax.jit(step)(p1, o1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert int(o2["step"]) == 2
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, p2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """prefill(S) + decode(token S) == full forward at position S."""
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)   # no token dropping
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(1))
    B, S = 2, 17
    tks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                             cfg.vocab_size)
    if cfg.family == "encdec":
        from repro.models.whisper import encode
        enc = jax.random.normal(jax.random.PRNGKey(3), (B, 24, cfg.d_model))
        memory = encode(cfg, params, enc)
        hidden = model.decode_fwd(cfg, params, tks, memory)
        from repro.models import layers as L
        full = L.unembed(cfg.replace(tie_embeddings=True), params["embed"],
                         None, hidden)
        logits_p, cache = model.prefill(
            cfg, params, {"enc_embeds": enc, "tokens": tks[:, :S]})
        cache["k"] = jnp.pad(cache["k"], ((0, 0),) * 3 + ((0, 4), (0, 0)))
        cache["v"] = jnp.pad(cache["v"], ((0, 0),) * 3 + ((0, 4), (0, 0)))
    else:
        from repro.models import layers as L
        fw = model.forward(cfg, params, tks)
        hidden = fw[0] if isinstance(fw, tuple) else fw
        full = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        logits_p, cache = model.prefill(cfg, params, {"tokens": tks[:, :S]})

        def pad_seq(c):
            out = {}
            for k2, v2 in c.items():
                if isinstance(v2, dict):
                    out[k2] = pad_seq(v2)
                elif (hasattr(v2, "ndim") and v2.ndim >= 4
                      and v2.shape[-2] == S and k2 in ("k", "v", "attn_k",
                                                       "attn_v")):
                    out[k2] = jnp.pad(v2, [(0, 0)] * (v2.ndim - 2)
                                      + [(0, 4), (0, 0)])
                elif (hasattr(v2, "ndim") and k2 in ("c_kv", "k_rope")
                      and v2.ndim >= 3 and v2.shape[-2] == S):
                    out[k2] = jnp.pad(v2, [(0, 0)] * (v2.ndim - 2)
                                      + [(0, 4), (0, 0)])
                else:
                    out[k2] = v2
            return out

        cache = pad_seq(cache)
    logits_d, _ = model.decode_step(cfg, params, cache,
                                    {"tokens": tks[:, S:S + 1]})
    a = np.asarray(full[:, S - 1]) if cfg.family != "encdec" else np.asarray(full[:, S - 1])
    b = np.asarray(logits_p[:, -1])
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 2e-4, f"prefill mismatch {rel}"
    a2 = np.asarray(full[:, S])
    b2 = np.asarray(logits_d[:, 0])
    rel2 = np.max(np.abs(a2 - b2)) / (np.max(np.abs(a2)) + 1e-9)
    assert rel2 < 2e-4, f"decode mismatch {rel2}"
