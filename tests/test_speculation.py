"""Speculative-execution bookkeeping: twin cancellation, launch counting,
and duration recording — satellite coverage for the incremental speculation
path in ``simcluster/sim.py``.

The fixed-duration harness replaces the stochastic duration model with a
script: the first launch of map 0 is a straggler, every other task is fast.
That makes the speculative copy's win deterministic, so the tests can assert
exact bookkeeping instead of distributional properties.
"""
import math

import pytest

from repro.core.baselines import FIFOScheduler
from repro.core.types import (ClusterSpec, JobSpec, TaskKind,
                              WorkloadProfile)
from repro.simcluster.sim import ClusterSim


PROF = WorkloadProfile(name="t", map_time=10.0, reduce_time=5.0,
                       shuffle_time_per_pair=0.0, time_cv=0.0)


def _spec():
    return ClusterSpec(num_machines=2, vms_per_machine=2)


def _job(spec, u_m=6, v_r=1):
    # every block on node 0 so locality is deterministic
    return JobSpec(job_id="j", profile=PROF, u_m=u_m, v_r=v_r,
                   deadline=10_000.0,
                   block_placement=[(0,)] * u_m)


class FixedDurationSim(ClusterSim):
    """First launch of j/map0 runs STRAGGLE seconds; everything else FAST."""

    STRAGGLE = 400.0
    FAST = 10.0

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._straggled = False
        self.duration_log = []   # (task, speculative-launch?, duration)

    def task_duration(self, job, task, local, node=None, now=0.0):
        if (task.kind == TaskKind.MAP and task.index == 0
                and not self._straggled):
            self._straggled = True
            d = self.STRAGGLE
        else:
            d = self.FAST
        self.duration_log.append((str(task), d))
        return d


def _run(speculative=True):
    spec = _spec()
    sched = FIFOScheduler(spec)
    sim = FixedDurationSim(spec, sched, seed=0, straggler_prob=0.0,
                           speculative=speculative)
    res = sim.run([_job(spec)])
    return sim, res


def test_speculative_copy_launched_and_counted():
    sim, res = _run()
    assert res.speculative_launches == 1
    assert sim.n_speculative == 1
    # the straggling original was map 0
    assert any(t == "j/map0" and d == FixedDurationSim.STRAGGLE
               for t, d in sim.duration_log)
    # a second (fast) copy of map 0 was launched
    assert sum(1 for t, _ in sim.duration_log if t == "j/map0") == 2


def test_twin_cancelled_on_speculative_win():
    sim, res = _run()
    job = res.jobs["j"]
    # every task completed exactly once: no duplicate completions
    assert len(job.completed_map) == job.spec.u_m
    assert len(job.map_durations) == job.spec.u_m
    # the loser's finish event must not leave a live entry or an occupied slot
    assert not sim.live
    assert all(not running for running in sim.map_running)
    assert all(not running for running in sim.red_running)


def test_speculative_win_records_winner_duration():
    sim, res = _run()
    job = res.jobs["j"]
    # the straggler lost: map 0's recorded duration is the fast copy's
    # elapsed time, not the 400 s original
    assert max(job.map_durations) < FixedDurationSim.STRAGGLE
    # and the win bounds the makespan far below the straggler's finish
    assert res.makespan < FixedDurationSim.STRAGGLE


def test_no_speculation_when_disabled():
    sim_on, res_on = _run(speculative=True)
    sim_off, res_off = _run(speculative=False)
    assert res_off.speculative_launches == 0
    assert not sim_off.spec_launched
    # with speculation off the straggler runs to completion
    assert math.isclose(max(res_off.jobs["j"].map_durations),
                        FixedDurationSim.STRAGGLE)
    assert res_on.makespan < res_off.makespan


def test_each_task_speculated_at_most_once():
    sim, res = _run()
    assert len(sim.spec_launched) == 1
    (task,) = sim.spec_launched
    assert task.index == 0 and task.kind == TaskKind.MAP
