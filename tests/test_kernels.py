"""Pallas kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 1, 1, 64, 64, 64),
    (2, 4, 2, 130, 130, 64),      # GQA + ragged
    (1, 2, 2, 97, 257, 128),      # cross lengths (non-causal)
    (1, 8, 1, 64, 64, 32),        # MQA
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
def test_flash_attention_sweep(dtype, B, Hq, Hkv, Sq, Skv, D, causal, window):
    if causal and Sq != Skv:
        pytest.skip("causal requires square self-attention here")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=64, kv_block=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    err = np.max(np.abs(out.astype(np.float32) - ref.astype(np.float32)))
    scale = np.max(np.abs(ref.astype(np.float32))) + 1e-9
    assert err / scale < TOL[dtype], err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 8, 32),
    (2, 100, 4, 16, 2, 8, 32),     # ragged + groups
    (1, 256, 8, 32, 8, 16, 64),
])
def test_ssd_scan_sweep(dtype, B, S, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = (jax.random.normal(ks[3], (B, S, G, N)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (B, S, G, N)) * 0.3).astype(dtype)
    D = jnp.ones((H,))
    y = ssd(x, dt, A, B_, C, D, chunk=chunk, interpret=True)
    yr, _ = ssd_ref(x, dt, A, B_, C, D)
    err = np.max(np.abs(y.astype(np.float32) - yr.astype(np.float32)))
    scale = np.max(np.abs(yr.astype(np.float32))) + 1e-9
    assert err / scale < TOL[dtype], err


def test_ssd_kernel_matches_model_chunked_path():
    """Kernel == the model's jnp chunked SSD (same algorithm, two impls)."""
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, G, N = 1, 96, 4, 16, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y_kernel = ssd(x, dt, A, B_, C, None, chunk=32, interpret=True)
    y_model, _ = ssd_chunked(x, dt, A, B_, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=2e-5, atol=2e-5)
