"""Differential fuzzing of the decision-parity contract.

`tests/test_parity.py` pins the indexed engine to the frozen seed engine on
a handful of fixed paper-cluster scenarios.  This suite is the randomized
complement: hundreds of generated small clusters (2-8 machines), job mixes,
deadlines, arrival gaps, straggler rates and reconfigurator knobs, each run
through both engines and compared bit-exactly — makespan, per-job finish
times, locality split, speculative launches, reconfiguration counts.

Generation is **hypothesis-driven when hypothesis is installed** (an extra
exploration pass whose example budget is bounded by the `tier1` profile:
derandomized, so CI is deterministic), but the core guarantee does not
depend on it: a deterministic seeded generator always produces
``REPRO_FUZZ_SCENARIOS`` scenarios (default 200) via plain parametrize, so
the suite gives the same coverage on machines without the optional extra
(``pip install .[test]`` brings hypothesis in).

One deliberate constraint: all submit times land inside a 12 s window.  The
seed engine's heartbeat chains die permanently once every *submitted* job
has finished, so a job arriving after a full drain is (intentionally) never
scheduled by the legacy engine while the indexed engine revives the chains
— a documented behavioural fix, not a parity bug.  Nothing can finish
before ~15 s (first heartbeat ≥3 s + shortest map ≥ ~14 s), so a ≤12 s
window keeps both engines on the common semantics the contract covers.

Every scenario also fuzzes the **AdaptiveConfig knobs with
``enabled=False``** — the parity contract pins that carrying arbitrary
adaptive settings (disabled) cannot perturb a single decision.  A separate
adaptive-ON differential suite (``REPRO_ADAPTIVE_FUZZ_SCENARIOS``, default
60) has no legacy counterpart; it pins the liveness contract instead:
every job finishes, every task completes exactly once, and the park ledger
balances — parked = matched + expired + (stale AQ entries whose task
already completed), i.e. adaptive parking never strands a task.

The **FaultConfig knobs are fuzzed the same two ways**: every parity
scenario carries a disabled-but-wild fault config (crash/burst/
heterogeneity settings must be inert while ``enabled=False``), and a
fault-ON chaos suite (``REPRO_FAULT_FUZZ_SCENARIOS``, default 60) runs
seeded crash/churn scenarios across all six policy columns, pinning
liveness: the event loop drains (no deadlock, no event-queue leak), every
job finishes, every crash-lost primary task is re-executed, and nothing is
left running on a down node.

The **TraceConfig knobs ride the same parity sweep**: every scenario
carries a disabled-but-wild trace config — the decision-trace bus is a
pure observer, so arbitrary (disabled) tracing knobs must not perturb a
single decision in either engine.  (Tracing-ON bit-exactness has its own
pins in ``tests/test_tracing.py``.)
"""
import dataclasses
import os
import random

import pytest

from repro.core.policies import PolicyError, PolicySpec
from repro.core.types import (AdaptiveConfig, ClusterSpec, FaultConfig,
                              MachineClass, ServeConfig, ServiceSpec,
                              TraceConfig)
from repro.simcluster._legacy import LegacyClusterSim
from repro.simcluster.sim import ClusterSim
from repro.simcluster.workloads import WORKLOADS, default_deadline, make_job

try:                                    # optional [test] extra
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                     # pragma: no cover - env-dependent
    hypothesis = None

N_SCENARIOS = int(os.environ.get("REPRO_FUZZ_SCENARIOS", "200"))
N_ADAPTIVE = int(os.environ.get("REPRO_ADAPTIVE_FUZZ_SCENARIOS", "60"))
N_FAULT = int(os.environ.get("REPRO_FAULT_FUZZ_SCENARIOS", "60"))
BASE_SEED = int(os.environ.get("REPRO_FUZZ_SEED", "0"))
CHUNKS = 8
SUBMIT_WINDOW_S = 12.0                  # see module docstring

if hypothesis is not None:
    # bounded, derandomized profile so tier-1 stays deterministic and fast;
    # opt into more exploration with HYPOTHESIS_PROFILE=dev
    settings.register_profile("tier1", max_examples=25, derandomize=True,
                              deadline=None, database=None)
    settings.register_profile("dev", max_examples=200, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "tier1"))


def fuzz_adaptive_config(rng: random.Random,
                         enabled: bool = False) -> AdaptiveConfig:
    """Random-but-valid AdaptiveConfig; ``enabled=False`` for the parity
    suite (knob values must be inert while disabled)."""
    floor = round(rng.uniform(1.0, 8.0), 2)
    return AdaptiveConfig(
        enabled=enabled,
        max_wait_floor=floor,
        max_wait_ceiling=round(floor + rng.uniform(5.0, 50.0), 2),
        ewma_alpha=round(rng.uniform(0.05, 0.9), 3),
        breakeven_margin=round(rng.uniform(0.5, 2.0), 2),
        fail_streak_limit=rng.randint(1, 4),
        fail_cooldown=round(rng.uniform(5.0, 60.0), 1),
        outcome_alpha=round(rng.uniform(0.05, 0.5), 3),
        park_win_floor=round(rng.uniform(0.0, 0.8), 2),
        park_active_factor=round(rng.uniform(0.1, 1.2), 2),
        park_min_width=round(rng.uniform(0.0, 24.0), 1),
        overload_pending_factor=round(rng.uniform(0.05, 1.5), 2),
        overload_active_factor=round(rng.uniform(0.1, 1.5), 2),
    )


def fuzz_fault_config(rng: random.Random,
                      enabled: bool = False) -> FaultConfig:
    """Random-but-valid FaultConfig.  ``enabled=False`` for the parity
    suite (wild crash/burst/heterogeneity knobs must be inert while
    disabled); ``enabled=True`` draws MTBFs short enough that small fuzz
    scenarios actually crash."""
    classes = ()
    if rng.random() < 0.5:
        classes = (MachineClass(name="new", weight=rng.randint(1, 3)),
                   MachineClass(name="old", weight=1,
                                speed=round(rng.uniform(1.0, 1.8), 2),
                                fabric=round(rng.uniform(0.8, 1.5), 2),
                                mtbf_scale=round(rng.uniform(0.3, 1.0), 2)))
    return FaultConfig(
        enabled=enabled,
        crash_mtbf=round(rng.uniform(120.0, 900.0), 1),
        crash_mttr=round(rng.uniform(20.0, 120.0), 1),
        crash_warmup=round(rng.uniform(0.0, 30.0), 1),
        rereplicate_after=round(rng.uniform(10.0, 60.0), 1),
        burst_rate=round(rng.uniform(100.0, 600.0), 1)
        if rng.random() < 0.5 else 0.0,
        burst_duration=round(rng.uniform(10.0, 60.0), 1),
        burst_slowdown=round(rng.uniform(1.5, 4.0), 2),
        machine_classes=classes,
    )


def fuzz_trace_config(rng: random.Random,
                      enabled: bool = False) -> TraceConfig:
    """Random-but-valid TraceConfig; ``enabled=False`` for the parity
    suite (the bus is a pure observer — wild category/cap knobs must be
    inert while disabled)."""
    return TraceConfig(
        enabled=enabled,
        launches=rng.random() < 0.5,
        parks=rng.random() < 0.5,
        overload=rng.random() < 0.5,
        faults=rng.random() < 0.5,
        pressure_every=round(rng.uniform(0.0, 60.0), 1),
        max_events=rng.choice([0, 1, 1000, 1_000_000]),
    )


def fuzz_serve_config(rng: random.Random) -> ServeConfig:
    """Random-but-**inactive** ServeConfig: either disabled carrying wild
    service specs, or quiet-enabled with zero services.  Both leave
    ``active`` False, so the serving layer must never be constructed — not
    a single extra RNG draw, not one decision perturbed."""
    enabled = rng.random() < 0.5
    services = ()
    if not enabled and rng.random() < 0.7:
        services = tuple(
            ServiceSpec(name=f"svc{i}",
                        replicas=rng.randint(1, 4),
                        vcpus=rng.randint(1, 2),
                        base_rps=round(rng.uniform(1.0, 40.0), 2),
                        diurnal_amplitude=round(rng.uniform(0.0, 0.9), 2),
                        burst_prob=round(rng.uniform(0.0, 0.2), 3),
                        burst_size_mean=round(rng.uniform(1.0, 16.0), 1),
                        service_time=round(rng.uniform(0.005, 0.1), 4),
                        slo_p99_ms=round(rng.uniform(100.0, 800.0), 1))
            for i in range(rng.randint(1, 2)))
    headroom = round(rng.uniform(0.1, 0.8), 2)
    return ServeConfig(
        enabled=enabled, services=services,
        harvest_headroom=headroom,
        harvest_return_util=round(headroom + rng.uniform(0.05, 0.19), 3),
        harvest_util_alpha=round(rng.uniform(0.05, 0.9), 3),
        slo_violation_bound=round(rng.uniform(0.0, 0.2), 3))


def build_scenario(rng: random.Random):
    """One random scenario: cluster shape, job mix, sim + scheduler knobs.
    Everything is drawn from ``rng``, so a scenario is reproducible from its
    integer seed alone."""
    machines = rng.randint(2, 8)
    vms = rng.randint(1, 2)
    nodes = machines * vms
    spec = ClusterSpec(num_machines=machines, vms_per_machine=vms,
                       replication=rng.randint(1, min(2, nodes)),
                       adaptive=fuzz_adaptive_config(rng),
                       faults=fuzz_fault_config(rng))
    n_jobs = rng.randint(1, 6)
    submits = sorted(round(rng.uniform(0.0, SUBMIT_WINDOW_S), 2)
                     for _ in range(n_jobs))
    submits[0] = 0.0
    jobs = []
    for i, t in enumerate(submits):
        w = rng.choice(sorted(WORKLOADS))
        gb = round(rng.uniform(0.125, 3.0), 3)
        deadline = round(default_deadline(w, gb) * rng.uniform(0.6, 3.0), 1)
        jobs.append(make_job(f"{w}-{i}", w, gb, deadline, spec, rng,
                             submit_time=t, skew=rng.uniform(0.0, 1.5)))
    # drawn *after* everything else so the tracing knobs don't shift the
    # pre-existing RNG stream — scenario seeds stay comparable across the
    # invariant/chaos suites that pin behaviour per seed range
    spec = dataclasses.replace(spec, tracing=fuzz_trace_config(rng))
    # the win-aware latch / churn-relief knobs are likewise tail-drawn:
    # while adaptive.enabled=False they must be inert (the parity suite
    # proves it), and appending them keeps every earlier draw unshifted
    spec = dataclasses.replace(spec, adaptive=dataclasses.replace(
        spec.adaptive,
        surge_width=round(rng.uniform(0.0, 40.0), 1),
        crash_discount=rng.random() < 0.5,
        ewma_gap_cap=round(rng.uniform(0.0, 8.0), 2),
    ))
    # serving knobs are tail-drawn for the same reason: while the config is
    # inactive (disabled, or quiet-enabled with zero services) it must be
    # invisible to both engines — the parity sweep proves it
    spec = dataclasses.replace(spec, serve=fuzz_serve_config(rng))
    return {
        "spec": spec,
        "jobs": jobs,
        "scheduler": rng.choice(["proposed", "fair", "fifo"]),
        "sim_seed": rng.randrange(1 << 30),
        "straggler_prob": rng.choice([0.0, 0.05, 0.2]),
        "straggler_factor": round(rng.uniform(2.0, 4.0), 2),
        "speculative": rng.random() < 0.75,
        "speculation_threshold": round(rng.uniform(1.5, 3.0), 2),
        "max_wait": round(rng.uniform(5.0, 60.0), 1),
        "park_depth": rng.randint(1, 6),
    }


def _policy_spec(sc) -> PolicySpec:
    """The scenario's scheduler as a policy spec — the fuzz suite builds
    both engines through the *policy registry* construction path, so the
    parity contract re-pins specs end-to-end, not just direct kwargs."""
    params = {}
    if sc["scheduler"] in ("proposed", "adaptive"):
        params = {"max_wait": sc["max_wait"], "park_depth": sc["park_depth"]}
    return PolicySpec(sc["scheduler"], params)


def _schedulers(sc):
    spec = sc["spec"]
    policy = _policy_spec(sc)
    new = policy.build(spec)
    if sc["scheduler"] == "adaptive":
        # pressure-adaptive mode: new engine only (no legacy counterpart)
        with pytest.raises(PolicyError):
            policy.build(spec, legacy=True)
        return new, None
    return new, policy.build(spec, legacy=True)


def assert_scenario_parity(sc):
    new_sched, old_sched = _schedulers(sc)
    kwargs = dict(seed=sc["sim_seed"],
                  straggler_prob=sc["straggler_prob"],
                  straggler_factor=sc["straggler_factor"],
                  speculative=sc["speculative"],
                  speculation_threshold=sc["speculation_threshold"])
    res_new = ClusterSim(sc["spec"], new_sched, **kwargs).run(
        [j for j in sc["jobs"]])
    res_old = LegacyClusterSim(sc["spec"], old_sched, **kwargs).run(
        [j for j in sc["jobs"]])
    # headline metrics — exact equality, not approximate
    assert res_new.makespan == res_old.makespan
    assert res_new.deadlines_met() == res_old.deadlines_met()
    assert res_new.locality_rate() == res_old.locality_rate()
    assert res_new.speculative_launches == res_old.speculative_launches
    # per-job agreement pins the full decision sequence
    assert set(res_new.jobs) == set(res_old.jobs)
    for jid, new in res_new.jobs.items():
        old = res_old.jobs[jid]
        assert new.finish_time == old.finish_time, jid
        assert new.local_map_launches == old.local_map_launches, jid
        assert new.remote_map_launches == old.remote_map_launches, jid
        assert new.reconfig_map_launches == old.reconfig_map_launches, jid
        assert new.map_durations == old.map_durations, jid
        assert new.reduce_durations == old.reduce_durations, jid
    for key in ("reconfigurations", "parked", "expired"):
        assert (res_new.reconfig_stats.get(key)
                == res_old.reconfig_stats.get(key))


@pytest.mark.fuzz
@pytest.mark.parametrize("chunk", range(CHUNKS))
def test_fuzz_parity_deterministic(chunk):
    """The canonical ≥200-scenario sweep: deterministic per
    (REPRO_FUZZ_SEED, REPRO_FUZZ_SCENARIOS), split into chunks so a failure
    localizes; the failing scenario seed is in the assertion context."""
    per_chunk = (N_SCENARIOS + CHUNKS - 1) // CHUNKS
    start = chunk * per_chunk
    for k in range(start, min(start + per_chunk, N_SCENARIOS)):
        scenario_seed = BASE_SEED * 1_000_003 + k
        sc = build_scenario(random.Random(scenario_seed))
        try:
            assert_scenario_parity(sc)
        except AssertionError as e:
            raise AssertionError(
                f"parity broken for fuzz scenario seed={scenario_seed} "
                f"({sc['scheduler']}, {sc['spec'].num_machines}x"
                f"{sc['spec'].vms_per_machine}, {len(sc['jobs'])} jobs): {e}"
            ) from e


@pytest.mark.fuzz
@pytest.mark.skipif(hypothesis is None,
                    reason="hypothesis not installed (pip install .[test])")
def test_fuzz_parity_hypothesis():
    """Extra hypothesis-driven exploration on top of the deterministic sweep
    (shrinking gives a minimal scenario seed on failure)."""

    @given(st.integers(min_value=0, max_value=2 ** 32 - 1))
    def check(scenario_seed):
        assert_scenario_parity(build_scenario(random.Random(scenario_seed)))

    check()


# ---------------------------------------------------------------------------
# adaptive-ON differential suite: liveness, not parity
# ---------------------------------------------------------------------------

def run_adaptive(sc):
    """Run the scenario on the new engine with adaptive mode ON (fuzzed
    enabled knobs) and return (result, scheduler)."""
    sc = dict(sc)
    sc["scheduler"] = "adaptive"
    sched, _ = _schedulers(sc)
    sim = ClusterSim(sc["spec"], sched, seed=sc["sim_seed"],
                     straggler_prob=sc["straggler_prob"],
                     straggler_factor=sc["straggler_factor"],
                     speculative=sc["speculative"],
                     speculation_threshold=sc["speculation_threshold"])
    return sim.run([j for j in sc["jobs"]]), sched


def assert_adaptive_liveness(sc):
    """Adaptive parking must never strand a task: every job finishes, every
    task completes exactly once, and every park leaves its AQ through a
    match, an expiry, or as a stale reservation whose task already ran."""
    res, sched = run_adaptive(sc)
    for jid, job in res.jobs.items():
        assert job.finish_time is not None, f"{jid} never finished"
        assert len(job.completed_map) == job.spec.u_m, jid
        assert len(job.completed_reduce) == job.spec.v_r, jid
    # the park ledger balances: entries still queued are stale reservations
    # of tasks that already completed — never a pending task left behind
    rc = sched.reconfig
    leftover = [item for q in rc.aq for item in q]
    stats = res.reconfig_stats
    assert stats["parked"] == (stats["reconfigurations"] + stats["expired"]
                               + len(leftover))
    for item in leftover:
        job = res.jobs[item.task.job_id]
        assert item.task.index in job.completed_map, (
            f"stranded parked task {item.task}")
    assert not rc.in_flight                 # no plug left hanging
    # adaptive-off completes the same task set (differential completeness)
    sc_off = dict(sc)
    sc_off["scheduler"] = "proposed"
    sched_off, _ = _schedulers(sc_off)
    res_off = ClusterSim(sc["spec"], sched_off, seed=sc["sim_seed"],
                         straggler_prob=sc["straggler_prob"],
                         straggler_factor=sc["straggler_factor"],
                         speculative=sc["speculative"],
                         speculation_threshold=sc["speculation_threshold"]
                         ).run([j for j in sc["jobs"]])
    assert set(res.jobs) == set(res_off.jobs)
    for jid, job in res_off.jobs.items():
        assert job.completed_map == res.jobs[jid].completed_map, jid
        assert job.completed_reduce == res.jobs[jid].completed_reduce, jid


@pytest.mark.fuzz
@pytest.mark.parametrize("chunk", range(CHUNKS))
def test_fuzz_adaptive_never_strands(chunk):
    """Adaptive-ON sweep over REPRO_ADAPTIVE_FUZZ_SCENARIOS generated
    scenarios (fuzzed enabled knobs): the liveness/ledger contract above."""
    per_chunk = (N_ADAPTIVE + CHUNKS - 1) // CHUNKS
    start = chunk * per_chunk
    for k in range(start, min(start + per_chunk, N_ADAPTIVE)):
        scenario_seed = BASE_SEED * 7_000_003 + k
        sc = build_scenario(random.Random(scenario_seed))
        try:
            assert_adaptive_liveness(sc)
        except AssertionError as e:
            raise AssertionError(
                f"adaptive liveness broken for scenario seed={scenario_seed} "
                f"({sc['spec'].num_machines}x{sc['spec'].vms_per_machine}, "
                f"{len(sc['jobs'])} jobs): {e}") from e


def _run_proposed(sc):
    sched, _ = _schedulers(sc)
    return ClusterSim(sc["spec"], sched, seed=sc["sim_seed"],
                      straggler_prob=sc["straggler_prob"],
                      straggler_factor=sc["straggler_factor"],
                      speculative=sc["speculative"],
                      speculation_threshold=sc["speculation_threshold"]
                      ).run([j for j in sc["jobs"]])


# ---------------------------------------------------------------------------
# fault-ON chaos suite: churn liveness, not parity
# ---------------------------------------------------------------------------

FAULT_POLICIES = ("proposed", "adaptive", "adaptive_ra", "delay",
                  "fair", "fifo")


def run_faulty(sc, policy: str):
    """Run the scenario on the new engine with an enabled fuzzed
    FaultConfig and return (sim, result)."""
    rng = random.Random(f"fault-knobs:{sc['sim_seed']}")
    spec = dataclasses.replace(sc["spec"],
                               faults=fuzz_fault_config(rng, enabled=True))
    sched = PolicySpec(policy).build(spec)
    sim = ClusterSim(spec, sched, seed=sc["sim_seed"],
                     straggler_prob=sc["straggler_prob"],
                     straggler_factor=sc["straggler_factor"],
                     speculative=sc["speculative"],
                     speculation_threshold=sc["speculation_threshold"])
    return sim, sim.run([j for j in sc["jobs"]])


def assert_fault_liveness(sc, policy: str):
    """Churn must degrade, never wedge: the event loop drains, every job
    finishes with every task completed exactly once, every crash-lost
    primary is re-executed, and no work is left behind on a down node."""
    sim, res = run_faulty(sc, policy)
    assert not sim.events, "event-queue leak: loop exited with events queued"
    assert not sim.live, "tasks still marked running after drain"
    assert not sim.lost_pending, (
        f"crash-lost tasks never re-executed: {sorted(sim.lost_pending)}")
    for node in range(sim.spec.num_nodes):
        assert not sim.map_running[node] and not sim.red_running[node]
    for jid, job in res.jobs.items():
        assert job.finish_time is not None, f"{jid} never finished"
        assert len(job.completed_map) == job.spec.u_m, jid
        assert len(job.completed_reduce) == job.spec.v_r, jid
    st = res.fault_stats
    assert st["crashes"] == sum(
        1 for _, kind, _ in res.fault_log if kind == "crash")
    # every loss is either re-executed or was a dead speculative copy
    assert st["tasks_reexecuted"] <= st["tasks_lost"]
    return st


@pytest.mark.fuzz
@pytest.mark.parametrize("chunk", range(CHUNKS))
def test_fuzz_fault_liveness(chunk):
    """Fault-ON sweep over REPRO_FAULT_FUZZ_SCENARIOS seeded crash/churn
    scenarios, policy column rotated per scenario; the chunk must observe
    crashes (the knobs are drawn so churn actually happens)."""
    per_chunk = (N_FAULT + CHUNKS - 1) // CHUNKS
    start = chunk * per_chunk
    crashes = 0
    for k in range(start, min(start + per_chunk, N_FAULT)):
        scenario_seed = BASE_SEED * 13_000_003 + k
        sc = build_scenario(random.Random(scenario_seed))
        policy = FAULT_POLICIES[k % len(FAULT_POLICIES)]
        try:
            st = assert_fault_liveness(sc, policy)
        except AssertionError as e:
            raise AssertionError(
                f"fault liveness broken for scenario seed={scenario_seed} "
                f"({policy}, {sc['spec'].num_machines}x"
                f"{sc['spec'].vms_per_machine}, {len(sc['jobs'])} jobs): {e}"
            ) from e
        crashes += st["crashes"]
    assert crashes > 0, "chaos suite chunk observed zero crashes"


@pytest.mark.fuzz
def test_fault_off_is_default_and_inert():
    """FaultConfig defaults to off, and a disabled config with wild knobs
    produces the identical run as the default config — the fault analogue
    of the adaptive inertness pin below."""
    assert FaultConfig().enabled is False
    sc = build_scenario(random.Random(31337))
    sc["scheduler"] = "proposed"
    assert sc["spec"].faults != FaultConfig()    # wild (disabled) knobs
    res_knobs = _run_proposed(sc)
    sc_plain = dict(sc)
    sc_plain["spec"] = dataclasses.replace(sc["spec"], faults=FaultConfig())
    sc_plain["jobs"] = [j for j in sc["jobs"]]
    res_plain = _run_proposed(sc_plain)
    assert res_knobs.makespan == res_plain.makespan
    assert {j: r.finish_time for j, r in res_knobs.jobs.items()} \
        == {j: r.finish_time for j, r in res_plain.jobs.items()}
    assert res_knobs.fault_stats == {} and res_knobs.fault_log == []


@pytest.mark.fuzz
def test_serving_off_is_default_and_inert():
    """ServeConfig defaults to off, an inactive config with wild knobs
    produces the identical run as the default config, and no serving layer
    or serve metrics appear — the serving analogue of the fault pin."""
    assert ServeConfig().enabled is False
    assert ServeConfig().active is False
    # quiet-enabled (services=()) is inactive too — satellite contract
    assert ServeConfig(enabled=True).active is False
    sc = build_scenario(random.Random(77377))
    sc["scheduler"] = "proposed"
    assert sc["spec"].serve != ServeConfig()     # wild (inactive) knobs
    assert not sc["spec"].serve.active
    res_knobs = _run_proposed(sc)
    sc_plain = dict(sc)
    sc_plain["spec"] = dataclasses.replace(sc["spec"], serve=ServeConfig())
    sc_plain["jobs"] = [j for j in sc["jobs"]]
    res_plain = _run_proposed(sc_plain)
    assert res_knobs.makespan == res_plain.makespan
    assert {j: r.finish_time for j, r in res_knobs.jobs.items()} \
        == {j: r.finish_time for j, r in res_plain.jobs.items()}
    assert res_knobs.serve_stats == {} and res_knobs.serve_log == []


@pytest.mark.fuzz
def test_serving_quiet_enabled_matches_off_bit_exact():
    """``ServeConfig(enabled=True, services=())`` is *quiet-enabled*: the
    layer never builds, so the run is bit-exact against serving-off —
    makespan, per-job launch splits and reconfig stats all identical."""
    sc = build_scenario(random.Random(424242))
    sc["scheduler"] = "proposed"
    sc_off = dict(sc)
    sc_off["spec"] = dataclasses.replace(sc["spec"], serve=ServeConfig())
    sc_off["jobs"] = [j for j in sc["jobs"]]
    sc_quiet = dict(sc)
    sc_quiet["spec"] = dataclasses.replace(
        sc["spec"], serve=ServeConfig(enabled=True, services=()))
    sc_quiet["jobs"] = [j for j in sc["jobs"]]
    res_off, res_quiet = _run_proposed(sc_off), _run_proposed(sc_quiet)
    assert res_off.makespan == res_quiet.makespan
    assert res_off.events_processed == res_quiet.events_processed
    assert res_off.reconfig_stats == res_quiet.reconfig_stats
    for jid, off in res_off.jobs.items():
        quiet = res_quiet.jobs[jid]
        assert off.finish_time == quiet.finish_time, jid
        assert off.local_map_launches == quiet.local_map_launches, jid
        assert off.remote_map_launches == quiet.remote_map_launches, jid
        assert off.map_durations == quiet.map_durations, jid
    assert res_quiet.serve_stats == {} and res_quiet.serve_log == []


@pytest.mark.fuzz
def test_tracing_off_is_default_and_inert():
    """TraceConfig defaults to off, no bus is attached while disabled, and
    a disabled config with wild knobs produces the identical run as the
    default config — the observer analogue of the fault/adaptive pins."""
    assert TraceConfig().enabled is False
    sc = build_scenario(random.Random(55057))
    sc["scheduler"] = "proposed"
    assert sc["spec"].tracing != TraceConfig()   # wild (disabled) knobs
    res_knobs = _run_proposed(sc)
    assert res_knobs.trace is None
    sc_plain = dict(sc)
    sc_plain["spec"] = dataclasses.replace(sc["spec"],
                                           tracing=TraceConfig())
    sc_plain["jobs"] = [j for j in sc["jobs"]]
    res_plain = _run_proposed(sc_plain)
    assert res_knobs.makespan == res_plain.makespan
    assert {j: r.finish_time for j, r in res_knobs.jobs.items()} \
        == {j: r.finish_time for j, r in res_plain.jobs.items()}


@pytest.mark.fuzz
def test_adaptive_off_is_default_and_inert():
    """AdaptiveConfig defaults to off, and a disabled config with wild
    knobs produces the identical run (same RNG draws, same decisions) as
    the default config."""
    assert AdaptiveConfig().enabled is False
    sc = build_scenario(random.Random(90210))
    sc["scheduler"] = "proposed"
    res_knobs = _run_proposed(sc)
    sc_plain = dict(sc)
    sc_plain["spec"] = dataclasses.replace(sc["spec"],
                                           adaptive=AdaptiveConfig())
    sc_plain["jobs"] = [j for j in sc["jobs"]]
    res_plain = _run_proposed(sc_plain)
    assert res_knobs.makespan == res_plain.makespan
    assert {j: r.finish_time for j, r in res_knobs.jobs.items()} \
        == {j: r.finish_time for j, r in res_plain.jobs.items()}
