"""The first-class policy API contract (``repro.core.policies``).

Covers: registry round-trips (``from_dict(to_dict(spec))`` identity),
error paths (unknown policy / unknown param / ill-typed param / bad JSON),
preset equivalence (registry-built schedulers produce bit-identical runs to
direct construction — the old string factory's bodies), cache-key stability
pins (cell hashes captured on the pre-policy commit must never move, or
every sweep cache on disk is orphaned), the deprecation shim, and the
behaviour of the composed non-preset policies (``delay``, ``edf_nopark``,
``adaptive_ra``).
"""
import dataclasses
import random

import pytest

from repro.core.baselines import FairScheduler, FIFOScheduler
from repro.core.policies import (COMPONENT_AXES, PolicyError, PolicySpec,
                                 build_policy, registered_policies,
                                 smoke_test_policies)
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler, SchedulerBase
from repro.core.types import ClusterSpec
from repro.simcluster.sim import ClusterSim
from repro.simcluster.workloads import (default_deadline, make_job,
                                        paper_cluster, paper_table2_jobs)

PRESETS = ("proposed", "adaptive", "fair", "fifo")


# ---------------------------------------------------------------------------
# registry + spec round-trips
# ---------------------------------------------------------------------------

def test_registry_covers_presets_and_extras():
    reg = registered_policies()
    assert set(PRESETS) <= set(reg)
    assert {"adaptive_ra", "delay", "edf_nopark", "harvest"} <= set(reg)
    for name, pol in reg.items():
        assert pol.name == name
        for axis, vocab in COMPONENT_AXES.items():
            assert pol.components[axis] in vocab, (name, axis)
    # the component decomposition puts the presets where the paper does;
    # every pre-serving policy sits at harvest "off" (the axis default)
    assert reg["proposed"].components == {
        "ordering": "edf", "park": "fixed", "overload": "none",
        "harvest": "off"}
    assert reg["adaptive"].components == {
        "ordering": "edf", "park": "adaptive", "overload": "latch",
        "harvest": "off"}
    assert reg["adaptive_ra"].components["overload"] == "reduce_aware"
    assert reg["fair"].components["ordering"] == "fair_deficit"
    assert reg["fifo"].components["ordering"] == "fifo"
    assert reg["delay"].components == {
        "ordering": "fair_deficit", "park": "off", "overload": "none",
        "harvest": "off"}
    assert reg["edf_nopark"].components == {
        "ordering": "edf", "park": "off", "overload": "none",
        "harvest": "off"}
    # the serving-aware preset: adaptive machinery + the harvest component
    assert reg["harvest"].components == {
        "ordering": "edf", "park": "adaptive", "overload": "latch",
        "harvest": "ewma"}


def test_harvest_preset_builds_harvest_flagged_scheduler():
    """Only the ``harvest`` preset flips ``SchedulerBase.harvest``; every
    other registered policy leaves the class default False."""
    spec = ClusterSpec(num_machines=2)
    assert SchedulerBase.harvest is False
    sched = build_policy("harvest", spec)
    assert sched.harvest is True
    assert sched.spec.adaptive.enabled         # adaptive construction path
    for name in ("proposed", "adaptive", "fair", "fifo", "delay"):
        assert build_policy(name, spec).harvest is False, name


@pytest.mark.parametrize("name", sorted({"proposed", "adaptive",
                                         "adaptive_ra", "delay", "fair",
                                         "fifo", "edf_nopark"}))
def test_spec_roundtrip_identity(name):
    """from_dict(to_dict(spec)) == spec, for defaults and for overrides."""
    spec = PolicySpec(name)
    assert PolicySpec.from_dict(spec.to_dict()) == spec
    defaults = registered_policies()[name].defaults
    for key, default in defaults.items():
        if isinstance(default, bool):
            override = not default
        elif isinstance(default, (int, float)):
            override = default + 1
        else:
            continue
        tweaked = PolicySpec(name, {key: override})
        assert PolicySpec.from_dict(tweaked.to_dict()) == tweaked
        assert tweaked != spec
        assert tweaked.effective_params()[key] == override


def test_spec_canonicalization_drops_default_params():
    """A param explicitly set to its default is the same policy: equal
    spec, same label, same cache key."""
    bare = PolicySpec("proposed")
    explicit = PolicySpec("proposed", {"max_wait": 30.0, "park_depth": 2})
    assert bare == explicit
    assert explicit.params == {}
    assert explicit.label == "proposed"
    assert explicit.cache_descriptor() == "proposed"
    assert bare.cache_key() == explicit.cache_key()


def test_spec_parse_accepts_name_json_dict_and_spec():
    s = PolicySpec.parse("fair")
    assert s == PolicySpec("fair")
    assert PolicySpec.parse(s) is s
    j = PolicySpec.parse('{"name": "delay", "params": {"locality_delay": 4}}')
    assert j == PolicySpec("delay", {"locality_delay": 4})
    assert j.label == "delay[locality_delay=4]"
    d = PolicySpec.parse({"name": "adaptive", "params": {"max_wait": 20.0}})
    assert d.effective_params()["max_wait"] == 20.0


def test_spec_error_paths():
    with pytest.raises(PolicyError, match="unknown policy"):
        PolicySpec("totally_new_policy")
    with pytest.raises(PolicyError, match="no parameter"):
        PolicySpec("fair", {"max_wait": 10.0})
    with pytest.raises(PolicyError, match="must be a number"):
        PolicySpec("proposed", {"max_wait": "fast"})
    with pytest.raises(PolicyError, match="must be an int"):
        PolicySpec("proposed", {"park_depth": 2.5})
    with pytest.raises(PolicyError, match="bad policy JSON"):
        PolicySpec.parse("{not json")
    with pytest.raises(PolicyError, match="name"):
        PolicySpec.parse({"params": {}})
    with pytest.raises(PolicyError, match="name"):
        PolicySpec.from_dict({"name": "fair", "extra": 1})
    with pytest.raises(PolicyError, match="must be a string"):
        PolicySpec.parse('{"name": {"x": 1}}')
    with pytest.raises(PolicyError, match="no legacy"):
        PolicySpec("adaptive").build(ClusterSpec(num_machines=2),
                                     legacy=True)
    # PolicyError is a ValueError: old `except ValueError` call sites hold
    assert issubclass(PolicyError, ValueError)


# ---------------------------------------------------------------------------
# cache-key stability
# ---------------------------------------------------------------------------

def test_cache_descriptor_legacy_alias():
    """Default preset specs collapse to the bare scheduler string the
    pre-policy cell descriptors carried; overrides switch to the dict."""
    for name in PRESETS + ("adaptive_ra", "delay", "edf_nopark"):
        assert PolicySpec(name).cache_descriptor() == name
    parameterized = PolicySpec("delay", {"locality_delay": 4})
    assert parameterized.cache_descriptor() == {
        "name": "delay", "params": {"locality_delay": 4}}


def test_cell_hashes_pin_pre_policy_cache_layout():
    """Cell hashes captured on the pre-policy commit (string schedulers).
    If one of these moves, every sweep cache on disk is orphaned — the
    legacy-alias contract is broken."""
    from repro.experiments.regimes import regime_spec
    from repro.experiments.runner import ExperimentSpec, TraceRef

    expected = {
        ("diurnal", "proposed"): "3b17001a30edb2a6",
        ("diurnal", "adaptive"): "4b070d9337068542",
        ("diurnal", "fair"): "4bc676956b6b3e2b",
        ("diurnal", "fifo"): "8fb06067a5bf44a4",
        ("heavy_tail", "proposed"): "8738df8c488c6a89",
        ("heavy_tail", "adaptive"): "946d33ecf3ebdb21",
        ("heavy_tail", "fair"): "8da1de015f3854fb",
        ("heavy_tail", "fifo"): "303797c134397519",
    }
    for preset in ("diurnal", "heavy_tail"):
        spec = regime_spec(preset, "20x2", seeds=(0,))
        for cell in spec.cells():
            key = (preset, cell.scheduler.label)
            if key in expected:
                assert cell.cache_hash() == expected[key], key
    # a CLI-shaped grid (path-free preset trace, explicit cluster)
    cli = ExperimentSpec(
        name="pin", traces=(TraceRef(preset="bursty"),),
        clusters=(ClusterSpec(num_machines=10, vms_per_machine=2,
                              replication=1),),
        schedulers=("proposed", "fair"), seeds=(0,))
    hashes = {c.scheduler.label: c.cache_hash() for c in cli.cells()}
    assert hashes == {"proposed": "eee4f777a374ba14",
                      "fair": "ef191f59af9f81d6"}
    # the surrogate engine's parallel hash family for the same grid —
    # pinned alongside so the namespaces can drift neither onto each other
    # nor away from their own caches on disk
    from repro.experiments.surrogate import surrogate_hash
    sur = {c.scheduler.label: surrogate_hash(c) for c in cli.cells()}
    assert sur == {"proposed": "3702536d985edd1e",
                   "fair": "4de0f7ac0dd18d9b"}
    assert not set(sur.values()) & set(hashes.values())


def test_policy_cache_keys_are_pinned():
    """PolicySpec.cache_key() is content-stable (introduced with the policy
    API; pinned so later refactors cannot silently reshuffle it)."""
    assert PolicySpec("proposed").cache_key() == \
        PolicySpec("proposed", {"max_wait": 30.0}).cache_key()
    assert PolicySpec("delay").cache_key() != \
        PolicySpec("delay", {"locality_delay": 4}).cache_key()
    pins = {
        "proposed": "ff278f96de1e0054",
        "fair": "da6a726b1a6357b4",
    }
    for name, key in pins.items():
        assert PolicySpec(name).cache_key() == key, name


# ---------------------------------------------------------------------------
# preset equivalence: registry construction == direct construction
# ---------------------------------------------------------------------------

def _direct_scheduler(kind, spec):
    """The old string factory's construction bodies, verbatim."""
    if kind == "proposed":
        return CompletionTimeScheduler(spec,
                                       Reconfigurator(spec, max_wait=30.0))
    if kind == "adaptive":
        aspec = spec if spec.adaptive.enabled else dataclasses.replace(
            spec, adaptive=dataclasses.replace(spec.adaptive, enabled=True))
        return CompletionTimeScheduler(aspec,
                                       Reconfigurator(aspec, max_wait=30.0))
    if kind == "fair":
        return FairScheduler(spec)
    return FIFOScheduler(spec)


@pytest.mark.parametrize("kind", PRESETS)
def test_preset_specs_match_direct_construction_bit_exactly(kind):
    """A registry-built preset runs bit-identically to the ad-hoc kwargs
    construction the old factory performed (same RNG draws, same decisions,
    same per-job finish times)."""
    spec = paper_cluster()
    results = []
    for build in (lambda: PolicySpec(kind).build(spec),
                  lambda: _direct_scheduler(kind, spec)):
        sched = build()
        results.append(ClusterSim(spec, sched, seed=7).run(
            paper_table2_jobs(spec, seed=7)))
    a, b = results
    assert a.makespan == b.makespan
    assert a.deadlines_met() == b.deadlines_met()
    assert a.locality_rate() == b.locality_rate()
    assert a.speculative_launches == b.speculative_launches
    for jid, ja in a.jobs.items():
        jb = b.jobs[jid]
        assert ja.finish_time == jb.finish_time, jid
        assert ja.local_map_launches == jb.local_map_launches, jid
        assert ja.remote_map_launches == jb.remote_map_launches, jid


def test_proposed_preset_matches_factory_on_adaptive_enabled_cluster():
    """The cache descriptor for `proposed` is the bare string on *every*
    cluster, including one that hand-enables AdaptiveConfig — so the built
    scheduler must reproduce the pre-policy factory (ctor defaults) there
    too, or cached and fresh cells would mix two different policies."""
    from repro.core.types import AdaptiveConfig
    spec = dataclasses.replace(paper_cluster(),
                               adaptive=AdaptiveConfig(enabled=True))
    a = ClusterSim(spec, PolicySpec("proposed").build(spec), seed=5).run(
        paper_table2_jobs(spec, seed=5))
    b = ClusterSim(spec, _direct_scheduler("proposed", spec), seed=5).run(
        paper_table2_jobs(spec, seed=5))
    assert a.makespan == b.makespan
    assert a.locality_rate() == b.locality_rate()
    for jid, ja in a.jobs.items():
        assert ja.finish_time == b.jobs[jid].finish_time, jid


def test_built_scheduler_carries_policy_and_label():
    spec = ClusterSpec(num_machines=2)
    sched = PolicySpec("adaptive").build(spec)
    assert sched.policy == PolicySpec("adaptive")
    assert sched.name == "adaptive"         # the instance-attr hack, now API
    custom = PolicySpec("fair", {"locality_delay": 3}).build(spec)
    assert custom.name == "fair[locality_delay=3]"
    assert custom.locality_delay == 3
    via_base = SchedulerBase.from_policy("fifo", spec)
    assert isinstance(via_base, FIFOScheduler)


def test_build_scheduler_string_path_is_deprecated():
    from repro.simcluster.largescale import build_scheduler
    spec = ClusterSpec(num_machines=2)
    with pytest.warns(DeprecationWarning, match="build_scheduler"):
        sched = build_scheduler("proposed", spec)
    assert isinstance(sched, CompletionTimeScheduler)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            build_scheduler("nope", spec)


# ---------------------------------------------------------------------------
# composed policies behave as declared
# ---------------------------------------------------------------------------

def _tiny_run(policy, *, machines=3, jobs=4, seed=11, skew=1.5):
    spec = ClusterSpec(num_machines=machines, vms_per_machine=2,
                       replication=1)
    rng = random.Random(seed)
    job_list = [make_job(f"j{i}", w, 0.5, default_deadline(w, 0.5),
                         spec, rng, submit_time=2.0 * i, skew=skew)
                for i, w in enumerate(("wordcount", "grep", "sort",
                                       "wordcount")[:jobs])]
    sched = build_policy(policy, spec)
    result = ClusterSim(spec, sched, seed=seed).run(job_list)
    return sched, result


def test_edf_nopark_never_parks():
    sched, result = _tiny_run("edf_nopark")
    assert sched.parking is False
    assert sched.uses_reconfig is False      # simulator skips reconfig paths
    assert result.reconfig_stats == {}       # sim saw no reconfigurator
    assert sched.reconfig.stats["parked"] == 0
    assert all(j.finish_time is not None for j in result.jobs.values())
    # the EDF machinery still ran: some remote launches happened instead
    assert sum(j.remote_map_launches for j in result.jobs.values()) > 0


def test_delay_policy_waits_for_locality():
    _, fair_res = _tiny_run("fair")
    _, delay_res = _tiny_run("delay")
    assert all(j.finish_time is not None for j in delay_res.jobs.values())
    # same workload, same placements: waiting for local slots must not
    # lower the data-local launch rate
    assert delay_res.locality_rate() >= fair_res.locality_rate()


def test_adaptive_ra_overload_knob_reaches_scheduler():
    spec = ClusterSpec(num_machines=2)
    assert build_policy("adaptive", spec).overload_policy == "latch"
    assert build_policy("adaptive_ra", spec).overload_policy == "reduce_aware"
    # `proposed` keeps the ctor default: on a cluster that enables
    # AdaptiveConfig by hand, the preset must reproduce the pre-policy
    # factory (which used the default) bit-exactly — the declared
    # overload component "none" reflects the preset's own terms, where
    # adaptive stays off and the machinery is inert
    assert build_policy("proposed", spec).overload_policy \
        == CompletionTimeScheduler(spec).overload_policy
    assert build_policy("edf_nopark", spec).overload_policy == "none"
    with pytest.raises(ValueError, match="overload"):
        CompletionTimeScheduler(spec, overload="sometimes")


def test_smoke_all_registered_policies():
    assert smoke_test_policies() == []
