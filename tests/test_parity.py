"""Decision-parity contract: the incremental-index engine must reproduce the
frozen seed engine's scheduling decisions *exactly*.

The optimized scheduler/simulator (per-job pending heaps, per-node local
index, lazy speculation heap, incremental reconfigurator queues) is a pure
reimplementation of the seed semantics — same candidate order, same RNG
draw sequence, same event tie-breaking.  For fixed seeds on the paper
cluster the two engines must therefore agree bit-for-bit on every
``SimResult`` metric, not just approximately.

If one of these tests fails after an engine change, the change altered
scheduling *behaviour*, not just speed — either fix it or (if the new
behaviour is intended) update the frozen legacy engine AND the paper-repro
expectations together.
"""
import pytest

from repro.core.baselines import FairScheduler, FIFOScheduler
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler
from repro.simcluster._legacy import (LegacyClusterSim,
                                      LegacyCompletionTimeScheduler,
                                      LegacyFairScheduler,
                                      LegacyFIFOScheduler,
                                      LegacyReconfigurator)
from repro.simcluster.sim import ClusterSim
from repro.simcluster.workloads import (paper_cluster, paper_job_mix,
                                        paper_table2_jobs)


def _proposed(spec):
    s = CompletionTimeScheduler(spec, Reconfigurator(spec, max_wait=30.0))
    s.park_depth = 4
    return s


def _legacy_proposed(spec):
    s = LegacyCompletionTimeScheduler(spec,
                                      LegacyReconfigurator(spec, max_wait=30.0))
    s.park_depth = 4
    return s


SCHEDULERS = {
    "proposed": (_proposed, _legacy_proposed),
    "fair": (FairScheduler, LegacyFairScheduler),
    "fifo": (FIFOScheduler, LegacyFIFOScheduler),
}


def _run_both(which, seed, jobs_fn):
    spec = paper_cluster()
    new_sched, old_sched = SCHEDULERS[which]
    res_new = ClusterSim(spec, new_sched(spec), seed=seed).run(
        jobs_fn(spec, seed))
    res_old = LegacyClusterSim(spec, old_sched(spec), seed=seed).run(
        jobs_fn(spec, seed))
    return res_new, res_old


def _assert_identical(res_new, res_old):
    # headline SimResult metrics — exact, not approximate
    assert res_new.makespan == res_old.makespan
    assert res_new.deadlines_met() == res_old.deadlines_met()
    assert res_new.locality_rate() == res_old.locality_rate()
    assert res_new.speculative_launches == res_old.speculative_launches
    # per-job agreement pins the full decision sequence, not just aggregates
    assert set(res_new.jobs) == set(res_old.jobs)
    for jid, new in res_new.jobs.items():
        old = res_old.jobs[jid]
        assert new.finish_time == old.finish_time, jid
        assert new.local_map_launches == old.local_map_launches, jid
        assert new.remote_map_launches == old.remote_map_launches, jid
        assert new.reconfig_map_launches == old.reconfig_map_launches, jid
        assert new.map_durations == old.map_durations, jid
        assert new.reduce_durations == old.reduce_durations, jid
    for key in ("reconfigurations", "parked", "expired"):
        assert (res_new.reconfig_stats.get(key)
                == res_old.reconfig_stats.get(key))


@pytest.mark.parametrize("which", ["proposed", "fair", "fifo"])
@pytest.mark.parametrize("seed", [3, 11])
def test_table2_parity(which, seed):
    res_new, res_old = _run_both(
        which, seed, lambda spec, s: paper_table2_jobs(spec, seed=s))
    _assert_identical(res_new, res_old)


@pytest.mark.parametrize("which", ["proposed", "fair"])
def test_job_mix_parity(which):
    res_new, res_old = _run_both(
        which, 2, lambda spec, s: paper_job_mix(spec, sizes_gb=(2, 4, 6),
                                                seed=s))
    _assert_identical(res_new, res_old)


def test_parity_with_heavy_stragglers():
    """Speculation bookkeeping is the trickiest incremental path — pin it
    under a straggler rate high enough to force many speculative launches."""
    spec = paper_cluster()
    res_new = ClusterSim(spec, _proposed(spec), seed=9, straggler_prob=0.2).run(
        paper_table2_jobs(spec, seed=9))
    res_old = LegacyClusterSim(
        spec, _legacy_proposed(spec), seed=9, straggler_prob=0.2).run(
        paper_table2_jobs(spec, seed=9))
    assert res_new.speculative_launches > 0
    _assert_identical(res_new, res_old)
