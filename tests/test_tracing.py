"""Decision-trace bus: config plumbing, observer bit-exactness, typed
fault records, event vocabulary, exporters, warehouse integration, and
the CLI discovery verbs.

The bus is default-off and a pure observer: enabling it draws from no RNG
and changes no decision — a traced run must be bit-identical to the
untraced run — and ``tracing`` never enters ``ClusterSpec.to_dict()``
(even enabled), so a traced replay of a cached cell hashes onto the same
cache entry it explains.
"""
import dataclasses
import json
import random

import pytest

from repro.core.policies import PolicySpec
from repro.core.tracing import (EVENT_KINDS, LATCH_RELEASE_CAUSES,
                                PARK_GATES, FaultEvent, TraceBus,
                                dumps_canonical)
from repro.core.types import ClusterSpec, FaultConfig, TraceConfig
from repro.simcluster.largescale import run_scenario
from repro.simcluster.sim import ClusterSim
from repro.simcluster.workloads import default_deadline, make_job

TRACE_ON = TraceConfig(enabled=True, pressure_every=5.0)
CHURN = FaultConfig(enabled=True, crash_mtbf=300.0, crash_mttr=60.0,
                    rereplicate_after=30.0)


def _spec(machines=6, vms=2, replication=1, tracing=TraceConfig(),
          faults=FaultConfig()):
    return ClusterSpec(num_machines=machines, vms_per_machine=vms,
                       replication=replication, tracing=tracing,
                       faults=faults)


def _jobs(spec, n=8, seed=0, stagger=10.0):
    rng = random.Random(seed)
    jobs = []
    for i in range(n):
        w = ["wordcount", "grep", "sort"][i % 3]
        gb = 0.5 + 0.5 * (i % 4)
        jobs.append(make_job(f"{w}-{i}", w, gb, default_deadline(w, gb),
                             spec, rng, submit_time=stagger * i))
    return jobs


def _run(spec, policy="proposed", seed=0, jobs=None):
    sched = PolicySpec(policy).build(spec)
    sim = ClusterSim(spec, sched, seed=seed)
    res = sim.run(jobs if jobs is not None else _jobs(spec))
    return sim, res


# -- config plumbing ----------------------------------------------------------

def test_trace_config_validation_and_roundtrip():
    assert TraceConfig().enabled is False
    with pytest.raises(ValueError):
        TraceConfig(pressure_every=-1.0)
    with pytest.raises(ValueError):
        TraceConfig(max_events=-1)
    rt = TraceConfig.from_dict(TRACE_ON.to_dict())
    assert rt == TRACE_ON


def test_tracing_always_omitted_from_spec_dict():
    """Cache-hash stability, stronger than the faults rule: tracing is a
    pure observer, so even an *enabled* config is dropped from the dict —
    a traced replay must hash onto the cell it explains."""
    assert "tracing" not in ClusterSpec(num_machines=4,
                                        vms_per_machine=2).to_dict()
    assert "tracing" not in _spec(tracing=TRACE_ON).to_dict()
    # explicit tracing in an incoming dict still deserializes
    d = _spec().to_dict()
    d["tracing"] = TRACE_ON.to_dict()
    assert ClusterSpec.from_dict(d).tracing == TRACE_ON


def test_no_bus_attached_while_disabled():
    sim, res = _run(_spec())
    assert sim.trace is None and res.trace is None


# -- observer bit-exactness ---------------------------------------------------

@pytest.mark.parametrize("policy", ["proposed", "adaptive", "fair"])
def test_traced_run_is_bit_exact(policy):
    """Tracing draws from no RNG: the traced run reproduces the untraced
    run decision-for-decision (makespan, per-job finish times, locality
    split), it just also carries the bus."""
    base = _spec()
    _, res_off = _run(base, policy=policy, seed=3)
    _, res_on = _run(_spec(tracing=TRACE_ON), policy=policy, seed=3,
                     jobs=_jobs(base))
    assert res_on.trace is not None and res_on.trace.total > 0
    assert res_on.makespan == res_off.makespan
    assert res_on.locality_rate() == res_off.locality_rate()
    assert res_on.speculative_launches == res_off.speculative_launches
    assert {j: r.finish_time for j, r in res_on.jobs.items()} \
        == {j: r.finish_time for j, r in res_off.jobs.items()}


def test_traced_churn_run_is_byte_reproducible():
    """Same (config, seed): two traced churn runs produce the identical
    fault log and the byte-identical JSONL bus serialization."""
    spec = _spec(tracing=TRACE_ON, faults=CHURN)
    sim_a, res_a = _run(spec, policy="adaptive", seed=7)
    sim_b, res_b = _run(spec, policy="adaptive", seed=7)
    assert sim_a.fault_stats["crashes"] > 0
    assert sim_a.fault_log == sim_b.fault_log
    assert res_a.trace.to_jsonl() == res_b.trace.to_jsonl()


# -- typed fault records ------------------------------------------------------

def test_fault_event_is_byte_compatible_with_tuples():
    """FaultEvent named tuples serialize, compare and unpack exactly like
    the bare (time, kind, machine) tuples they replaced — the
    byte-reproducibility pins in tests/test_faults.py hold unchanged."""
    ev = FaultEvent(12.5, "crash", 3)
    assert json.dumps([ev]) == json.dumps([(12.5, "crash", 3)])
    assert ev == (12.5, "crash", 3)
    t, kind, machine = ev
    assert (t, kind, machine) == (12.5, "crash", 3)
    assert ev.time == 12.5 and ev.kind == "crash" and ev.machine == 3
    sim, _ = _run(_spec(faults=CHURN), seed=7)
    assert sim.fault_stats["crashes"] > 0
    assert all(isinstance(e, FaultEvent) for e in sim.fault_log)
    assert json.dumps(sim.fault_log) \
        == json.dumps([tuple(e) for e in sim.fault_log])


def test_fault_bus_events_match_fault_log():
    sim, res = _run(_spec(tracing=TRACE_ON, faults=CHURN), policy="adaptive",
                    seed=7)
    bus = res.trace
    for kind in ("crash", "restart", "rereplicate"):
        assert bus.count(kind) == sum(1 for e in sim.fault_log
                                      if e.kind == kind)


# -- event vocabulary ---------------------------------------------------------

def test_emitted_kinds_are_registered():
    _, res = _run(_spec(tracing=TRACE_ON, faults=CHURN), policy="adaptive",
                  seed=7)
    registered = {k for kinds in EVENT_KINDS.values() for k in kinds}
    assert set(res.trace.counts) <= registered


def test_park_deny_gates_are_named():
    """Every park_deny event names its failing gate from the PARK_GATES
    vocabulary, with the gate's own signals alongside."""
    gates = set()
    for policy in ("proposed", "adaptive"):
        _, res = _run(_spec(tracing=TRACE_ON), policy=policy, seed=3,
                      jobs=_jobs(_spec(), n=12, stagger=2.0))
        for _, kind, data in res.trace.events:
            if kind == "park_deny":
                gates.add(data["gate"])
    assert gates and gates <= set(PARK_GATES)
    assert len(gates) >= 2


def test_latch_trip_and_release_events():
    """An overloaded adaptive run emits latch_trip with the triggering
    counters, and every latch_release names its cause."""
    spec = _spec(machines=4, tracing=TRACE_ON)
    jobs = _jobs(spec, n=12, stagger=0.5)
    # a straggler job arriving after the burst drains: the latch (if still
    # set) must release on the empty cluster rather than throttle it
    jobs += [make_job("late-0", "grep", 0.5,
                      default_deadline("grep", 0.5), spec,
                      random.Random(99), submit_time=20_000.0)]
    _, res = _run(spec, policy="adaptive", seed=1, jobs=jobs)
    bus = res.trace
    assert bus.count("latch_trip") > 0
    trips = [d for _, k, d in bus.events if k == "latch_trip"]
    for d in trips:
        assert d["pending_maps"] >= d["pending_bar"]
        assert d["crowd"] >= d["crowd_bar"]
    releases = [d for _, k, d in bus.events if k == "latch_release"]
    assert len(releases) > 0
    for d in releases:
        assert d["cause"] in LATCH_RELEASE_CAUSES


def test_category_switches_gate_emission():
    """Per-category booleans suppress exactly their kinds."""
    spec = _spec(tracing=TraceConfig(enabled=True, launches=False))
    _, res = _run(spec, policy="adaptive", seed=3, jobs=_jobs(spec))
    bus = res.trace
    for kind in EVENT_KINDS["launches"]:
        assert bus.count(kind) == 0
    assert any(bus.count(k) for k in EVENT_KINDS["parks"])


def test_max_events_cap_bounds_memory_not_counts():
    spec = _spec(tracing=TraceConfig(enabled=True, max_events=25))
    _, res = _run(spec, policy="adaptive", seed=3, jobs=_jobs(spec))
    bus = res.trace
    assert len(bus.events) == 25
    assert bus.dropped > 0
    assert bus.total == len(bus.events) + bus.dropped
    assert sum(bus.counts.values()) == bus.total


# -- scenario suite + exporters -----------------------------------------------

def test_run_scenario_tracing_hook(tmp_path):
    from repro.experiments.telemetry import (fold_trace, write_chrome_trace,
                                             write_jsonl)
    res = run_scenario("smoke_40x2", scheduler="adaptive", seed=0,
                       tracing=TraceConfig(enabled=True, pressure_every=30.0))
    bus = res.trace
    assert bus is not None and bus.count("launch") > 0
    assert bus.count("pressure") > 0
    untraced = run_scenario("smoke_40x2", scheduler="adaptive", seed=0)
    assert untraced.trace is None and untraced.makespan == res.makespan
    with pytest.raises(ValueError, match="indexed engine"):
        run_scenario("smoke_40x2", engine="legacy", tracing=True)
    # canonical JSONL: every line is a sorted-key record with t/kind
    p = write_jsonl(bus, tmp_path / "t.jsonl")
    lines = p.read_text().splitlines()
    assert len(lines) == len(bus.events)
    rec = json.loads(lines[0])
    assert "t" in rec and "kind" in rec
    assert lines[0] == dumps_canonical(rec)
    # Chrome trace_event JSON: X slices for task executions, with the
    # machine as pid and the VM as tid; instants and counters alongside
    c = write_chrome_trace(bus, tmp_path / "t.chrome.json")
    doc = json.loads(c.read_text())
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert xs and all({"pid", "tid", "ts", "dur"} <= set(e) for e in xs)
    assert any(e["ph"] == "C" for e in doc["traceEvents"])
    summary = fold_trace(bus, res.makespan)
    assert summary.maps_local + summary.maps_remote == bus.count("launch") \
        - summary.reduces - summary.speculative
    assert summary.locality_rate() == pytest.approx(res.locality_rate())


# -- warehouse integration ----------------------------------------------------

def _cell(seed=0):
    from repro.experiments.runner import Cell, TraceRef
    return Cell(trace=TraceRef(preset="mix_small"),
                cluster=ClusterSpec(num_machines=8, vms_per_machine=2),
                scheduler=PolicySpec("adaptive"), seed=seed,
                straggler_prob=0.05, straggler_factor=3.0,
                speculative=True, speculation_threshold=2.0)


def test_simulate_cell_traced_reproduces_the_cached_record(tmp_path):
    from repro.experiments.runner import simulate_cell
    from repro.experiments.telemetry import (fold_trace, simulate_cell_traced,
                                             store_trace_summary)
    cell = _cell()
    plain = simulate_cell(cell)             # dict, as the cache stores it
    record, bus = simulate_cell_traced(cell)
    assert record.makespan == plain["makespan"]
    assert record.locality_rate == plain["locality_rate"]
    assert record.cluster == plain["cluster"]   # tracing not in the dict
    summary = fold_trace(bus, record.makespan)
    path = store_trace_summary(tmp_path, cell, summary)
    from repro.experiments.runner import _cell_paths
    cell_dir, result_path = _cell_paths(tmp_path, cell)
    assert path == cell_dir / f"seed{cell.seed}.trace.json"
    loaded = json.loads(path.read_text())
    assert loaded["counts"] == dict(bus.counts)
    assert loaded["locality_rate"] == pytest.approx(record.locality_rate)


def test_explain_cell_attributes_decisions(tmp_path):
    from repro.experiments.telemetry import explain_cell
    text, pol, base = explain_cell(
        "saturated", "20x2", cache_dir=tmp_path,
        export_dir=tmp_path / "export")
    assert "attribution:" in text
    assert "latch" in text
    assert pol.park_admits + sum(pol.park_denies.values()) > 0
    assert (tmp_path / "export").exists()
    assert any((tmp_path / "export").glob("*.chrome.json"))


# -- CLI ----------------------------------------------------------------------

def test_cli_faults_list(capsys):
    from repro.experiments.__main__ import main
    assert main(["faults", "--list"]) == 0
    out = capsys.readouterr().out
    from repro.experiments.regimes import FAULT_PROFILES
    for name in FAULT_PROFILES:
        assert name in out


def test_cli_explain(tmp_path, capsys):
    from repro.experiments.__main__ import main
    assert main(["explain", "saturated", "20x2", "--cache", str(tmp_path),
                 "--no-store"]) == 0
    out = capsys.readouterr().out
    assert "attribution:" in out and "denied by gate" in out
    with pytest.raises(SystemExit):
        main(["explain", "nope", "20x2"])
    with pytest.raises(SystemExit):
        main(["explain", "saturated", "13x7"])
