"""Fault-injection layer: determinism, re-replication, heterogeneity,
config plumbing, and baseline liveness under churn.

The crash/restart/burst schedule is driven by dedicated per-machine RNG
streams seeded from (sim seed, machine) alone — scheduler decisions never
draw from them, so a run's fault log is byte-reproducible from (config,
seed, workload, policy).  (Fault chains *suspend* while the cluster is
idle and revive on the next submit, so the realized schedule is coupled to
the workload's idle windows — policies that drain at different times can
see different churn tails.)  That determinism is the foundation the chaos
wall stands on: a liveness failure reproduces from its seed.
"""
import copy
import json
import random

import pytest

from repro.core.policies import PolicySpec
from repro.core.types import (ClusterSpec, FaultConfig, JobSpec,
                              MachineClass, TaskKind, WorkloadProfile)
from repro.simcluster.largescale import SCENARIOS
from repro.simcluster.sim import ClusterSim
from repro.simcluster.workloads import default_deadline, make_job

CHURN = FaultConfig(enabled=True, crash_mtbf=300.0, crash_mttr=60.0,
                    rereplicate_after=30.0)
HETERO = (MachineClass(name="new", weight=3),
          MachineClass(name="old", weight=1, speed=1.4, fabric=1.25,
                       mtbf_scale=0.5))


def _spec(machines=6, vms=2, replication=1, faults=CHURN):
    return ClusterSpec(num_machines=machines, vms_per_machine=vms,
                       replication=replication, faults=faults)


def _jobs(spec, n=6, seed=0):
    rng = random.Random(seed)
    jobs = []
    for i in range(n):
        w = ["wordcount", "grep", "sort"][i % 3]
        gb = 0.5 + 0.5 * (i % 4)
        jobs.append(make_job(f"{w}-{i}", w, gb, default_deadline(w, gb),
                             spec, rng, submit_time=30.0 * i))
    return jobs


def _run(spec, policy="proposed", seed=0, jobs=None):
    sched = PolicySpec(policy).build(spec)
    sim = ClusterSim(spec, sched, seed=seed)
    res = sim.run(jobs if jobs is not None else _jobs(spec))
    return sim, res


# -- fault-schedule determinism ----------------------------------------------

def test_fault_log_is_deterministic_for_config_and_seed():
    """Same (FaultConfig, seed, workload, policy) -> byte-identical fault
    event log on every repeat; a different seed diverges.  The schedule is
    drawn from dedicated streams, but chains suspend over idle windows, so
    two *policies* may realize different churn tails — the reproducibility
    pin is per run configuration."""
    logs = {}
    for policy in ("proposed", "fifo", "adaptive"):
        sim, res = _run(_spec(), policy=policy, seed=7)
        assert sim.fault_stats["crashes"] > 0
        logs[policy] = json.dumps(sim.fault_log)
        again, _ = _run(_spec(), policy=policy, seed=7)
        assert json.dumps(again.fault_log) == logs[policy]
    # the pre-idle prefix is policy-independent: every policy starts from
    # the same per-machine streams, so the first crash is shared
    first = json.loads(logs["proposed"])[0]
    assert first == json.loads(logs["fifo"])[0]
    assert first == json.loads(logs["adaptive"])[0]
    other, _ = _run(_spec(), policy="proposed", seed=8)
    assert json.dumps(other.fault_log) != logs["proposed"]


@pytest.mark.parametrize("policy", ["fifo", "fair", "delay"])
def test_fault_rng_streams_do_not_touch_decision_rng(policy):
    """Faults draw from dedicated per-machine streams, never ``self.rng``:
    an *enabled* config whose every fault process is off reproduces the
    faults-off run exactly — same durations, same decisions, same makespan.
    (Pinned on the non-reconfiguring policies: the fault-aware engine also
    frees a reconfig double-launch's leaked slot, an intentional divergence
    from the frozen engine's leak.)"""
    base_spec = _spec(faults=FaultConfig())
    quiet = FaultConfig(enabled=True, crash_mtbf=0.0, burst_rate=0.0)
    sim_off, res_off = _run(base_spec, policy=policy, seed=3)
    sim_on, res_on = _run(_spec(faults=quiet), policy=policy, seed=3,
                          jobs=_jobs(base_spec))
    assert res_on.makespan == res_off.makespan
    assert {j: r.finish_time for j, r in res_on.jobs.items()} \
        == {j: r.finish_time for j, r in res_off.jobs.items()}
    assert sim_on.fault_log == []


# -- re-replication -----------------------------------------------------------

def test_rereplication_restores_locality_and_counts():
    """With replication=1 a down machine orphans its blocks; after the
    grace window each orphaned pending block gains a replica on a live
    node, and the caller's JobSpec placements are never mutated."""
    spec = _spec(machines=4, vms=2, replication=1,
                 faults=FaultConfig(enabled=True, crash_mtbf=200.0,
                                    crash_mttr=400.0,  # long outages
                                    rereplicate_after=20.0))
    jobs = _jobs(spec, n=8)
    before = [copy.deepcopy(j.block_placement) for j in jobs]
    sim, res = _run(spec, seed=11, jobs=jobs)
    assert sim.fault_stats["crashes"] > 0
    assert sim.fault_stats["blocks_rereplicated"] > 0
    assert [j.block_placement for j in jobs] == before
    assert all(r.finish_time is not None for r in res.jobs.values())


# -- heterogeneity ------------------------------------------------------------

def test_machine_class_pattern_is_weight_expanded_round_robin():
    f = FaultConfig(enabled=True, machine_classes=HETERO)
    names = [f.machine_class(m).name for m in range(8)]
    assert names == ["new", "new", "new", "old"] * 2
    # disabled or homogeneous -> base class everywhere
    assert FaultConfig().machine_class(0).name == "base"
    assert FaultConfig(enabled=True).machine_class(3).speed == 1.0


def test_heterogeneous_fleet_slows_old_class_tasks():
    """Tasks on 'old'-class machines take speed× longer: with CV=0 the
    recorded map durations on old-class VMs are exactly 1.4× the new-class
    ones for the same job."""
    prof = WorkloadProfile(name="t", map_time=10.0, reduce_time=5.0,
                           shuffle_time_per_pair=0.0, time_cv=0.0)
    f = FaultConfig(enabled=True, machine_classes=HETERO)
    spec = ClusterSpec(num_machines=4, vms_per_machine=1, replication=1,
                       faults=f)
    # two blocks per node (= map slots per VM) so every VM runs exactly
    # its own local maps
    job = JobSpec(job_id="j", profile=prof, u_m=8, v_r=1, deadline=1e6,
                  block_placement=[(i // 2,) for i in range(8)])
    sched = PolicySpec("fifo").build(spec)
    sim = ClusterSim(spec, sched, seed=0, straggler_prob=0.0)
    durations = {}
    real = ClusterSim.task_duration

    def record(self, jb, task, local, node=None, now=0.0):
        d = real(self, jb, task, local, node=node, now=now)
        if task.kind == TaskKind.MAP:
            durations[node] = d
        return d
    sim.task_duration = record.__get__(sim)
    sim.run([job])
    # machines 0-2 are 'new', machine 3 is 'old' (weights 3:1); 1 VM each
    assert durations[3] == pytest.approx(1.4 * durations[0])
    assert durations[0] == durations[1] == durations[2]


# -- config plumbing ----------------------------------------------------------

def test_default_faults_omitted_from_spec_dict():
    """Cache-hash stability: a default FaultConfig must leave
    ClusterSpec.to_dict() exactly as it was before the fault layer."""
    d = ClusterSpec(num_machines=4, vms_per_machine=2).to_dict()
    assert "faults" not in d
    d2 = _spec().to_dict()
    assert d2["faults"]["enabled"] is True
    assert ClusterSpec.from_dict(d2) == _spec()
    assert ClusterSpec.from_dict(d) == ClusterSpec(num_machines=4,
                                                   vms_per_machine=2)


def test_fault_config_validation_and_active():
    with pytest.raises(ValueError):
        FaultConfig(crash_mtbf=-1.0)
    with pytest.raises(ValueError):
        FaultConfig(crash_mttr=0.0)
    with pytest.raises(ValueError):
        FaultConfig(burst_slowdown=0.9)
    with pytest.raises(ValueError):
        MachineClass(weight=0)
    assert not FaultConfig().active
    assert not FaultConfig(enabled=True).active          # all processes off
    assert FaultConfig(enabled=True, crash_mtbf=100.0).active
    assert FaultConfig(enabled=True, machine_classes=HETERO).active
    rt = FaultConfig.from_dict(CHURN.to_dict())
    assert rt == CHURN


def test_churn_scenario_preset_shape():
    sc = SCENARIOS["fleet_100x2_churn"]
    assert sc.faults.enabled and sc.faults.crash_mtbf > 0
    assert sc.faults.machine_classes
    assert sc.cluster().faults is sc.faults
    # the non-churn scenarios stay fault-free
    assert not SCENARIOS["fleet_100x2"].faults.enabled


# -- baseline liveness under churn (the delay scheduler must not wedge) ------

@pytest.mark.parametrize("policy", ["delay", "fair", "fifo", "adaptive_ra"])
def test_baselines_drain_under_churn(policy):
    """Every baseline finishes every job under sustained churn: in
    particular the delay scheduler's skip-count logic must not spin on
    offers that can no longer arrive from a down node."""
    spec = _spec(machines=5, vms=2, replication=2)
    sim, res = _run(spec, policy=policy, seed=5, jobs=_jobs(spec, n=10))
    assert sim.fault_stats["crashes"] > 0
    assert not sim.live and not sim.lost_pending
    assert all(r.finish_time is not None for r in res.jobs.values())
    for rj in res.jobs.values():
        assert len(rj.completed_map) == rj.spec.u_m
        assert len(rj.completed_reduce) == rj.spec.v_r


def test_vcpu_conservation_across_crash_restart():
    """Crash + restart of machines holding parked tasks / in-flight plugs
    keeps the cluster vCPU sum exact (reconfiguring policies)."""
    spec = _spec(machines=5, vms=2, replication=2)
    sim, res = _run(spec, policy="adaptive", seed=9, jobs=_jobs(spec, n=10))
    assert sim.fault_stats["crashes"] > 0
    rc = sim.reconfig
    assert rc.total_vcpus == spec.num_nodes * spec.base_map_slots
    assert sum(rc.vcpus) + len(rc.in_flight) == rc.total_vcpus
    assert all(r.finish_time is not None for r in res.jobs.values())
