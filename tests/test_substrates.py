"""Optimizer / data / checkpoint / compression / mapreduce substrates."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                      total_steps=200)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p)
        return adamw_update(cfg, p, g, s)

    for _ in range(150):
        params, state = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, _ = adamw_update(cfg, params, g, state)
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[10]                       # warmup rises
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] == pytest.approx(0.1, rel=1e-3)


# -- data pipeline -----------------------------------------------------------

def test_data_determinism_and_locality():
    from repro.data import DataConfig, ShardedDataset, make_batch_iter
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4,
                     num_shards=8, seed=7)
    ds1 = ShardedDataset(cfg, num_hosts=4)
    ds2 = ShardedDataset(cfg, num_hosts=4)
    b1 = next(make_batch_iter(ds1, hosts=[0]))
    b2 = next(make_batch_iter(ds2, hosts=[0]))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert b1["tokens"].max() < 1000 and b1["tokens"].min() >= 1
    assert ds1.locality_rate() == 1.0             # host 0 reads its own shards


# -- checkpoint ----------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest():
    from repro.checkpoint import (AsyncCheckpointer, latest_step,
                                  restore_checkpoint, save_checkpoint)
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"b": np.ones(4, np.int32)}}
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(d, 3, tree)
        save_checkpoint(d, 7, tree)
        assert latest_step(d) == 7
        out = restore_checkpoint(d, 7, tree)
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["nested"]["b"], tree["nested"]["b"])
        # async path
        ck = AsyncCheckpointer(d)
        ck.save(9, tree)
        ck.wait()
        assert latest_step(d) == 9


def test_checkpoint_incomplete_ignored():
    from repro.checkpoint import latest_step, save_checkpoint
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"x": np.zeros(2)})
        os.makedirs(os.path.join(d, "step_5"))      # torn checkpoint, no manifest
        assert latest_step(d) == 1


# -- compression -----------------------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_quantization_error_bound(seed):
    from repro.parallel.compression import (_blockify, dequantize_int8,
                                            quantize_int8)
    x = jax.random.normal(jax.random.PRNGKey(seed), (533,)) * 3.0
    q, s = quantize_int8(x)
    _, shape, pad = _blockify(x)
    deq = dequantize_int8(q, s, shape, pad)
    err = np.max(np.abs(np.asarray(deq) - np.asarray(x)))
    bound = float(np.max(np.abs(np.asarray(x)))) / 127.0 * 0.5 + 1e-6
    assert err <= bound * 1.01


def test_error_feedback_recovers_mean():
    """With error feedback the time-averaged quantized signal converges to
    the true signal (residual carries the error)."""
    from repro.parallel.compression import _blockify, dequantize_int8, quantize_int8
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.01
    residual = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    steps = 50
    for _ in range(steps):
        xc = x + residual
        q, s = quantize_int8(xc)
        _, shape, pad = _blockify(xc)
        deq = dequantize_int8(q, s, shape, pad)
        residual = xc - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(x),
                               atol=5e-4)


def test_wire_ratio():
    from repro.parallel.compression import wire_bytes_ratio
    assert wire_bytes_ratio() < 0.27


# -- mapreduce engine -------------------------------------------------------------

@pytest.mark.parametrize("workload", ["wordcount", "grep", "sort",
                                      "permutation", "inverted_index"])
def test_mapreduce_matches_numpy_oracle(workload):
    from repro.mapreduce import MRJob, run_mapreduce, WORKLOAD_FNS
    from repro.mapreduce.engine import make_blocks, VOCAB
    job = MRJob(workload=workload, n_blocks=6, block_tokens=512, n_reducers=4)
    blocks = make_blocks(job)
    out = run_mapreduce(job, blocks)
    if workload == "wordcount":
        ref = np.bincount(blocks.reshape(-1), minlength=VOCAB).reshape(4, -1)
        np.testing.assert_array_equal(out, ref)
    elif workload == "grep":
        assert out.sum() == (blocks == 7).sum()
    elif workload == "inverted_index":
        ref = sum((np.bincount(b, minlength=VOCAB) > 0).astype(np.int32)
                  for b in blocks).reshape(4, -1)
        np.testing.assert_array_equal(out, ref)
    else:
        assert out.sum() > 0
        assert out.shape[0] == 4
