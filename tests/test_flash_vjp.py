"""Custom-VJP chunked attention (XLA path): fwd + grads vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention
from repro.models.layers import attention_dense


@pytest.mark.parametrize("pack", [False, True])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_vjp_matches_dense(pack, window):
    B, Hq, Hkv, Sq, D = 2, 4, 2, 260, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, Sq, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, Sq, D), jnp.float32)
    pos = jnp.arange(Sq, dtype=jnp.int32)

    def f_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, pos, pos, True, window,
                                       64, 64, pack) ** 2)

    def f_dense(q, k, v):
        return jnp.sum(attention_dense(q, k, v, causal=True, q_positions=pos,
                                       kv_positions=pos, window=window) ** 2)

    o1 = flash_attention(q, k, v, pos, pos, True, window, 64, 64, pack)
    o2 = attention_dense(q, k, v, causal=True, q_positions=pos,
                         kv_positions=pos, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-5)
    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_packed_equals_unpacked_fwd():
    B, H, S, D = 1, 2, 512, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, D)) for kk in ks)
    pos = jnp.arange(S, dtype=jnp.int32)
    o_u = flash_attention(q, k, v, pos, pos, True, None, 128, 128, False)
    o_p = flash_attention(q, k, v, pos, pos, True, None, 128, 128, True)
    np.testing.assert_allclose(np.asarray(o_u), np.asarray(o_p), atol=1e-5)
