"""The serving layer contract (``repro.simcluster.serving``).

Covers: the SLO fleet drains alongside the batch workload and folds
per-tick/whole-run latency metrics; request streams and harvest decisions
are byte-reproducible per (config, seed, policy); the harvest ledger
reconciles three ways (serving layer == reconfigurator counters == trace
bus); chaos interaction (a crashed machine drops its replicas and sheds
in-window arrivals; churn relief stands harvesting down); oversubscribed
service placements are rejected at construction; and the satellite
latency-percentile utilities in ``experiments.stats``.

Serving-off inertness (wild inactive knobs, quiet-enabled bit-exactness,
the 200-scenario parity sweep) lives in ``tests/test_parity_fuzz.py``;
the cache-hash pins live in ``tests/test_policies.py``.
"""
import dataclasses
import json
import math
import random

import pytest

from repro.core.policies import build_policy
from repro.core.types import (AdaptiveConfig, ClusterSpec, FaultConfig,
                              ServeConfig, ServiceSpec, TraceConfig)
from repro.simcluster.serving import (BORROW_SIGNALS, RETURN_SIGNALS,
                                      ServingLayer)
from repro.simcluster.sim import ClusterSim
from repro.simcluster.workloads import paper_cluster, paper_table2_jobs

SERVICES = (ServiceSpec(name="api", replicas=6, vcpus=2, base_rps=15.0,
                        diurnal_amplitude=0.3, slo_p99_ms=400.0),)


def serve_cluster(services=SERVICES, **serve_over) -> ClusterSpec:
    return dataclasses.replace(
        paper_cluster(),
        serve=ServeConfig(enabled=True, services=services, **serve_over))


def run_serving(spec, policy="harvest", seed=3, tracing=False):
    """(sim, result) for the paper job mix on a serving cluster."""
    if tracing:
        spec = dataclasses.replace(spec, tracing=TraceConfig(enabled=True))
    sched = build_policy(policy, spec)
    sim = ClusterSim(spec, sched, seed=seed)
    return sim, sim.run(paper_table2_jobs(spec, seed=seed))


def _stream_fingerprint(res) -> str:
    """Canonical byte string of everything the serving layer produced."""
    return json.dumps([res.serve_log, res.serve_stats,
                       sorted(res.reconfig_stats.items())], sort_keys=True)


# ---------------------------------------------------------------------------
# the SLO fleet drains and folds metrics
# ---------------------------------------------------------------------------

def test_serving_fleet_drains_and_folds_metrics():
    spec = serve_cluster()
    _, res = run_serving(spec, policy="adaptive")
    assert all(j.finish_time is not None for j in res.jobs.values())
    st = res.serve_stats
    assert st["requests"] > 0
    assert st["p99_ms"] >= st["p50_ms"] > 0.0
    assert 0.0 <= st["violation_rate"] <= 1.0
    svc = st["services"]["api"]
    assert svc["replicas"] == 6 and svc["vcpus"] == 2
    assert svc["requests"] == st["requests"]
    # no harvest component on `adaptive`: cores never move
    assert st["harvest_borrows"] == st["harvest_returns"] == 0
    assert res.reconfig_stats["harvest_borrows"] == 0
    # the per-tick log carries [t, service, replica, served, shed, p50_ms,
    # p99_ms, util_ewma, cores] rows for every replica
    assert res.serve_log and all(len(row) == 9 for row in res.serve_log)
    assert {row[1] for row in res.serve_log} == {"api"}
    assert all(row[8] == 2 for row in res.serve_log)     # cores never move


def test_replica_pinning_reduces_map_capacity():
    spec = serve_cluster()
    sched = build_policy("proposed", spec)
    sim = ClusterSim(spec, sched, seed=0)
    # 6 replicas x 2 vcpus round-robin from machine 0: each pinned VM loses
    # its whole map capacity (base_map_slots == 2), the rest keep theirs
    pinned = {rep.node for rep in sim.serving.replicas}
    assert len(pinned) == 6
    for node in range(spec.num_nodes):
        want = 0 if node in pinned else spec.base_map_slots
        assert sim.map_capacity(node) == want, node


def test_oversubscribed_service_placement_rejected():
    spec = serve_cluster(services=(
        ServiceSpec(name="fat", replicas=1, vcpus=3),))
    sched = build_policy("proposed", spec)
    with pytest.raises(ValueError, match="oversubscribes"):
        ClusterSim(spec, sched, seed=0)


# ---------------------------------------------------------------------------
# byte-reproducibility
# ---------------------------------------------------------------------------

def test_request_streams_and_harvest_byte_reproducible():
    """Identical (config, seed, workload, policy) => identical request log,
    serving stats and harvest decisions, byte for byte."""
    spec = serve_cluster()
    fingerprints = [_stream_fingerprint(run_serving(spec, seed=7)[1])
                    for _ in range(2)]
    assert fingerprints[0] == fingerprints[1]


def test_request_schedule_is_policy_independent():
    """Arrivals come from dedicated per-replica streams — the schedule
    generated through any instant is a pure function of (config, seed),
    whatever the scheduler decided around it."""
    spec = serve_cluster()
    reps = []
    for _ in range(2):
        rep = ServingLayer(spec, seed=5).replicas[0]
        rep.gen_until(500.0)
        reps.append(list(rep.buf))
    assert reps[0] == reps[1]
    # and the decision RNG is untouched: generating arrivals consumes only
    # the replica's own stream
    before = random.Random(5).random()
    assert before == random.Random(5).random()


# ---------------------------------------------------------------------------
# harvest: borrowing, returning, reconciliation
# ---------------------------------------------------------------------------

def test_harvest_borrows_and_ledger_reconciles_three_ways():
    spec = serve_cluster()
    sim, res = run_serving(spec, policy="harvest", tracing=True)
    st = res.serve_stats
    assert st["harvest_borrows"] > 0
    # ledger identity: borrows - returns == cores still lent out
    assert (st["harvest_borrows"] - st["harvest_returns"]
            == st["outstanding_borrows"])
    assert st["outstanding_borrows"] == sim.serving.outstanding_borrows()
    # serving layer == reconfigurator accounting == trace bus
    assert res.reconfig_stats["harvest_borrows"] == st["harvest_borrows"]
    assert res.reconfig_stats["harvest_returns"] == st["harvest_returns"]
    assert res.trace.count("harvest_borrow") == st["harvest_borrows"]
    assert res.trace.count("harvest_return") == st["harvest_returns"]
    # every emitted event names a documented trigger signal
    for rec in res.trace.records():
        if rec["kind"] == "harvest_borrow":
            assert rec["signal"] in BORROW_SIGNALS, rec
        elif rec["kind"] == "harvest_return":
            assert rec["signal"] in RETURN_SIGNALS, rec


def test_harvest_recovers_batch_throughput():
    """On a saturated fleet with an over-provisioned service, lending idle
    service cores to the batch side must not hurt the makespan — and the
    borrowed capacity stays inside the per-request SLO."""
    spec = serve_cluster()
    _, base = run_serving(spec, policy="adaptive")
    _, harv = run_serving(spec, policy="harvest")
    assert harv.serve_stats["harvest_borrows"] > 0
    assert harv.makespan <= base.makespan
    bound = spec.serve.slo_violation_bound
    assert harv.serve_stats["violation_rate"] <= bound


def test_harvest_never_takes_last_core():
    spec = serve_cluster()
    sim, res = run_serving(spec, policy="harvest")
    for rep in sim.serving.replicas:
        assert rep.cores >= 1, (rep.svc.name, rep.index)
    for row in res.serve_log:
        assert row[8] >= 1                       # cores column


def test_telemetry_folds_harvest_and_service_timeline():
    from repro.experiments.metrics import run_record_from_result
    from repro.experiments.telemetry import fold_trace, format_summary
    from repro.simcluster.traces import Trace

    spec = serve_cluster()
    _, res = run_serving(spec, policy="harvest", tracing=True)
    summary = fold_trace(res.trace, res.makespan)
    assert summary.serve_ticks == res.trace.count("serve_tick")
    assert summary.total_harvest_borrows() \
        == res.serve_stats["harvest_borrows"]
    assert summary.total_harvest_returns() \
        == res.serve_stats["harvest_returns"]
    assert "api" in summary.service_timeline
    slo = summary.service_slo["api"]
    assert 0.0 <= slo["residency"] <= 1.0
    assert slo["ticks"] >= slo["ok_ticks"] > 0
    trace = Trace(name="paper", seed=3, jobs=[])
    record = run_record_from_result(
        res, trace=trace, cluster_dict=spec.to_dict(),
        scheduler="harvest", seed=3, wall_time_s=0.0)
    text = format_summary("harvest", record, summary)
    assert "serve:" in text and "SLO residency" in text
    assert "borrows" in text


# ---------------------------------------------------------------------------
# chaos interaction
# ---------------------------------------------------------------------------

class _StubReconfig:
    """Accounting stub: records harvest calls like the real reconfigurator."""

    def __init__(self, machines):
        from collections import deque
        self.aq = [deque() for _ in range(machines)]
        self.calls = []

    def harvest_borrow(self, now, **kw):
        self.calls.append(("borrow", kw["signal"]))

    def harvest_return(self, now, **kw):
        self.calls.append(("return", kw["signal"]))


class _StubSched:
    harvest = True
    total_pending_maps = 40

    def __init__(self, relief=False):
        self.adaptive = AdaptiveConfig(enabled=True, crash_discount=True)
        self._machines_down = 1 if relief else 0


def _hot_layer(relief=False):
    """A harvest-enabled layer with one busy-then-idle replica."""
    spec = ClusterSpec(num_machines=4, vms_per_machine=2, replication=1,
                       serve=ServeConfig(enabled=True, services=(
                           ServiceSpec(name="svc", replicas=1, vcpus=2,
                                       base_rps=2.0, service_time=0.01),)))
    rc = _StubReconfig(spec.num_machines)
    layer = ServingLayer(spec, seed=1, sched=_StubSched(relief=relief),
                         reconfig=rc)
    assert layer.harvest_on
    return layer, rc


def test_harvest_borrow_names_map_backlog_signal():
    layer, rc = _hot_layer()
    for t in range(1, 40):
        layer.tick(float(3 * t))
    assert ("borrow", "map_backlog") in rc.calls
    assert layer.outstanding_borrows() == 1      # never the last core


def test_churn_relief_stands_harvesting_down():
    layer, rc = _hot_layer()
    for t in range(1, 40):
        layer.tick(float(3 * t))
    assert layer.outstanding_borrows() == 1
    # churn hits: the relief probe flips on the next tick
    layer.sched._machines_down = 1
    layer.tick(123.0)
    assert ("return", "churn_relief") in rc.calls
    assert layer.outstanding_borrows() == 0
    # and no new borrow happens while relief holds
    n_borrows = sum(1 for kind, _ in rc.calls if kind == "borrow")
    for t in range(50, 70):
        layer.tick(float(3 * t))
    assert sum(1 for kind, _ in rc.calls if kind == "borrow") == n_borrows


def test_machine_down_sheds_and_returns_cores():
    layer, rc = _hot_layer()
    for t in range(1, 40):
        layer.tick(float(3 * t))
    rep = layer.replicas[0]
    assert rep.machine == 0 and rep.borrowed == 1
    layer.machine_down(0, 120.0)
    assert ("return", "machine_down") in rc.calls
    assert rep.down and rep.borrowed == 0
    served_before = rep.requests
    shed_before = rep.shed
    layer.tick(150.0)
    assert rep.requests == served_before         # down replica serves nothing
    assert rep.shed > shed_before                # arrivals shed instead
    # restart: arrivals inside the down window stay shed, new ones serve
    layer.machine_restarted(0, 150.0)
    assert not rep.down and rep.up_since == 150.0
    layer.tick(300.0)
    assert rep.requests > served_before


def test_crash_drops_service_replicas_end_to_end():
    spec = dataclasses.replace(
        serve_cluster(),
        faults=FaultConfig(enabled=True, crash_mtbf=600.0, crash_mttr=90.0,
                           crash_warmup=30.0))
    sim, res = run_serving(spec, policy="harvest", seed=11, tracing=True)
    assert res.fault_stats["crashes"] > 0
    assert all(j.finish_time is not None for j in res.jobs.values())
    # the run still reconciles under churn
    st = res.serve_stats
    assert (st["harvest_borrows"] - st["harvest_returns"]
            == st["outstanding_borrows"])
    assert res.reconfig_stats["harvest_borrows"] == st["harvest_borrows"]
    # a crash on a pinned machine sheds requests during the outage
    crashed = {m for _, kind, m in res.fault_log if kind == "crash"}
    pinned = {rep.machine for rep in sim.serving.replicas}
    if crashed & pinned:
        assert st["shed"] > 0


# ---------------------------------------------------------------------------
# satellite: experiments.stats latency utilities
# ---------------------------------------------------------------------------

def test_percentile_is_exact_nearest_rank():
    from repro.experiments.stats import percentile
    vals = [5.0, 1.0, 4.0, 2.0, 3.0]
    assert percentile(vals, 0.0) == 1.0
    assert percentile(vals, 50.0) == 3.0
    assert percentile(vals, 99.0) == 5.0
    assert percentile(vals, 100.0) == 5.0
    assert percentile([7.5], 99.0) == 7.5
    # nearest rank returns an actual sample, never an interpolation
    assert percentile([1.0, 2.0], 50.0) == 1.0
    assert percentile([1.0, 2.0], 51.0) == 2.0
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50.0)
    with pytest.raises(ValueError, match="0, 100"):
        percentile(vals, 101.0)


def test_latency_summary_folds_and_zero_cases():
    from repro.experiments.stats import latency_summary
    assert latency_summary([]) == {"n": 0, "mean": 0.0, "p50": 0.0,
                                   "p99": 0.0}
    s = latency_summary([0.01, 0.02, 0.03, 0.4])
    assert s["n"] == 4
    assert math.isclose(s["mean"], 0.115)
    assert s["p50"] == 0.02 and s["p99"] == 0.4


def _serve_record(scheduler: str, seed: int, p99_ms: float,
                  throughput: float = 10.0):
    from repro.experiments.metrics import RunRecord
    return RunRecord(
        trace_name="t", trace_seed=0, cluster={"num_machines": 4},
        scheduler=scheduler, seed=seed, makespan=100.0,
        throughput_jph=throughput, jobs_total=5, jobs_finished=5,
        deadlines_met=5, locality_rate=1.0, speculative_launches=0,
        events_processed=10, wall_time_s=0.1,
        serve={"p99_ms": p99_ms} if p99_ms else {})


def test_compare_serve_p99_pairs_and_signs():
    from repro.experiments.stats import compare_serve_p99
    a = [_serve_record("base", s, p99_ms=200.0 + s) for s in range(4)]
    b = [_serve_record("harvest", s, p99_ms=100.0 + s) for s in range(4)]
    cmpres = compare_serve_p99(a, b, n_boot=200)
    assert cmpres.metric == "serve_p99_ms"
    assert cmpres.n_pairs == 4
    assert cmpres.mean_gain_pct > 0          # lower p99 == positive gain
    assert cmpres.ci_lo_pct > 0
    assert cmpres.win_rate == 1.0
    with pytest.raises(ValueError, match="serving metrics"):
        compare_serve_p99(a, [_serve_record("harvest", s, p99_ms=0.0)
                              for s in range(4)])
