"""GPipe pipeline (shard_map + ppermute) vs unpipelined oracle.

On 1 CPU device the mesh has a single pipe stage — the schedule degenerates
but stays exact; the multi-stage path runs in a subprocess with 4 fake
devices."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.pipeline import pipeline_apply, reference_apply

ROOT = Path(__file__).resolve().parents[1]

_BODY = """
import os
assert "--xla_force_host_platform_device_count=4" in os.environ["XLA_FLAGS"]
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.parallel.pipeline import pipeline_apply, reference_apply

def layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"]) + x

n_layers, d, n_micro, mb = 8, 16, 6, 4
ks = jax.random.split(jax.random.PRNGKey(0), n_layers)
params = {"w": jnp.stack([jax.random.normal(k, (d, d)) * 0.2 for k in ks])}
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
mesh = Mesh(np.array(jax.devices()).reshape(4), ("pipe",))
out = pipeline_apply(layer_fn, params, x, mesh=mesh)
ref = reference_apply(layer_fn, params, x)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
print("PIPELINE OK", err)
"""


def test_single_stage_degenerate():
    def layer_fn(lp, x):
        return jnp.tanh(x @ lp["w"]) + x

    n_layers, d = 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), n_layers)
    params = {"w": jnp.stack([jax.random.normal(k, (d, d)) * 0.2 for k in ks])}
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 2, d))
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1), ("pipe",))
    out = pipeline_apply(layer_fn, params, x, mesh=mesh)
    ref = reference_apply(layer_fn, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.slow
def test_four_stage_pipeline_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run([sys.executable, "-c", _BODY], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-1500:])
    assert "PIPELINE OK" in out.stdout
