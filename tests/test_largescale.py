"""Large-fleet scenario suite + the heartbeat re-arm (deadlock/churn) fixes."""
import random

import pytest

from repro.core.baselines import FIFOScheduler
from repro.core.types import ClusterSpec, JobSpec, WorkloadProfile
from repro.simcluster.largescale import SCENARIOS, run_scenario
from repro.simcluster.sim import ClusterSim
from repro.simcluster.workloads import make_job


PROF = WorkloadProfile(name="t", map_time=10.0, reduce_time=5.0,
                       shuffle_time_per_pair=0.0, time_cv=0.0)


def test_scenario_registry_shapes():
    assert "fleet_100x2_sustained" in SCENARIOS
    for sc in SCENARIOS.values():
        spec = sc.cluster()
        jobs = sc.jobs(spec, seed=1)
        assert len(jobs) == sc.num_jobs
        assert spec.num_machines == sc.num_machines
        # arrival trace is sorted and bursty patterns respect the gap
        submits = [j.submit_time for j in jobs]
        assert submits == sorted(submits)
        # placement stays within the fleet
        for j in jobs[:5]:
            for replicas in j.block_placement:
                assert all(0 <= v < spec.num_nodes for v in replicas)


def test_scenario_jobs_deterministic_per_seed():
    sc = SCENARIOS["smoke_40x2"]
    spec = sc.cluster()
    a = sc.jobs(spec, seed=5)
    b = sc.jobs(spec, seed=5)
    assert [(j.job_id, j.submit_time, j.block_placement) for j in a] \
        == [(j.job_id, j.submit_time, j.block_placement) for j in b]


def test_smoke_scenario_completes_all_jobs():
    res = run_scenario("smoke_40x2", seed=0)
    assert all(j.finish_time is not None for j in res.jobs.values())
    assert res.makespan > 0


def test_job_after_idle_gap_is_scheduled():
    """Seed-engine deadlock regression: heartbeats must re-arm on submit.

    Job B arrives 500 s after job A finished; the seed engine's heartbeat
    chains all died when A completed, so B starved forever."""
    spec = ClusterSpec(num_machines=2, vms_per_machine=2)
    a = JobSpec(job_id="a", profile=PROF, u_m=2, v_r=1, deadline=5_000.0,
                submit_time=0.0, block_placement=[(0,), (1,)])
    b = JobSpec(job_id="b", profile=PROF, u_m=2, v_r=1, deadline=5_000.0,
                submit_time=500.0, block_placement=[(2,), (3,)])
    res = ClusterSim(spec, FIFOScheduler(spec), seed=0).run([a, b],
                                                            until=5_000.0)
    assert res.jobs["a"].finish_time is not None
    assert res.jobs["b"].finish_time is not None, \
        "job submitted after idle gap was never scheduled"
    assert res.jobs["b"].finish_time < 700.0


def test_idle_heartbeats_do_not_churn():
    """With no jobs at all the event loop must terminate immediately rather
    than ticking heartbeats until the horizon (seed churned ~3.3M events)."""
    spec = ClusterSpec(num_machines=2, vms_per_machine=2)
    sim = ClusterSim(spec, FIFOScheduler(spec), seed=0)
    res = sim.run([])
    assert sim.events_processed <= spec.num_nodes  # one dying beat per node
    assert res.makespan == 0.0


def test_heartbeats_stop_after_last_job():
    """After the final job completes, every chain dies instead of ticking to
    the 10M-second horizon."""
    spec = ClusterSpec(num_machines=2, vms_per_machine=2)
    rng = random.Random(0)
    job = make_job("j", "grep", 0.5, 4_000.0, spec, rng)
    sim = ClusterSim(spec, FIFOScheduler(spec), seed=0)
    res = sim.run([job])
    assert res.jobs["j"].finish_time is not None
    # events are bounded by actual work, not the horizon: generous cap
    assert sim.events_processed < 10_000


@pytest.mark.slow
def test_midsize_fleet_all_schedulers():
    for kind in ("proposed", "fair", "fifo"):
        res = run_scenario("smoke_40x2", scheduler=kind, seed=2)
        done = sum(1 for j in res.jobs.values() if j.finish_time is not None)
        assert done == len(res.jobs), kind
