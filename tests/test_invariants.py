"""Scheduler/simulator invariant property tests.

The incremental indices (per-job pending sets, per-node local counters,
global pending-work counters, maintained EDF/Fair orders) are redundant
views of ground-truth state.  These tests run random scenarios through an
instrumented simulator that, at every heartbeat, recomputes each view from
scratch and asserts the incremental copy agrees — so a silently drifting
counter fails loudly instead of skewing scheduling decisions.

Invariants checked on every run:

* **launch-once** — a task never launches twice through the select /
  speculation paths.  The single sanctioned exception, inherited from the
  seed engine and pinned by the parity suite: a *parked* task that also
  launched through the direct local path can be re-launched once by its
  stale reconfiguration plug (``via_reconfig=True``); any other duplicate
  is a bug.  Speculative duplicates are capped at one per task.
* **slot caps** — per-VM running maps never exceed the live vCPU count
  (reconfiguration moves the cap, never below the occupancy), running
  reduces never exceed the configured reduce slots.
* **counter recounts** — ``total_pending_maps``, ``ready_pending_reduces``
  and the per-node ``local_pending_count`` (behind ``has_local_pending``)
  equal a from-scratch recount; the ``map_done`` / ``all_done`` /
  ``has_progress`` flag mirrors equal their defining properties; the
  active-jobs dict holds exactly the unfinished jobs.
* **order maintenance** — the proposed scheduler's incremental EDF list
  equals a full stable re-sort of the active jobs; the Fair scheduler's
  in-select deficit reinsertion keeps its entries list exactly sorted.

Adaptive-mode invariants (AdaptiveConfig enabled) extend the audit:

* **rq_depth recount** — the incremental per-machine offer counter equals
  ``len(rq[machine])``; an injected off-by-one is caught (pinned below).
* **vCPU conservation** — ``total_vcpus`` (incl. in-flight plugs) equals
  the static provisioning at every heartbeat, parks gated or not.
* **pressure-EWMA agreement** — the offer/core-free EWMAs recomputed from
  the full event history match the incrementally maintained values.
* **per-park bound** — every adaptive park's wait bound lies inside
  ``[max_wait_floor, max_wait_ceiling]`` (legacy mode: bound is None).
* **park index** — every ``cancel_parked`` index entry points at a live AQ
  entry of the right machine.
* **map_open_jobs / overdue** — the map-phase-open counter and the lazy
  overdue set equal from-scratch recomputations.

Fault-path invariants (FaultConfig enabled) extend the audit again:

* **no work on down nodes** — a launch targeting a crashed node raises
  immediately; at every heartbeat the down nodes' running lists are empty
  and no live attempt sits on a down node.
* **lost-task ledger** — a crash-killed task is never simultaneously in
  ``lost_pending`` and live, and stays pending (or completed by an
  already-resolved twin) until its re-execution launches.
* **re-execution is not a duplicate** — the launch-once audit treats a
  killed attempt's re-launch as a fresh primary launch, while still
  flagging any other duplicate.
* all the counter recounts above run unchanged on fault runs — crashes,
  re-pends, re-replication and parked-task cancellation must keep every
  incremental view recount-exact, including ``map_open_jobs`` when a
  machine crash kills a job's running maps in one sweep (injected-bug
  pin below).

The final tests inject off-by-ones (pending-map counter, locality counter,
rq_depth, map_open_jobs on mass task loss) and assert the recount catches
them — the detection property itself is pinned.

Decision-trace reconciliation (TraceConfig enabled) closes the loop from
the other side: the bus is a redundant *event-level* view of the same
run, so every launch/finish/kill/park event must reconcile against the
final per-job counters — per-job local/remote/reconfig launch tallies,
map/reduce completion counts, the attempt conservation law
(launches = finishes + kills), the park ledger, and the fault log.
"""
import bisect
import dataclasses
import math
import random

import pytest

from repro.core.baselines import FairScheduler
from repro.core.policies import PolicySpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler, SchedulerBase
from repro.core.types import TaskKind, TraceConfig
from repro.simcluster.sim import ClusterSim
from test_parity_fuzz import build_scenario, _schedulers, fuzz_fault_config

N_RUNS = 12                       # random scenarios per scheduler-agnostic run


class InvariantViolation(AssertionError):
    pass


class InvariantCheckedSim(ClusterSim):
    """ClusterSim that audits the incremental state at every transition."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._primary_seen = set()
        self._reconfig_relaunches = set()
        self._spec_seen = set()
        self._ever_parked = set()
        self.heartbeats_checked = 0
        self.parks_audited = 0
        self.fault_kills = 0
        if self.reconfig is not None:
            self._instrument_reconfig()

    def _instrument_reconfig(self):
        """Wrap the pressure-signal feeds to keep a full event history, so
        the incremental EWMAs can be recomputed from scratch, and audit
        every park's wait bound at park time."""
        rc = self.reconfig
        m = self.spec.num_machines
        self._offer_times = [[] for _ in range(m)]
        self._free_times = [[] for _ in range(m)]

        real_release = rc.release_core

        def release_core(vm, now):
            before = len(rc.rq[rc.spec.machine_of(vm)])
            real_release(vm, now)
            if len(rc.rq[rc.spec.machine_of(vm)]) > before \
                    and rc.adaptive.enabled:
                self._offer_times[rc.spec.machine_of(vm)].append(now)
        rc.release_core = release_core

        real_free = rc.observe_core_free

        def observe_core_free(vm, now):
            real_free(vm, now)
            self._free_times[rc.spec.machine_of(vm)].append(now)
        rc.observe_core_free = observe_core_free

        real_restart = rc.machine_restarted

        def machine_restarted(machine, now):
            # the restart resets every pressure signal (pre-crash samples
            # must not poison the fresh machine) — the from-scratch
            # recomputation starts over from the same point
            real_restart(machine, now)
            self._offer_times[machine].clear()
            self._free_times[machine].clear()
        rc.machine_restarted = machine_restarted

        real_park = rc.park_task

        def park_task(task, target_vm, now, wait_bound=None):
            real_park(task, target_vm, now, wait_bound=wait_bound)
            entry = rc.aq[rc.spec.machine_of(target_vm)][-1]
            a = rc.adaptive
            if a.enabled:
                if entry.wait_bound is None or not (
                        a.max_wait_floor - 1e-12 <= entry.wait_bound
                        <= a.max_wait_ceiling + 1e-12):
                    raise InvariantViolation(
                        f"park bound {entry.wait_bound} outside "
                        f"[{a.max_wait_floor}, {a.max_wait_ceiling}]")
            elif entry.wait_bound is not None:
                raise InvariantViolation(
                    "legacy park carries an adaptive wait bound")
            self.parks_audited += 1
        rc.park_task = park_task

    def _ewma_from_scratch(self, times, cfg):
        # mirrors Reconfigurator._ewma exactly, including the restart-gap
        # cap (and its prev > 0 guard against wedging at zero)
        ewma = None
        for prev, cur in zip(times, times[1:]):
            sample = cur - prev
            if ewma is None:
                ewma = sample
                continue
            if (cfg.ewma_gap_cap > 0.0 and ewma > 0.0
                    and sample > cfg.ewma_gap_cap * ewma):
                sample = cfg.ewma_gap_cap * ewma
            ewma = cfg.ewma_alpha * sample + (1.0 - cfg.ewma_alpha) * ewma
        return ewma

    # -- launch-once + slot caps ------------------------------------------
    def _launch(self, launch, now, speculative=False):
        task = launch.task
        if self.faults is not None and launch.node in self.down_nodes:
            raise InvariantViolation(
                f"launch of {task} on down node {launch.node}")
        if speculative:
            if task in self._spec_seen:
                raise InvariantViolation(f"speculative duplicate: {task}")
            self._spec_seen.add(task)
        elif task in self._primary_seen:
            if not launch.via_reconfig:
                raise InvariantViolation(
                    f"task launched twice outside reconfig: {task}")
            if task not in self._ever_parked:
                raise InvariantViolation(
                    f"reconfig re-launch of a never-parked task: {task}")
            if task in self._reconfig_relaunches:
                raise InvariantViolation(
                    f"task re-launched more than once via reconfig: {task}")
            self._reconfig_relaunches.add(task)
        else:
            self._primary_seen.add(task)
        super()._launch(launch, now, speculative)
        self._check_slot_caps(launch.node)

    def _check_slot_caps(self, node):
        cap = self.map_capacity(node)
        if len(self.map_running[node]) > cap:
            raise InvariantViolation(
                f"node {node}: {len(self.map_running[node])} running maps "
                f"> capacity {cap}")
        if len(self.red_running[node]) > self.spec.base_reduce_slots:
            raise InvariantViolation(
                f"node {node}: {len(self.red_running[node])} running reduces "
                f"> {self.spec.base_reduce_slots} slots")

    # -- fault-path bookkeeping -------------------------------------------
    def _kill_running(self, rt, now):
        was_live = (rt.task, rt.speculative) in self.live
        super()._kill_running(rt, now)
        if not was_live:
            return
        self.fault_kills += 1
        # re-executing a killed attempt is a fresh launch, not a duplicate:
        # forget the dead lineage so the launch-once audit accepts exactly
        # one new primary (and one new speculative copy) for the task
        self._spec_seen.discard(rt.task)
        if not rt.speculative:
            self._primary_seen.discard(rt.task)
            self._reconfig_relaunches.discard(rt.task)

    def _check_fault_state(self):
        for v in sorted(self.down_nodes):
            if self.map_running[v] or self.red_running[v]:
                raise InvariantViolation(
                    f"down node {v} still has running tasks")
        for rt in self.live.values():
            if rt.node in self.down_nodes:
                raise InvariantViolation(
                    f"live attempt {rt.task} on down node {rt.node}")
        for task in self.lost_pending:
            if (task, False) in self.live or (task, True) in self.live:
                raise InvariantViolation(
                    f"lost task {task} is simultaneously live")
            job = self.sched.jobs[task.job_id]
            pend = (job.pending_map if task.kind == TaskKind.MAP
                    else job.pending_reduce)
            done = (job.completed_map if task.kind == TaskKind.MAP
                    else job.completed_reduce)
            if task.index not in pend and task.index not in done:
                raise InvariantViolation(
                    f"lost task {task} neither pending nor completed")

    # -- per-heartbeat recounts -------------------------------------------
    def _heartbeat(self, node, now):
        if self.reconfig is not None:
            # parked set snapshot before expiry/matching can drain it
            self._ever_parked.update(self.sched.parked)
        self._now_checked = now
        self._check_counters()
        self.heartbeats_checked += 1
        super()._heartbeat(node, now)

    def _check_counters(self):
        sched = self.sched
        spec = self.spec
        jobs = sched.jobs.values()
        expect_total = sum(len(j.pending_map) for j in jobs)
        if sched.total_pending_maps != expect_total:
            raise InvariantViolation(
                f"total_pending_maps={sched.total_pending_maps} != "
                f"recount {expect_total}")
        expect_ready = sum(len(j.pending_reduce) for j in jobs if j.map_done)
        if sched.ready_pending_reduces != expect_ready:
            raise InvariantViolation(
                f"ready_pending_reduces={sched.ready_pending_reduces} != "
                f"recount {expect_ready}")
        counts = [0] * spec.num_nodes
        for j in jobs:
            placement = j.spec.block_placement
            for idx in j.pending_map:
                for n in set(placement[idx]):
                    counts[n] += 1
        if sched.local_pending_count != counts:
            diff = [(n, sched.local_pending_count[n], counts[n])
                    for n in range(spec.num_nodes)
                    if sched.local_pending_count[n] != counts[n]]
            raise InvariantViolation(
                f"local_pending_count drift (node, have, want): {diff[:5]}")
        for n in range(spec.num_nodes):
            if sched.has_local_pending(n) != (counts[n] > 0):
                raise InvariantViolation(f"has_local_pending({n}) wrong")
        for jid, j in sched.jobs.items():
            if j.map_done != j.map_finished:
                raise InvariantViolation(f"{jid}: map_done flag drift")
            if j.all_done != j.finished:
                raise InvariantViolation(f"{jid}: all_done flag drift")
            if j.has_progress != j.started:
                raise InvariantViolation(f"{jid}: has_progress flag drift")
            if (jid in sched.active) != (not j.all_done):
                raise InvariantViolation(f"{jid}: active-set membership drift")
        expect_open = sum(1 for j in jobs if not j.map_done)
        if sched.map_open_jobs != expect_open:
            raise InvariantViolation(
                f"map_open_jobs={sched.map_open_jobs} != recount "
                f"{expect_open}")
        if self.faults is not None:
            self._check_fault_state()
        if isinstance(sched, CompletionTimeScheduler):
            expect_edf = sorted((j.absolute_deadline, j.seq, j.spec.job_id)
                                for j in sched.active.values())
            if sched._edf != expect_edf:
                raise InvariantViolation("EDF order != full re-sort")
            if [e[2] for e in sched._edf] != [j.spec.job_id
                                              for j in sched._edf_jobs]:
                raise InvariantViolation("_edf_jobs misaligned with _edf")
        if self.reconfig is not None:
            self._check_reconfig()

    def _check_reconfig(self):
        rc = self.reconfig
        spec = self.spec
        # incremental offer-depth counter vs recount
        for m in range(spec.num_machines):
            if rc.rq_depth[m] != len(rc.rq[m]):
                raise InvariantViolation(
                    f"rq_depth[{m}]={rc.rq_depth[m]} != recount "
                    f"{len(rc.rq[m])}")
        # vCPU conservation: gated parking must never mint or leak cores
        provisioned = spec.num_nodes * spec.base_map_slots
        if rc.total_vcpus != provisioned:
            raise InvariantViolation(
                f"total_vcpus={rc.total_vcpus} != provisioned {provisioned}")
        # cancel index points at live AQ entries on the right machine
        for task, (m, entry) in rc._parked_entry.items():
            if not any(it is entry for it in rc.aq[m]):
                raise InvariantViolation(
                    f"park index maps {task} to a dead AQ entry")
        # pressure EWMAs: incremental == recomputed-from-scratch
        if rc.adaptive.enabled:
            a = rc.adaptive
            for m in range(spec.num_machines):
                for name, times, have in (
                        ("offer", self._offer_times[m], rc.offer_ewma[m]),
                        ("free", self._free_times[m], rc.free_ewma[m])):
                    want = self._ewma_from_scratch(times, a)
                    if (want is None) != (have is None) or (
                            want is not None
                            and not math.isclose(want, have,
                                                 rel_tol=1e-12, abs_tol=0.0)):
                        raise InvariantViolation(
                            f"{name}_ewma[{m}]={have} != recomputed {want}")
        if isinstance(self.sched, CompletionTimeScheduler) \
                and self.sched.adaptive.enabled:
            sched = self.sched
            # the lazy overdue set, once synced to "now", equals a
            # from-scratch scan of the active jobs (heartbeat `now` is the
            # newest time the scheduler has seen)
            now = self._now_checked
            sched._sync_overdue(now)
            expect = {jid for jid, j in sched.active.items()
                      if j.absolute_deadline < now}
            if sched.overdue != expect:
                raise InvariantViolation(
                    f"overdue set {sorted(sched.overdue)} != recount "
                    f"{sorted(expect)}")


def run_checked(scenario_seed: int, scheduler: str = None):
    sc = build_scenario(random.Random(scenario_seed))
    if scheduler is not None:
        sc["scheduler"] = scheduler
    sched, _ = _schedulers(sc)
    sim = InvariantCheckedSim(
        sc["spec"], sched, seed=sc["sim_seed"],
        straggler_prob=sc["straggler_prob"],
        straggler_factor=sc["straggler_factor"],
        speculative=sc["speculative"],
        speculation_threshold=sc["speculation_threshold"])
    result = sim.run(sc["jobs"])
    assert sim.heartbeats_checked > 0
    return sim, result


@pytest.mark.parametrize("scheduler", ["proposed", "fair", "fifo"])
def test_invariants_hold_on_random_runs(scheduler):
    for k in range(N_RUNS):
        run_checked(424200 + k, scheduler)


def test_invariants_hold_under_heavy_stragglers():
    """Speculation churn (duplicates, cancellations, refreshed queue entries)
    must not corrupt the pending counters."""
    sc = build_scenario(random.Random(777))
    sc.update(scheduler="proposed", straggler_prob=0.3, speculative=True,
              speculation_threshold=1.5)
    sched, _ = _schedulers(sc)
    sim = InvariantCheckedSim(sc["spec"], sched, seed=3, straggler_prob=0.3,
                              speculative=True, speculation_threshold=1.5)
    sim.run(sc["jobs"])
    assert sim.heartbeats_checked > 0


def test_fair_incremental_order_matches_resort(monkeypatch):
    """Fair keeps its deficit order by popping the launched job and
    re-inserting with one bisect; wrap insort to pin 'list stays exactly
    sorted' at every reinsertion."""
    calls = {"n": 0}
    real_insort = bisect.insort

    def checked_insort(lst, item, *args, **kwargs):
        real_insort(lst, item, *args, **kwargs)
        if lst != sorted(lst):
            raise InvariantViolation("fair deficit list unsorted after insort")
        calls["n"] += 1

    import repro.core.baselines as baselines
    monkeypatch.setattr(baselines.bisect, "insort", checked_insort)
    for k in range(4):
        sim, result = run_checked(515100 + k, "fair")
        assert all(j.finish_time is not None for j in result.jobs.values())
    assert calls["n"] > 0            # the instrumented path actually ran


def test_injected_pending_counter_bug_is_caught(monkeypatch):
    """Acceptance check: a deliberate off-by-one in the pending-map counter
    must be flagged by the recount — the detection property itself is a
    regression test, not a one-off manual experiment."""
    real_drop = SchedulerBase._drop_pending_map
    state = {"calls": 0}

    def buggy_drop(self, job, idx):
        out = real_drop(self, job, idx)
        state["calls"] += 1
        if out and state["calls"] == 7:
            self.total_pending_maps -= 1          # the injected off-by-one
        return out

    monkeypatch.setattr(SchedulerBase, "_drop_pending_map", buggy_drop)
    with pytest.raises(InvariantViolation, match="total_pending_maps"):
        run_checked(424242, "fair")


def test_injected_local_counter_bug_is_caught(monkeypatch):
    """Same for the per-node locality counters behind has_local_pending."""
    real_drop = SchedulerBase._drop_pending_map
    state = {"calls": 0}

    def buggy_drop(self, job, idx):
        out = real_drop(self, job, idx)
        state["calls"] += 1
        if out and state["calls"] == 3:
            placement = job.spec.block_placement[idx]
            self.local_pending_count[next(iter(placement))] += 1
        return out

    monkeypatch.setattr(SchedulerBase, "_drop_pending_map", buggy_drop)
    with pytest.raises(InvariantViolation, match="local_pending_count"):
        run_checked(424242, "proposed")


# -- adaptive-mode invariants ------------------------------------------------

def test_adaptive_invariants_hold_on_random_runs():
    """The full audit (vCPU conservation, rq_depth recounts, EWMA
    agreement, park-bound clamps, park index, overdue recount) over random
    adaptive-ON scenarios — fuzzed knobs included via build_scenario."""
    parks = 0
    for k in range(N_RUNS):
        sim, result = run_checked(868600 + k, "adaptive")
        parks += sim.parks_audited
        assert all(j.finish_time is not None for j in result.jobs.values())
    assert parks > 0          # the bound audit actually exercised parking


def test_legacy_mode_park_bounds_are_none():
    """Adaptive-off runs park with wait_bound=None (fixed max_wait path) —
    the audit in the instrumented sim raises otherwise."""
    parks = 0
    for k in range(6):
        sim, _ = run_checked(525200 + k, "proposed")
        parks += sim.parks_audited
    assert parks > 0


def test_injected_rq_depth_bug_is_caught(monkeypatch):
    """Acceptance pin: an off-by-one in the incremental RQ-depth counter
    must be flagged by the per-heartbeat recount."""
    real_release = Reconfigurator.release_core
    state = {"calls": 0}

    def buggy_release(self, vm, now):
        before = len(self.rq[self.spec.machine_of(vm)])
        real_release(self, vm, now)
        m = self.spec.machine_of(vm)
        if len(self.rq[m]) > before:
            state["calls"] += 1
            if state["calls"] == 2:
                self.rq_depth[m] += 1          # the injected off-by-one
    monkeypatch.setattr(Reconfigurator, "release_core", buggy_release)
    with pytest.raises(InvariantViolation, match="rq_depth"):
        for k in range(40):                    # scan until a scenario parks
            run_checked(909000 + k, "proposed")
    assert state["calls"] >= 2


# -- fault-path invariants ----------------------------------------------------

FAULT_POLICIES = ("proposed", "adaptive", "adaptive_ra", "delay",
                  "fair", "fifo")


def run_checked_faulty(scenario_seed: int, scheduler: str):
    """A random scenario re-run with crashes/bursts/heterogeneity ON —
    the full per-heartbeat audit plus the fault-state checks."""
    sc = build_scenario(random.Random(scenario_seed))
    sc["spec"] = dataclasses.replace(
        sc["spec"],
        faults=fuzz_fault_config(random.Random(scenario_seed * 31 + 7),
                                 enabled=True))
    sched = PolicySpec(scheduler).build(sc["spec"])
    sim = InvariantCheckedSim(
        sc["spec"], sched, seed=sc["sim_seed"],
        straggler_prob=sc["straggler_prob"],
        straggler_factor=sc["straggler_factor"],
        speculative=sc["speculative"],
        speculation_threshold=sc["speculation_threshold"])
    result = sim.run(sc["jobs"])
    assert sim.heartbeats_checked > 0
    return sim, result


@pytest.mark.parametrize("scheduler", FAULT_POLICIES)
def test_fault_invariants_hold_on_random_runs(scheduler):
    """Node churn must keep every incremental view recount-exact: counters,
    flags, orders, vCPU conservation, plus the down-node / lost-task audits.
    Across the seeds each policy must actually observe kills (the fault
    paths ran) and every job must still finish (re-execution liveness)."""
    kills = 0
    for k in range(6):
        sim, result = run_checked_faulty(626200 + k, scheduler)
        kills += sim.fault_kills
        assert all(j.finish_time is not None for j in result.jobs.values())
        assert not sim.lost_pending and not sim.live
    assert kills > 0


def test_down_node_launch_audit_fires():
    """The no-work-on-down-nodes audit itself: force a node down and the
    next launch attempt on it must raise."""
    from repro.simcluster.sim import Launch
    sc = build_scenario(random.Random(626299))
    sc["spec"] = dataclasses.replace(
        sc["spec"], faults=fuzz_fault_config(random.Random(1), enabled=True))
    sched = PolicySpec("fifo").build(sc["spec"])
    sim = InvariantCheckedSim(sc["spec"], sched, seed=0)
    job = sc["jobs"][0]
    sim.sched.job_added(job, 0.0)
    sim.down_nodes.add(0)
    from repro.core.types import TaskId
    task = TaskId(job_id=job.job_id, kind=TaskKind.MAP, index=0)
    with pytest.raises(InvariantViolation, match="down node"):
        sim._launch(Launch(task, 0, local=True), 0.0)


# -- decision-trace reconciliation --------------------------------------------

def run_traced(scenario_seed: int, scheduler, faults: bool = False):
    """A random scenario with the decision-trace bus ON (and optionally
    churn): returns (sim, result) with ``result.trace`` carrying the bus.
    ``scheduler`` is a policy name or a full :class:`PolicySpec`."""
    sc = build_scenario(random.Random(scenario_seed))
    spec = sc["spec"]
    if faults:
        spec = dataclasses.replace(
            spec, faults=fuzz_fault_config(
                random.Random(scenario_seed * 31 + 7), enabled=True))
    spec = dataclasses.replace(spec, tracing=TraceConfig(enabled=True))
    sched = PolicySpec.parse(scheduler).build(spec)
    sim = ClusterSim(spec, sched, seed=sc["sim_seed"],
                     straggler_prob=sc["straggler_prob"],
                     straggler_factor=sc["straggler_factor"],
                     speculative=sc["speculative"],
                     speculation_threshold=sc["speculation_threshold"])
    return sim, sim.run(sc["jobs"])


def assert_trace_reconciles(sim, res):
    """Every launch/finish/kill/park event on the bus reconciles against
    the final per-job counters and the run-level ledgers."""
    bus = res.trace
    local, remote, reconfig = {}, {}, {}
    fin_maps, fin_reds = {}, {}
    for _, kind, d in bus.events:
        if kind == "launch" and d["tkind"] == "map" and not d["spec"]:
            tally = local if d["local"] else remote
            tally[d["job"]] = tally.get(d["job"], 0) + 1
            if d["via_reconfig"]:
                reconfig[d["job"]] = reconfig.get(d["job"], 0) + 1
        elif kind == "finish":
            tally = fin_maps if d["tkind"] == "map" else fin_reds
            tally[d["job"]] = tally.get(d["job"], 0) + 1
    for jid, job in res.jobs.items():
        if job.local_map_launches != local.get(jid, 0):
            raise InvariantViolation(
                f"{jid}: local_map_launches={job.local_map_launches} != "
                f"{local.get(jid, 0)} local launch events")
        if job.remote_map_launches != remote.get(jid, 0):
            raise InvariantViolation(
                f"{jid}: remote_map_launches={job.remote_map_launches} != "
                f"{remote.get(jid, 0)} remote launch events")
        if job.reconfig_map_launches != reconfig.get(jid, 0):
            raise InvariantViolation(
                f"{jid}: reconfig_map_launches="
                f"{job.reconfig_map_launches} != "
                f"{reconfig.get(jid, 0)} via_reconfig launch events")
        if fin_maps.get(jid, 0) != job.spec.u_m \
                or fin_reds.get(jid, 0) != job.spec.v_r:
            raise InvariantViolation(
                f"{jid}: finish events ({fin_maps.get(jid, 0)} map, "
                f"{fin_reds.get(jid, 0)} reduce) != task counts "
                f"({job.spec.u_m}, {job.spec.v_r})")
    # attempt conservation: every launched attempt finishes or is killed
    if bus.count("launch") != bus.count("finish") + bus.count("kill"):
        raise InvariantViolation(
            f"attempt leak: {bus.count('launch')} launches != "
            f"{bus.count('finish')} finishes + {bus.count('kill')} kills")
    # park ledger: admissions/expiries/matches mirror the reconfig stats
    stats = res.reconfig_stats
    if stats:
        for ev, key in (("park_admit", "parked"), ("park_expired", "expired"),
                        ("reconfig_match", "reconfigurations")):
            if bus.count(ev) != stats[key]:
                raise InvariantViolation(
                    f"{ev} events={bus.count(ev)} != "
                    f"reconfig_stats[{key}]={stats[key]}")
        if bus.count("unpark") != sum(j.reconfig_map_launches
                                      for j in res.jobs.values()):
            raise InvariantViolation("unpark events != reconfig launches")
    # fault events mirror the typed fault log
    for kind in ("crash", "restart", "burst", "rereplicate"):
        logged = sum(1 for e in sim.fault_log if e.kind == kind)
        if bus.count(kind) != logged:
            raise InvariantViolation(
                f"{kind} events={bus.count(kind)} != {logged} in fault_log")
    # harvest ledger: borrow/return events mirror the reconfigurator
    # counters and the serving layer's own accounting
    if stats:
        for ev, key in (("harvest_borrow", "harvest_borrows"),
                        ("harvest_return", "harvest_returns")):
            if bus.count(ev) != stats.get(key, 0):
                raise InvariantViolation(
                    f"{ev} events={bus.count(ev)} != "
                    f"reconfig_stats[{key}]={stats.get(key, 0)}")
    if getattr(sim, "serving", None) is not None:
        st = res.serve_stats
        if (st["harvest_borrows"] - st["harvest_returns"]
                != st["outstanding_borrows"]):
            raise InvariantViolation(
                f"harvest ledger leak: {st['harvest_borrows']} borrows - "
                f"{st['harvest_returns']} returns != "
                f"{st['outstanding_borrows']} outstanding")
        if stats and st["harvest_borrows"] != stats["harvest_borrows"]:
            raise InvariantViolation(
                f"serving layer counted {st['harvest_borrows']} borrows, "
                f"reconfigurator {stats['harvest_borrows']}")


@pytest.mark.parametrize("scheduler", ["proposed", "adaptive", "fair"])
def test_trace_events_reconcile_with_job_counters(scheduler):
    for k in range(N_RUNS):
        sim, res = run_traced(303300 + k, scheduler)
        assert res.trace is not None and res.trace.total > 0
        assert_trace_reconciles(sim, res)


def test_trace_events_reconcile_under_churn():
    """The reconciliation holds through crash kills and re-executions, and
    the churn runs actually crash (the fault half of the audit ran)."""
    crashes = 0
    for k in range(6):
        sim, res = run_traced(626200 + k, "adaptive", faults=True)
        assert_trace_reconciles(sim, res)
        crashes += res.trace.count("crash")
    assert crashes > 0


def test_trace_events_reconcile_across_latch_relief_paths():
    """Both sides of the churn-relief fork keep the ledgers exact.  With
    ``crash_discount`` off (the pre-PR-8 latch) the overload latch trips
    mid-churn and parking suspends behind it; with it on (the default) the
    relief stands the latch down and crash re-pends flow through the
    ``_repend_debt`` settlement instead.  The same scenario seeds run both
    ways, every event ledger must reconcile, and the ablation side must
    actually trip (the audit demonstrably crossed the latched paths)."""
    abl = PolicySpec("adaptive", params={"crash_discount": False})
    abl_trips = on_trips = crashes = 0
    for k in range(6):
        sim, res = run_traced(626300 + k, abl, faults=True)
        assert_trace_reconciles(sim, res)
        abl_trips += res.trace.count("latch_trip")
        crashes += res.trace.count("crash")
        sim, res = run_traced(626300 + k, "adaptive", faults=True)
        assert_trace_reconciles(sim, res)
        on_trips += res.trace.count("latch_trip")
    assert crashes > 0          # the fault half of the audit ran
    assert abl_trips > 0        # measured: 3 trips across these seeds
    assert on_trips == 0        # churn relief stands the latch down


def test_trace_events_reconcile_with_serving_harvest():
    """The harvest half of the audit: borrow/return events on the bus
    mirror the reconfigurator counters and the serving layer's own ledger
    — on a quiet fleet and under churn — and borrowing actually happened
    (the audit demonstrably crossed the harvest paths)."""
    from repro.core.types import ServeConfig, ServiceSpec
    from repro.simcluster.workloads import paper_cluster, paper_table2_jobs
    borrows = 0
    for seed, faults in ((3, False), (11, True)):
        spec = dataclasses.replace(
            paper_cluster(),
            serve=ServeConfig(enabled=True, services=(
                ServiceSpec(name="api", replicas=6, vcpus=2, base_rps=15.0,
                            diurnal_amplitude=0.3, slo_p99_ms=400.0),)),
            tracing=TraceConfig(enabled=True))
        if faults:
            spec = dataclasses.replace(spec, faults=fuzz_fault_config(
                random.Random(808800), enabled=True))
        sched = PolicySpec("harvest").build(spec)
        sim = ClusterSim(spec, sched, seed=seed)
        res = sim.run(paper_table2_jobs(spec, seed=seed))
        assert_trace_reconciles(sim, res)
        borrows += res.trace.count("harvest_borrow")
    assert borrows > 0


def test_injected_map_open_jobs_bug_on_mass_loss_is_caught(monkeypatch):
    """Satellite pin: when a machine crash kills a job's running maps in one
    sweep, ``map_open_jobs`` must *not* change (the phase was open before
    the crash and the re-pended maps keep it open).  Inject the plausible
    off-by-one — treating 'no running maps left' as the phase closing —
    and the per-heartbeat recount must flag it."""
    real_lost = SchedulerBase.task_lost
    state = {"mass_losses": 0}

    def buggy_lost(self, task, node, now):
        real_lost(self, task, node, now)
        job = self.jobs[task.job_id]
        if (task.kind == TaskKind.MAP and not job.running_map
                and not job.map_done and state["mass_losses"] == 0):
            state["mass_losses"] += 1
            self.map_open_jobs -= 1          # the injected misaccounting
    monkeypatch.setattr(SchedulerBase, "task_lost", buggy_lost)
    with pytest.raises(InvariantViolation, match="map_open_jobs"):
        for k in range(40):       # scan until a crash wipes a job's maps
            run_checked_faulty(626200 + k, "proposed")
    assert state["mass_losses"] == 1
