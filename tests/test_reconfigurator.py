"""Algorithm-1 AQ/RQ machinery invariants."""
from repro.core.reconfigurator import Reconfigurator
from repro.core.types import ClusterSpec, TaskId, TaskKind


def _t(i):
    return TaskId("j", TaskKind.MAP, i)


def make():
    spec = ClusterSpec(num_machines=4, vms_per_machine=2, base_map_slots=2,
                       max_vcpus_per_vm=4, min_vcpus_per_vm=1,
                       hotplug_latency=0.5)
    return spec, Reconfigurator(spec, max_wait=10.0)


def test_core_conservation_through_matches():
    spec, rc = make()
    total0 = rc.total_vcpus
    rc.park_task(_t(0), target_vm=0, now=0.0)     # machine 0 hosts vm0, vm1
    rc.release_core(1, now=0.0)                    # sibling offers
    started = rc.match(0.0)
    assert len(started) == 1
    assert rc.total_vcpus == total0                # in-flight counted
    done = rc.complete_plugs(1.0)
    assert len(done) == 1
    assert rc.total_vcpus == total0
    assert rc.vcpus[0] == 3 and rc.vcpus[1] == 1


def test_never_below_min_vcpus():
    spec, rc = make()
    rc.vcpus[1] = 1
    rc.park_task(_t(0), 0, 0.0)
    rc.release_core(1, 0.0)                        # at min: refuse
    assert rc.match(0.0) == []


def test_cross_machine_transfer_impossible():
    spec, rc = make()
    rc.park_task(_t(0), target_vm=0, now=0.0)      # machine 0
    rc.release_core(2, now=0.0)                    # machine 1 donor
    assert rc.match(0.0) == []                     # queues never pair


def test_stale_offer_dropped_by_validator():
    spec, rc = make()
    rc.validator = lambda vm: False                # all offers stale
    rc.park_task(_t(0), 0, 0.0)
    rc.release_core(1, 0.0)
    assert rc.match(0.0) == []
    assert rc.rq_len(0) == 0


def test_expiry_returns_parked_tasks():
    spec, rc = make()
    rc.park_task(_t(0), 0, now=0.0)
    assert rc.expire_stale(5.0) == []
    out = rc.expire_stale(11.0)
    assert [p.task for p in out] == [_t(0)]
    assert rc.stats["expired"] == 1


def test_max_vcpus_cap():
    spec, rc = make()
    rc.vcpus[0] = spec.max_vcpus_per_vm
    rc.park_task(_t(0), 0, 0.0)
    rc.release_core(1, 0.0)
    assert rc.match(0.0) == []                     # target saturated
