"""Algorithm-1 AQ/RQ machinery invariants + the pressure-adaptive policy."""
import dataclasses

import pytest

from repro.core.reconfigurator import Reconfigurator
from repro.core.types import AdaptiveConfig, ClusterSpec, TaskId, TaskKind


def _t(i):
    return TaskId("j", TaskKind.MAP, i)


def make():
    spec = ClusterSpec(num_machines=4, vms_per_machine=2, base_map_slots=2,
                       max_vcpus_per_vm=4, min_vcpus_per_vm=1,
                       hotplug_latency=0.5)
    return spec, Reconfigurator(spec, max_wait=10.0)


def make_adaptive(**over):
    cfg = AdaptiveConfig(enabled=True, **over)
    spec = ClusterSpec(num_machines=4, vms_per_machine=2, base_map_slots=2,
                       max_vcpus_per_vm=4, min_vcpus_per_vm=1,
                       hotplug_latency=0.5, adaptive=cfg)
    return spec, Reconfigurator(spec, max_wait=10.0)


def test_core_conservation_through_matches():
    spec, rc = make()
    total0 = rc.total_vcpus
    rc.park_task(_t(0), target_vm=0, now=0.0)     # machine 0 hosts vm0, vm1
    rc.release_core(1, now=0.0)                    # sibling offers
    started = rc.match(0.0)
    assert len(started) == 1
    assert rc.total_vcpus == total0                # in-flight counted
    done = rc.complete_plugs(1.0)
    assert len(done) == 1
    assert rc.total_vcpus == total0
    assert rc.vcpus[0] == 3 and rc.vcpus[1] == 1


def test_never_below_min_vcpus():
    spec, rc = make()
    rc.vcpus[1] = 1
    rc.park_task(_t(0), 0, 0.0)
    rc.release_core(1, 0.0)                        # at min: refuse
    assert rc.match(0.0) == []


def test_cross_machine_transfer_impossible():
    spec, rc = make()
    rc.park_task(_t(0), target_vm=0, now=0.0)      # machine 0
    rc.release_core(2, now=0.0)                    # machine 1 donor
    assert rc.match(0.0) == []                     # queues never pair


def test_stale_offer_dropped_by_validator():
    spec, rc = make()
    rc.validator = lambda vm: False                # all offers stale
    rc.park_task(_t(0), 0, 0.0)
    rc.release_core(1, 0.0)
    assert rc.match(0.0) == []
    assert rc.rq_len(0) == 0


def test_expiry_returns_parked_tasks():
    spec, rc = make()
    rc.park_task(_t(0), 0, now=0.0)
    assert rc.expire_stale(5.0) == []
    out = rc.expire_stale(11.0)
    assert [p.task for p in out] == [_t(0)]
    assert rc.stats["expired"] == 1


def test_max_vcpus_cap():
    spec, rc = make()
    rc.vcpus[0] = spec.max_vcpus_per_vm
    rc.park_task(_t(0), 0, 0.0)
    rc.release_core(1, 0.0)
    assert rc.match(0.0) == []                     # target saturated


# -- cancel_parked: O(1) index over a populated multi-machine state ----------

def test_cancel_parked_multi_machine():
    spec, rc = make()
    # two entries on machine 0, one on machine 1, one on machine 3
    rc.park_task(_t(0), 0, 0.0)
    rc.park_task(_t(1), 1, 1.0)
    rc.park_task(_t(2), 2, 2.0)
    rc.park_task(_t(3), 7, 3.0)
    assert rc.cancel_parked(_t(1)) is True         # middle of machine 0's AQ
    assert [it.task for it in rc.aq[0]] == [_t(0)]
    assert [it.task for it in rc.aq[1]] == [_t(2)]
    assert [it.task for it in rc.aq[3]] == [_t(3)]
    assert rc.cancel_parked(_t(1)) is False        # already gone
    assert rc.cancel_parked(TaskId("x", TaskKind.MAP, 9)) is False
    # cancelled entries are skipped by expiry; the others still expire
    out = rc.expire_stale(30.0)
    assert sorted(p.task.index for p in out) == [0, 2, 3]
    assert rc.stats["expired"] == 3
    assert all(not q for q in rc.aq)
    assert rc._parked_entry == {}


def test_cancel_parked_entry_not_matched_later():
    spec, rc = make()
    rc.park_task(_t(0), 0, 0.0)
    assert rc.cancel_parked(_t(0)) is True
    rc.release_core(1, 0.0)
    assert rc.match(0.0) == []                     # nothing left to pair


# -- adaptive pressure signals ------------------------------------------------

def test_offer_ewma_tracks_release_intervals():
    spec, rc = make_adaptive(ewma_alpha=0.5)
    rc.release_core(0, 0.0)
    assert rc.offer_ewma[0] is None and rc.last_offer[0] == 0.0
    rc.release_core(1, 4.0)
    assert rc.offer_ewma[0] == 4.0                 # first interval
    rc.release_core(0, 10.0)
    assert rc.offer_ewma[0] == 0.5 * 6.0 + 0.5 * 4.0
    assert rc.last_offer[0] == 10.0
    assert rc.offer_ewma[1] is None                # other machines untouched


def test_observe_core_free_feeds_free_ewma():
    spec, rc = make_adaptive(ewma_alpha=0.25)
    rc.observe_core_free(2, 1.0)                   # machine 1
    rc.observe_core_free(3, 5.0)
    rc.observe_core_free(2, 6.0)
    assert rc.free_ewma[1] == 0.25 * 1.0 + 0.75 * 4.0
    assert rc.free_ewma[0] is None


def test_predicted_core_wait_paths():
    spec, rc = make_adaptive()
    assert rc.predicted_core_wait(0, 0.0) is None          # no signal yet
    rc.observe_core_free(0, 0.0)
    rc.observe_core_free(1, 6.0)
    assert rc.predicted_core_wait(0, 6.0) == 6.0           # free EWMA alone
    rc.park_task(_t(0), 0, 6.0)                            # AQ depth scales it
    assert rc.predicted_core_wait(0, 6.0) == 12.0
    rc.release_core(2, 7.0)                                # live offer on m1
    assert rc.predicted_core_wait(1, 7.0) == spec.hotplug_latency


def test_park_decision_gates_and_bounds():
    spec, rc = make_adaptive(max_wait_floor=2.0, max_wait_ceiling=8.0,
                             fail_streak_limit=2, breakeven_margin=1.0)
    # no signal: park with the fixed max_wait clamped into [floor, ceiling]
    ok, bound = rc.park_decision(0, 0.0, breakeven=30.0)
    assert ok and bound == 8.0                     # max_wait 10 -> ceiling
    # predicted wait beyond the break-even: decline
    rc.observe_core_free(0, 0.0)
    rc.observe_core_free(1, 50.0)                  # free interval 50s
    ok, _ = rc.park_decision(0, 50.0, breakeven=20.0)
    assert not ok and rc.stats["park_declined"] == 1
    # fail streak at the limit: decline regardless of signals
    rc.fail_streak[2] = 2
    ok, _ = rc.park_decision(2, 0.0, breakeven=1e9)
    assert not ok
    # cool-down earns a fresh probe at floor patience
    rc.last_fail[2] = 0.0
    ok, bound = rc.park_decision(2, 100.0, breakeven=1e9)
    assert ok and bound == 2.0 and rc.fail_streak[2] == 0


def test_note_park_outcome_updates_streak_and_ewma():
    spec, rc = make_adaptive(outcome_alpha=0.5, fail_streak_limit=2)
    rc.park_task(_t(0), 0, 0.0)
    rc.note_park_outcome(_t(0), 5.0, won=False)
    assert rc.fail_streak[0] == 1 and rc.last_fail[0] == 5.0
    assert rc.park_outcome_ewma == 0.5             # 0.5*0 + 0.5*1.0
    assert rc.stats["park_losses"] == 1
    # a later win resets the machine and restores full patience
    rc.park_task(_t(1), 1, 6.0)                    # same machine 0
    rc.note_park_outcome(_t(1), 8.0, won=True)
    assert rc.fail_streak[0] == 0 and rc.last_fail[0] is None
    assert rc.park_outcome_ewma == 0.75
    assert rc.stats["park_wins"] == 1
    # outcomes for tasks the reconfigurator never saw are ignored
    rc.note_park_outcome(TaskId("zz", TaskKind.MAP, 0), 9.0, won=False)
    assert rc.stats["park_losses"] == 1


def test_global_win_floor_suspends_parking_with_probes():
    spec, rc = make_adaptive(outcome_alpha=1.0, park_win_floor=0.5,
                             fail_cooldown=10.0, max_wait_floor=3.0)
    rc.park_task(_t(0), 0, 0.0)
    rc.note_park_outcome(_t(0), 1.0, won=False)    # ewma -> 0.0
    assert rc.park_outcome_ewma == 0.0
    ok, bound = rc.park_decision(2, 2.0, breakeven=1e9)   # fresh machine
    assert ok and bound == 3.0                     # first probe, floor bound
    ok, _ = rc.park_decision(2, 5.0, breakeven=1e9)
    assert not ok                                  # within the probe cooldown
    ok, _ = rc.park_decision(2, 20.0, breakeven=1e9)
    assert ok                                      # cooldown elapsed: probe


def test_expire_uses_per_park_bounds_when_adaptive():
    spec, rc = make_adaptive(max_wait_floor=2.0, max_wait_ceiling=40.0)
    rc.park_task(_t(0), 0, 0.0, wait_bound=3.0)
    rc.park_task(_t(1), 2, 0.0, wait_bound=20.0)
    assert rc.expire_stale(2.5) == []
    out = rc.expire_stale(3.5)                     # only the 3s bound passed
    assert [p.task for p in out] == [_t(0)]
    out = rc.expire_stale(21.0)
    assert [p.task for p in out] == [_t(1)]


def test_adaptive_default_bound_clamped():
    spec, rc = make_adaptive(max_wait_floor=2.0, max_wait_ceiling=6.0)
    rc.park_task(_t(0), 0, 0.0)                    # no explicit bound
    entry = rc.aq[0][0]
    assert entry.wait_bound == 6.0                 # max_wait 10 -> ceiling


def test_adaptive_config_validation():
    with pytest.raises(ValueError, match="max_wait_ceiling"):
        AdaptiveConfig(max_wait_floor=10.0, max_wait_ceiling=5.0)
    with pytest.raises(ValueError, match="ewma_alpha"):
        AdaptiveConfig(ewma_alpha=0.0)
    with pytest.raises(ValueError, match="park_win_floor"):
        AdaptiveConfig(park_win_floor=1.5)
    with pytest.raises(ValueError, match="overload entry factors"):
        AdaptiveConfig(overload_active_factor=0.0)
    # serialization round-trips through ClusterSpec
    spec = ClusterSpec(adaptive=AdaptiveConfig(enabled=True,
                                               park_min_width=7.0))
    again = ClusterSpec.from_dict(spec.to_dict())
    assert again == spec and again.adaptive.park_min_width == 7.0
