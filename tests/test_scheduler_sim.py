"""End-to-end simulator + scheduler behaviour (the paper's §5 evaluation)."""
import statistics

import pytest

from repro.core.baselines import FairScheduler, FIFOScheduler
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler
from repro.core.types import ClusterSpec, TaskKind
from repro.simcluster import ClusterSim, paper_job_mix, paper_table2_jobs
from repro.simcluster.workloads import paper_cluster


def _prop(spec):
    s = CompletionTimeScheduler(spec, Reconfigurator(spec, max_wait=30.0))
    s.park_depth = 4
    return s


@pytest.mark.parametrize("make", [
    lambda spec: FairScheduler(spec),
    lambda spec: FIFOScheduler(spec),
    lambda spec: _prop(spec),
], ids=["fair", "fifo", "proposed"])
def test_all_jobs_finish(make):
    spec = paper_cluster()
    sched = make(spec)
    res = ClusterSim(spec, sched, seed=3).run(paper_table2_jobs(spec, seed=3))
    for j in res.jobs.values():
        assert j.finish_time is not None
        assert len(j.completed_map) == j.spec.u_m
        assert len(j.completed_reduce) == j.spec.v_r


def test_no_map_slot_oversubscription():
    spec = paper_cluster()
    sched = _prop(spec)
    sim = ClusterSim(spec, sched, seed=5)
    orig = sim._heartbeat

    def checked(node, now):
        orig(node, now)
        for n in range(spec.num_nodes):
            assert len(sim.map_running[n]) <= sim.map_capacity(n) + len(
                sim.reconfig.in_flight), (n, now)
            assert len(sim.red_running[n]) <= spec.base_reduce_slots

    sim._heartbeat = checked
    sim.run(paper_table2_jobs(spec, seed=5))


def test_core_conservation_end_to_end():
    spec = paper_cluster()
    sched = _prop(spec)
    sim = ClusterSim(spec, sched, seed=7)
    total0 = sched.reconfig.total_vcpus
    sim.run(paper_table2_jobs(spec, seed=7))
    assert sched.reconfig.total_vcpus == total0


def test_proposed_beats_fair_on_locality_and_throughput():
    """The paper's headline: ~12% throughput gain, driven by locality."""
    spec = paper_cluster()
    gains, loc_f, loc_p = [], [], []
    for seed in range(1, 7):
        f = ClusterSim(spec, FairScheduler(spec), seed=seed).run(
            paper_table2_jobs(spec, seed=seed))
        p = ClusterSim(spec, _prop(spec), seed=seed).run(
            paper_table2_jobs(spec, seed=seed))
        gains.append(p.throughput_jobs_per_hour() / f.throughput_jobs_per_hour() - 1)
        loc_f.append(f.locality_rate())
        loc_p.append(p.locality_rate())
    assert statistics.mean(loc_p) > statistics.mean(loc_f) + 0.15
    assert statistics.mean(gains) > 0.02      # positive mean gain


def test_deadlines_met_under_proposed():
    spec = paper_cluster()
    res = ClusterSim(spec, _prop(spec), seed=11).run(
        paper_table2_jobs(spec, seed=11))
    assert res.deadlines_met() >= 4            # at most one straggler miss


def test_reconfigurations_happen():
    spec = paper_cluster()
    res = ClusterSim(spec, _prop(spec), seed=2).run(
        paper_table2_jobs(spec, seed=2))
    assert res.reconfig_stats["reconfigurations"] > 0
    assert res.reconfig_stats["parked"] >= res.reconfig_stats["reconfigurations"]


def test_fifo_respects_submission_order():
    spec = paper_cluster()
    sched = FIFOScheduler(spec)
    jobs = paper_job_mix(spec, sizes_gb=(2, 4), seed=1)
    res = ClusterSim(spec, sched, seed=1, speculative=False).run(jobs)
    firsts = [j for j in res.jobs.values() if j.spec.submit_time == 0.0]
    assert all(j.finish_time is not None for j in firsts)


def test_speculative_execution_bounds_stragglers():
    spec = paper_cluster()
    f_on = ClusterSim(spec, FairScheduler(spec), seed=9, straggler_prob=0.15,
                      speculative=True).run(paper_table2_jobs(spec, seed=9))
    f_off = ClusterSim(spec, FairScheduler(spec), seed=9, straggler_prob=0.15,
                       speculative=False).run(paper_table2_jobs(spec, seed=9))
    assert f_on.speculative_launches > 0
    assert f_on.makespan <= f_off.makespan * 1.05
