"""Experiment harness: sweep caching, paired statistics, and the statistical
reproduction of the paper's §5 claims through the trace-driven path."""
import json
import math

import pytest

from repro.core.types import ClusterSpec
from repro.experiments.metrics import RunRecord
from repro.experiments.paperfig import FULL_SEEDS, QUICK_SEEDS, run_paper
from repro.experiments.runner import (ExperimentSpec, TraceRef,
                                      run_experiment, simulate_cell)
from repro.experiments.stats import (bootstrap_mean_ci,
                                     compare_completion_by_workload,
                                     compare_throughput, paired_bootstrap)
from repro.simcluster.traces import PRESETS, TraceConfig, generate_trace


def _small_spec(seeds=(0, 1), schedulers=("proposed", "fair"), trace_seed=0):
    return ExperimentSpec(
        name="t",
        traces=(TraceRef(preset="mix_small", seed=trace_seed),),
        clusters=(ClusterSpec(num_machines=6, vms_per_machine=2,
                              replication=1),),
        schedulers=schedulers,
        seeds=seeds,
    )


# -- cache behaviour --------------------------------------------------------

def test_rerun_hits_cache_zero_new_sims(tmp_path):
    spec = _small_spec()
    first = run_experiment(spec, tmp_path)
    assert first.simulated == 4 and first.cached == 0
    again = run_experiment(spec, tmp_path)
    assert again.simulated == 0 and again.cached == 4
    assert [r.to_dict() for r in again.records] \
        == [r.to_dict() for r in first.records]


def test_partial_grid_runs_only_missing_cells(tmp_path):
    run_experiment(_small_spec(seeds=(0, 1)), tmp_path)
    grown = run_experiment(_small_spec(seeds=(0, 1, 2)), tmp_path)
    assert grown.simulated == 2          # only the two seed-2 cells
    assert grown.cached == 4
    extra_sched = run_experiment(
        _small_spec(seeds=(0, 1, 2), schedulers=("proposed", "fair", "fifo")),
        tmp_path)
    assert extra_sched.simulated == 3    # only the fifo column
    assert extra_sched.cached == 6


def test_policy_specs_share_cache_with_string_schedulers(tmp_path):
    """The legacy alias: a default PolicySpec hits the cells a bare string
    scheduler wrote (and vice versa), while a parameter override is a new
    cell.  Records carry the canonical policy dict and the spec's label."""
    from repro.core.policies import PolicySpec
    first = run_experiment(_small_spec(seeds=(0,), schedulers=("fair",)),
                           tmp_path)
    assert first.simulated == 1
    as_spec = run_experiment(
        _small_spec(seeds=(0,), schedulers=(PolicySpec("fair"),)), tmp_path)
    assert as_spec.simulated == 0 and as_spec.cached == 1
    (rec,) = as_spec.records
    assert rec.scheduler == "fair"
    assert rec.policy == {"name": "fair", "params": {}}
    assert rec.policy_spec() == PolicySpec("fair")
    tweaked = run_experiment(
        _small_spec(seeds=(0,),
                    schedulers=(PolicySpec("fair", {"locality_delay": 2}),)),
        tmp_path)
    assert tweaked.simulated == 1        # parameter override = new cell
    (trec,) = tweaked.records
    assert trec.scheduler == "fair[locality_delay=2]"
    assert trec.policy == {"name": "fair", "params": {"locality_delay": 2}}


def test_unknown_and_duplicate_policies_rejected():
    with pytest.raises(ValueError, match="unknown"):
        _small_spec(schedulers=("warp_speed",))
    with pytest.raises(ValueError, match="duplicate"):
        from repro.core.policies import PolicySpec
        _small_spec(schedulers=("fair", PolicySpec("fair")))


def test_cache_distinguishes_cluster_and_trace(tmp_path):
    run_experiment(_small_spec(), tmp_path)
    other_cluster = ExperimentSpec(
        name="t",
        traces=(TraceRef(preset="mix_small", seed=0),),
        clusters=(ClusterSpec(num_machines=8, vms_per_machine=2,
                              replication=1),),
        schedulers=("proposed", "fair"), seeds=(0, 1))
    assert run_experiment(other_cluster, tmp_path).simulated == 4
    other_trace = _small_spec(trace_seed=9)
    assert run_experiment(other_trace, tmp_path).simulated == 4


def test_path_trace_cache_invalidates_on_edit(tmp_path):
    trace = generate_trace(PRESETS["mix_small"], seed=0)
    tpath = tmp_path / "trace.jsonl"
    trace.save(tpath)
    spec = ExperimentSpec(
        name="t", traces=(TraceRef(path=str(tpath)),),
        clusters=(ClusterSpec(num_machines=6, vms_per_machine=2,
                              replication=1),),
        schedulers=("fair",), seeds=(0,))
    cache = tmp_path / "cache"
    assert run_experiment(spec, cache).simulated == 1
    assert run_experiment(spec, cache).simulated == 0
    generate_trace(PRESETS["mix_small"], seed=1).save(tpath)   # edit the file
    assert run_experiment(spec, cache).simulated == 1


def test_records_survive_cache_round_trip(tmp_path):
    spec = _small_spec(seeds=(0,), schedulers=("proposed",))
    rec = run_experiment(spec, tmp_path).records[0]
    restored = RunRecord.from_dict(json.loads(json.dumps(rec.to_dict())))
    assert restored.to_dict() == rec.to_dict()
    assert restored.pair_key() == rec.pair_key()
    assert len(restored.jobs) == rec.jobs_total


def test_worker_pool_matches_inline(tmp_path):
    spec = _small_spec()
    inline = run_experiment(spec, tmp_path / "a")
    pooled = run_experiment(spec, tmp_path / "b", workers=2)
    assert pooled.simulated == 4

    def strip_wall(rec):
        d = rec.to_dict()
        d.pop("wall_time_s")            # measured timing, not sim output
        return d

    assert [strip_wall(r) for r in pooled.records] \
        == [strip_wall(r) for r in inline.records]


def test_rows_trace_ref_resolves_and_caches(tmp_path):
    """The rows kind (hand-built mixes, e.g. the Fig.-2 grid) flows through
    the cache like any other trace and re-rolls placement per sim seed."""
    rows = (("sort", 2.0, 400.0, 0.0), ("grep", 1.0, 300.0, 10.0))
    ref = TraceRef(rows=rows, name="mini")
    t0, t1 = ref.resolve(0), ref.resolve(1)
    assert [j.job_id for j in t0.jobs] == ["mini-0000-sort", "mini-0001-grep"]
    assert t0.jobs[0].placement_seed != t1.jobs[0].placement_seed
    assert ref.descriptor()["kind"] == "rows"
    spec = ExperimentSpec(
        name="rows", traces=(ref,),
        clusters=(ClusterSpec(num_machines=4, vms_per_machine=2,
                              replication=1),),
        schedulers=("fair",), seeds=(0, 1))
    assert run_experiment(spec, tmp_path).simulated == 2
    assert run_experiment(spec, tmp_path).simulated == 0
    with pytest.raises(ValueError, match="exactly one of"):
        TraceRef(rows=rows, preset="mix_small")


def test_paired_runs_share_trace(tmp_path):
    """Both schedulers of one seed must see the identical job list."""
    report = run_experiment(_small_spec(seeds=(0,)), tmp_path)
    a, b = report.records
    assert a.pair_key() == b.pair_key()
    assert [j.job_id for j in a.jobs] == [j.job_id for j in b.jobs]
    assert [j.input_gb for j in a.jobs] == [j.input_gb for j in b.jobs]


# -- statistics -------------------------------------------------------------

def test_bootstrap_mean_ci_brackets_mean():
    vals = [1.0, 2.0, 3.0, 4.0, 5.0]
    mean, lo, hi = bootstrap_mean_ci(vals, n_boot=500, seed=1)
    assert mean == 3.0 and lo <= mean <= hi and lo < hi
    m1, l1, h1 = bootstrap_mean_ci(vals, n_boot=500, seed=1)
    assert (m1, l1, h1) == (mean, lo, hi)       # deterministic per seed


def test_paired_bootstrap_directionality():
    a = [100.0] * 6
    b = [110.0] * 6
    up = paired_bootstrap(a, b, higher_is_better=True)
    assert up.mean_gain_pct == pytest.approx(10.0)
    assert up.win_rate == 1.0
    down = paired_bootstrap(a, b, higher_is_better=False)
    assert down.mean_gain_pct == pytest.approx(-10.0)
    assert down.win_rate == 0.0
    with pytest.raises(ValueError):
        paired_bootstrap([1.0], [1.0, 2.0])


def test_paired_bootstrap_degenerate_pairs():
    # A scored zero throughput while B finished: a (capped) win for B
    up = paired_bootstrap([0.0, 100.0], [50.0, 100.0], higher_is_better=True)
    assert up.win_rate == 0.5 and up.mean_gain_pct == pytest.approx(50.0)
    # B left runs unfinished (inf completion time): a loss, not a tie
    down = paired_bootstrap([200.0, 200.0], [math.inf, 200.0],
                            higher_is_better=False)
    assert down.win_rate == 0.0 and down.mean_gain_pct == pytest.approx(-50.0)
    # both sides degenerate: a tie
    tie = paired_bootstrap([math.inf], [math.inf], higher_is_better=False)
    assert tie.mean_gain_pct == 0.0


def test_compare_requires_common_cells(tmp_path):
    report = run_experiment(_small_spec(seeds=(0, 1)), tmp_path)
    by = report.by_scheduler()
    cmp = compare_throughput(by["fair"], by["proposed"])
    assert cmp.n_pairs == 2
    assert math.isfinite(cmp.mean_gain_pct)
    per_w = compare_completion_by_workload(by["fair"], by["proposed"])
    assert per_w and all(c.n_pairs >= 1 for c in per_w.values())
    with pytest.raises(ValueError, match="no common"):
        compare_throughput(by["fair"][:1], by["proposed"][1:])


# -- the paper reproduction -------------------------------------------------

def test_paper_quick_reports_ci(tmp_path):
    report = run_paper(QUICK_SEEDS, cache_dir=tmp_path)
    assert report.throughput.n_pairs == len(QUICK_SEEDS)
    assert report.throughput.ci_lo_pct <= report.throughput.mean_gain_pct \
        <= report.throughput.ci_hi_pct
    assert set(report.per_workload) == {"grep", "wordcount", "sort",
                                        "permutation", "inverted_index"}
    text = report.format()
    assert "95% CI" in text and "weakest-gain workload" in text
    # quick rerun is served from cache
    again = run_paper(QUICK_SEEDS, cache_dir=tmp_path)
    assert again.simulated == 0 and again.cached == 2 * len(QUICK_SEEDS)


def test_paper_full_reproduces_claims(tmp_path):
    """The headline acceptance check: positive throughput gain over Fair
    with a CI excluding zero, and Permutation as the weakest-gain workload
    (Fig. 3 ordering)."""
    report = run_paper(FULL_SEEDS, cache_dir=tmp_path)
    assert report.failures() == []
    assert report.throughput.mean_gain_pct > 0
    assert report.throughput.ci_lo_pct > 0
    assert report.weakest_workload() == "permutation"
    # every workload except permutation gains under the proposed scheduler
    for w, cmp in report.per_workload.items():
        if w != "permutation":
            assert cmp.mean_gain_pct > 0, (w, cmp.mean_gain_pct)


# -- surrogate cache namespace ----------------------------------------------

def test_surrogate_namespace_disjoint_from_event_cache(tmp_path):
    """A surrogate sweep into a warm event cache neither serves from nor
    touches the event engine's cells — the engine-id descriptor key forks
    the hash family, so the two engines coexist in one cache dir."""
    from repro.experiments.surrogate import run_surrogate, surrogate_hash

    spec = _small_spec()
    event = run_experiment(spec, tmp_path)
    assert event.simulated == 4
    before = {p: p.read_bytes() for p in sorted(tmp_path.rglob("*.json"))}
    sur = run_surrogate(spec, tmp_path)
    assert sur.simulated == 4 and sur.cached == 0   # no cross-engine hits
    for path, blob in before.items():
        assert path.read_bytes() == blob            # event cells untouched
    # and back: the event engine still sees its own cells, nothing more
    again = run_experiment(spec, tmp_path)
    assert again.simulated == 0 and again.cached == 4
    for cell in spec.cells():
        assert surrogate_hash(cell) != cell.cache_hash()
