"""Trace generation determinism, JSONL round-trip, replay, and the SWIM
cluster-log importer."""
import json
from pathlib import Path

import pytest

from repro.core.types import ClusterSpec
from repro.simcluster.traces import (PRESETS, SWIM_SIGNATURES, ArrivalConfig,
                                     SizeConfig, Trace, TraceConfig,
                                     TraceImportError, TraceJob,
                                     classify_swim_workload, generate_trace,
                                     import_swim, import_swim_file,
                                     paper_trace, trace_from_rows)
from repro.simcluster.workloads import (WORKLOADS, n_map_tasks,
                                        n_reduce_tasks, paper_cluster)

DATA = Path(__file__).parent / "data"


def test_same_seed_byte_identical():
    cfg = PRESETS["bursty"]
    a = generate_trace(cfg, seed=7).to_jsonl()
    b = generate_trace(cfg, seed=7).to_jsonl()
    assert a == b


def test_different_seed_differs():
    cfg = PRESETS["mix_small"]
    assert (generate_trace(cfg, seed=0).to_jsonl()
            != generate_trace(cfg, seed=1).to_jsonl())


def test_different_config_same_seed_differs():
    a = generate_trace(PRESETS["mix_small"], seed=0)
    b = generate_trace(PRESETS["heavy_tail"], seed=0)
    assert [j.input_gb for j in a.jobs] != [j.input_gb for j in b.jobs[:len(a.jobs)]]


def test_jsonl_round_trip_bit_exact(tmp_path):
    trace = generate_trace(PRESETS["diurnal"], seed=11)
    p1 = tmp_path / "t1.jsonl"
    trace.save(p1)
    loaded = Trace.load(p1)
    p2 = tmp_path / "t2.jsonl"
    loaded.save(p2)
    assert p1.read_bytes() == p2.read_bytes()
    # and the loaded object is semantically identical
    assert loaded.name == trace.name and loaded.seed == trace.seed
    assert loaded.jobs == trace.jobs
    assert loaded.config == trace.config


def test_header_is_versioned_and_validated(tmp_path):
    trace = generate_trace(PRESETS["mix_small"], seed=0)
    header = json.loads(trace.to_jsonl().splitlines()[0])
    assert header["format"] == "repro-trace/v1"
    assert header["num_jobs"] == len(trace.jobs)
    with pytest.raises(ValueError, match="unsupported trace format"):
        Trace.from_jsonl('{"format":"repro-trace/v999","name":"x","seed":0,'
                         '"num_jobs":0,"config":null}\n')
    # truncation is detected
    lines = trace.to_jsonl().splitlines()
    with pytest.raises(ValueError, match="truncated"):
        Trace.from_jsonl("\n".join(lines[:-1]))


def test_arrivals_sorted_and_sized():
    for preset in ("mix", "bursty", "diurnal", "heavy_tail"):
        trace = generate_trace(PRESETS[preset], seed=2)
        times = [j.submit_time for j in trace.jobs]
        assert times == sorted(times)
        assert len(trace.jobs) == PRESETS[preset].num_jobs
        for j in trace.jobs:
            cfg = PRESETS[preset].sizes
            assert cfg.min_gb <= j.input_gb <= cfg.max_gb
            assert j.workload in WORKLOADS
            assert j.deadline > 0


def test_bursts_produce_tight_clusters():
    cfg = TraceConfig(name="b", num_jobs=80,
                      arrival=ArrivalConfig(rate_per_hour=30.0, burst_prob=0.5,
                                            burst_size_mean=6.0,
                                            burst_stagger_s=1.0))
    trace = generate_trace(cfg, seed=4)
    gaps = [b.submit_time - a.submit_time
            for a, b in zip(trace.jobs, trace.jobs[1:])]
    # bursty trace: many tiny gaps next to long exponential gaps
    assert sum(1 for g in gaps if g <= 1.5) > len(gaps) / 4
    assert max(gaps) > 30.0


def test_mix_weights_respected():
    cfg = TraceConfig(name="m", num_jobs=200,
                      mix=(("sort", 1.0), ("grep", 0.0)),
                      arrival=ArrivalConfig(rate_per_hour=600.0))
    trace = generate_trace(cfg, seed=0)
    assert trace.workload_counts() == {"sort": 200}


def test_replay_deterministic_and_shape_aware():
    trace = generate_trace(PRESETS["mix_small"], seed=5)
    spec = ClusterSpec(num_machines=6, vms_per_machine=2, replication=2)
    jobs1 = trace.job_specs(spec)
    jobs2 = trace.job_specs(spec)
    assert [j.block_placement for j in jobs1] == [j.block_placement for j in jobs2]
    for tj, j in zip(trace.jobs, jobs1):
        assert j.u_m == n_map_tasks(tj.input_gb)
        assert j.v_r == n_reduce_tasks(tj.workload, tj.input_gb)
        assert len(j.block_placement) == j.u_m
        for placement in j.block_placement:
            assert len(placement) == min(2, spec.num_nodes)
            assert all(0 <= n < spec.num_nodes for n in placement)
    # a different shape gets placements inside *its* node range
    small = ClusterSpec(num_machines=2, vms_per_machine=1, replication=1)
    for j in trace.job_specs(small):
        assert all(0 <= n < 2 for p in j.block_placement for n in p)


def test_paper_trace_matches_table2():
    trace = paper_trace(seed=3)
    rows = [(j.workload, j.input_gb, j.deadline) for j in trace.jobs]
    assert rows == [("grep", 10.0, 650.0), ("wordcount", 5.0, 520.0),
                    ("sort", 10.0, 500.0), ("permutation", 4.0, 850.0),
                    ("inverted_index", 8.0, 720.0)]
    assert all(j.submit_time == 0.0 for j in trace.jobs)
    # placement re-rolls with the trace seed
    spec = paper_cluster()
    p3 = [j.block_placement for j in paper_trace(3).job_specs(spec)]
    p4 = [j.block_placement for j in paper_trace(4).job_specs(spec)]
    assert p3 != p4
    assert p3 == [j.block_placement for j in paper_trace(3).job_specs(spec)]


def test_trace_from_rows_explicit_submit_times():
    trace = trace_from_rows("custom", [("sort", 2.0, 300.0, 0.0),
                                       ("grep", 1.0, 200.0, 45.5)], seed=0)
    assert [j.submit_time for j in trace.jobs] == [0.0, 45.5]
    assert trace.jobs[1].job_id == "custom-0001-grep"
    # duration is the latest submit even when rows are not time-sorted
    unsorted = trace_from_rows("u", [("grep", 2.0, 600.0, 500.0),
                                     ("sort", 4.0, 500.0, 0.0)], seed=0)
    assert unsorted.duration() == 500.0


def test_arrival_config_validation():
    with pytest.raises(ValueError, match="diurnal_amplitude"):
        ArrivalConfig(diurnal_amplitude=-0.5)
    with pytest.raises(ValueError, match="diurnal_amplitude"):
        ArrivalConfig(diurnal_amplitude=1.5)
    with pytest.raises(ValueError, match="rate_per_hour"):
        ArrivalConfig(rate_per_hour=0.0)
    with pytest.raises(ValueError, match="burst_prob"):
        ArrivalConfig(burst_prob=2.0)


def test_size_distributions():
    logn = SizeConfig(distribution="lognormal", median_gb=2.0, sigma=1.0,
                      min_gb=0.25, max_gb=64.0)
    par = SizeConfig(distribution="pareto", alpha=1.2, min_gb=0.5, max_gb=64.0)
    import random
    rng = random.Random(0)
    ln_draws = [logn.draw(rng) for _ in range(500)]
    pa_draws = [par.draw(rng) for _ in range(500)]
    assert all(0.25 <= x <= 64.0 for x in ln_draws)
    assert all(0.5 <= x <= 64.0 for x in pa_draws)
    # heavy tail: max far above median
    assert max(pa_draws) > 10 * sorted(pa_draws)[len(pa_draws) // 2]
    with pytest.raises(ValueError):
        SizeConfig(distribution="uniform")


# -- SWIM / Facebook-format import ------------------------------------------

def test_swim_golden_file_round_trip(tmp_path):
    """Importing the committed SWIM fixture reproduces the committed golden
    trace byte-for-byte, and the golden itself round-trips bit-exactly."""
    golden = DATA / "swim_small.trace.jsonl"
    trace = import_swim_file(DATA / "swim_small.tsv")
    assert trace.to_jsonl() == golden.read_text()
    # import is deterministic (stable placement seeds, no ambient RNG)
    assert import_swim_file(DATA / "swim_small.tsv").to_jsonl() \
        == trace.to_jsonl()
    loaded = Trace.load(golden)
    out = tmp_path / "again.jsonl"
    loaded.save(out)
    assert out.read_bytes() == golden.read_bytes()


def test_swim_import_normalizes_and_classifies():
    trace = import_swim_file(DATA / "swim_small.tsv")
    assert trace.jobs[0].submit_time == 0.0          # shifted to t=0
    times = [j.submit_time for j in trace.jobs]
    assert times == sorted(times)
    assert set(trace.workload_counts()) == set(WORKLOADS)
    for j in trace.jobs:
        assert 0.125 <= j.input_gb <= 64.0
        assert j.deadline > 0
        assert 0 <= j.placement_seed < (1 << 31)
    # the 64 GB row was clamped to the cap
    assert max(j.input_gb for j in trace.jobs) == 64.0
    # replays against any cluster shape
    spec = ClusterSpec(num_machines=4, vms_per_machine=2, replication=1)
    for job in trace.job_specs(spec):
        assert all(0 <= n < spec.num_nodes
                   for p in job.block_placement for n in p)


def test_swim_classifier_signatures():
    """Each signature's own byte profile maps back to its workload, and the
    classifier is total over degenerate inputs (zero bytes)."""
    for w, (s_ratio, o_ratio) in SWIM_SIGNATURES.items():
        inp = 2e9
        assert classify_swim_workload(inp, inp * s_ratio, inp * o_ratio) == w
    assert classify_swim_workload(0, 0, 0) == "grep"     # all-zero: smallest
    assert classify_swim_workload(1e9, 10e9, 2e9) == "permutation"


def test_swim_malformed_line_errors():
    with pytest.raises(TraceImportError, match="line 2: expected 6"):
        import_swim("j1\t0\t0\t1e9\t1e8\t1e7\nj2\t1\t2\t3\n")
    with pytest.raises(TraceImportError, match="line 1: non-numeric"):
        import_swim("j1\tzero\t0\t1e9\t1e8\t1e7\n")
    with pytest.raises(TraceImportError, match="negative submit"):
        import_swim("j1\t-3\t0\t1e9\t1e8\t1e7\n")
    with pytest.raises(TraceImportError, match="negative byte count"):
        import_swim("j1\t0\t0\t1e9\t-1\t1e7\n")


def test_swim_empty_trace_errors():
    with pytest.raises(TraceImportError, match="empty trace"):
        import_swim("")
    with pytest.raises(TraceImportError, match="empty trace"):
        import_swim("# only comments\n\n   \n")


def test_swim_rejects_trace_jsonl_and_wrong_version_header():
    """Feeding an already-converted trace to the importer gives a targeted
    error, and a version-bumped header still fails loading as a trace."""
    trace = import_swim_file(DATA / "swim_small.tsv")
    with pytest.raises(TraceImportError, match="looks like JSON"):
        import_swim(trace.to_jsonl())
    bad_header = trace.to_jsonl().replace("repro-trace/v1", "repro-trace/v9")
    with pytest.raises(ValueError, match="unsupported trace format"):
        Trace.from_jsonl(bad_header)


def test_swim_import_options():
    text = (DATA / "swim_small.tsv").read_text()
    capped = import_swim(text, name="x", max_jobs=3)
    assert len(capped.jobs) == 3 and capped.config["jobs_in"] == 3
    slacked = import_swim(text, name="x", deadline_slack=4.4)
    base = import_swim(text, name="x")
    assert all(a.deadline > b.deadline
               for a, b in zip(slacked.jobs, base.jobs))
    # options land in the header config, so the cache layer (which hashes
    # file content) distinguishes differently-imported variants
    assert slacked.config["deadline_slack"] == 4.4
    with pytest.raises(TraceImportError, match="cannot read"):
        import_swim_file(DATA / "no_such_file.tsv")


def test_config_validation():
    with pytest.raises(ValueError, match="unknown workload"):
        TraceConfig(mix=(("nosuch", 1.0),))
    with pytest.raises(ValueError, match="num_jobs"):
        TraceConfig(num_jobs=0)
    cfg = TraceConfig.from_dict(PRESETS["bursty"].to_dict())
    assert cfg == PRESETS["bursty"]
