"""Regime atlas: spec construction, report distillation, caching, rendering,
and the adaptive-policy regression pins on the --quick sub-grid.

Full-size atlas cells are exercised by `python -m repro.experiments regimes
--quick` (and the committed EXPERIMENTS.md); here a small preset at the
smallest shape keeps the property checks fast.
"""
import json

import pytest

from repro.core.types import ClusterSpec
from repro.experiments.regimes import (BASE_FABRIC, FABRICS, FULL_FABRICS,
                                       FULL_SHAPES, QUICK_SEEDS, QUICK_SHAPES,
                                       REGIME_PRESETS, SCHEDULERS,
                                       RegimeReport, regime_spec, run_regimes,
                                       scaled_jobs)
from repro.experiments.runner import (ExperimentSpec, TraceRef,
                                      run_experiment)
from repro.experiments.stats import compare_throughput
from repro.simcluster.largescale import FLEET_SHAPES, fleet_shape
from repro.simcluster.traces import PRESETS


def test_atlas_grid_covers_acceptance_floor():
    """≥4 presets x ≥2 shapes x 4 schedulers x ≥8 paired seeds, plus the
    remote-penalty fabric axis."""
    assert len(REGIME_PRESETS) >= 4
    assert len(QUICK_SHAPES) >= 2 and len(FULL_SHAPES) >= 3
    assert set(SCHEDULERS) == {"proposed", "adaptive", "fair", "fifo"}
    from repro.experiments.regimes import FULL_SEEDS
    assert len(FULL_SEEDS) >= 8
    assert set(QUICK_SHAPES) <= set(FULL_SHAPES)   # quick is a sub-grid
    assert set(QUICK_SEEDS) <= set(FULL_SEEDS)
    assert set(FABRICS) == {"1GbE", "10GbE", "40GbE"}
    assert FABRICS[BASE_FABRIC] == 1.0
    assert set(FULL_FABRICS) <= set(FABRICS)
    # fabric scales decrease with link speed
    assert FABRICS["1GbE"] > FABRICS["10GbE"] > FABRICS["40GbE"]


def test_scaled_jobs_tracks_fleet_size():
    assert scaled_jobs("heavy_tail", 20) == PRESETS["heavy_tail"].num_jobs
    assert scaled_jobs("heavy_tail", 100) == 5 * PRESETS["heavy_tail"].num_jobs
    assert scaled_jobs("heavy_tail", 10) == PRESETS["heavy_tail"].num_jobs


def test_fleet_shape_lookup():
    spec = fleet_shape("50x2")
    assert (spec.num_machines, spec.vms_per_machine) == (50, 2)
    assert spec.replication == 1
    with pytest.raises(ValueError, match="unknown fleet shape"):
        fleet_shape("30x7")


def test_regime_spec_pairs_all_schedulers():
    spec = regime_spec("bursty", "20x2", seeds=(0, 1))
    assert spec.schedulers == SCHEDULERS
    assert spec.n_cells() == 1 * 1 * 4 * 2
    # trace seed coupled to sim seed: placements re-roll per replication
    ref = spec.traces[0]
    assert ref.seed is None
    assert ref.config.num_jobs == scaled_jobs("bursty", 20)
    # base fabric leaves the cluster untouched; others scale the penalty
    assert spec.clusters[0].remote_penalty_scale == 1.0
    fab = regime_spec("bursty", "20x2", seeds=(0,), fabric="10GbE")
    assert fab.clusters[0].remote_penalty_scale == FABRICS["10GbE"]


def test_run_regimes_report_and_cache(tmp_path):
    report = run_regimes(presets=("mix_small",), shapes=("20x2",),
                         seeds=(0, 1), cache_dir=tmp_path / "cache",
                         n_boot=200)
    assert report.simulated == 8 and report.cached == 0
    (cell,) = report.cells
    assert cell.verdict() in ("win", "loss", "tie")
    assert cell.adaptive_verdict() in ("win", "loss", "tie")
    assert cell.fabric == BASE_FABRIC
    assert cell.vs_fair.n_pairs == 2 and cell.vs_fifo.n_pairs == 2
    assert cell.adaptive_vs_fair.n_pairs == 2
    assert set(cell.locality) == set(SCHEDULERS)
    assert all(0.0 <= v <= 1.0 for v in cell.deadline_frac.values())
    # rerun: pure cache hit
    again = run_regimes(presets=("mix_small",), shapes=("20x2",),
                        seeds=(0, 1), cache_dir=tmp_path / "cache",
                        n_boot=200)
    assert again.simulated == 0 and again.cached == 8
    assert again.cells[0].to_dict() == cell.to_dict()
    # machine-readable report round-trips through JSON
    out = report.save_json(tmp_path / "report.json")
    loaded = json.loads(out.read_text())
    assert loaded["cells"][0]["throughput_vs_fair"]["ci_lo_pct"] \
        <= loaded["cells"][0]["throughput_vs_fair"]["ci_hi_pct"]
    assert loaded["cells"][0]["verdict"] == cell.verdict()
    assert loaded["cells"][0]["adaptive_verdict"] == cell.adaptive_verdict()
    assert loaded["fabrics"] == ["1GbE"]
    # renders
    assert "adapt" in report.format()
    md = report.to_markdown()
    assert md.startswith("| regime |") and "mix_small" in md
    assert "adaptive vs fair" in md


def test_fabric_axis_extends_grid_and_reuses_cache(tmp_path):
    base = run_regimes(presets=("mix_small",), shapes=("20x2",),
                       seeds=(0,), cache_dir=tmp_path / "cache", n_boot=100)
    assert base.simulated == 4
    fab = run_regimes(presets=("mix_small",), shapes=("20x2",),
                      seeds=(0,), fabrics=("10GbE",),
                      cache_dir=tmp_path / "cache", n_boot=100)
    # base cells reused; only the 10GbE cell simulates
    assert fab.simulated == 4 and fab.cached == 4
    assert [c.fabric for c in fab.cells] == ["1GbE", "10GbE"]
    assert fab.fabrics == ("1GbE", "10GbE")
    assert fab.cell("mix_small", "20x2", "10GbE").fabric == "10GbE"
    with pytest.raises(KeyError):
        fab.cell("mix_small", "20x2", "40GbE")
    with pytest.raises(ValueError, match="unknown fabric"):
        run_regimes(presets=("mix_small",), shapes=("20x2",), seeds=(0,),
                    fabrics=("100GbE",), cache_dir=tmp_path / "cache")


# -- the flipped loss cell must not silently regress -------------------------

@pytest.fixture(scope="module")
def quick_cells(tmp_path_factory):
    """The --quick-compatible diurnal/20x2 cell + the paper closed mix,
    simulated once for both regression pins below."""
    cache = tmp_path_factory.mktemp("atlas-cache")
    diurnal = ExperimentSpec(
        name="pin-diurnal",
        traces=(regime_spec("diurnal", "20x2").traces[0],),
        clusters=(fleet_shape("20x2"),),
        schedulers=("proposed", "adaptive", "fair"),
        seeds=QUICK_SEEDS,
    )
    paper = ExperimentSpec(
        name="pin-paper",
        traces=(TraceRef(preset="paper"),),
        clusters=(ClusterSpec(replication=1),),
        schedulers=("proposed", "adaptive", "fair"),
        seeds=QUICK_SEEDS,
    )
    return (run_experiment(diurnal, cache).by_scheduler(),
            run_experiment(paper, cache).by_scheduler())


def test_adaptive_flips_diurnal_loss_cell(quick_cells):
    """On the diurnal/20x2 loss cell the adaptive policy must beat the
    fixed policy outright and sit within noise of Fair (the committed
    8-seed atlas shows the full flip; this pin is the fast canary)."""
    by, _ = quick_cells
    vs_proposed = compare_throughput(by["proposed"], by["adaptive"])
    vs_fair = compare_throughput(by["fair"], by["adaptive"])
    assert vs_proposed.mean_gain_pct > 5.0     # measured ~+12.6%
    assert vs_fair.mean_gain_pct > -3.0        # measured ~-0.7%


def test_adaptive_preserves_closed_mix_win(quick_cells):
    """On the paper's closed mix the adaptive policy must keep the
    throughput win over Fair (the latch and gates must never fire there)
    and stay within noise of the fixed policy."""
    _, by = quick_cells
    vs_fair = compare_throughput(by["fair"], by["adaptive"])
    vs_proposed = compare_throughput(by["proposed"], by["adaptive"])
    assert vs_fair.mean_gain_pct > 10.0        # measured ~+22.1%
    assert vs_proposed.mean_gain_pct > -30.0   # measured ~-15%, noisy cell
