"""Regime atlas: spec construction, report distillation, caching, rendering.

Full-size atlas cells are exercised by `python -m repro.experiments regimes
--quick` (and the committed EXPERIMENTS.md); here a small preset at the
smallest shape keeps the property checks fast.
"""
import json

import pytest

from repro.experiments.regimes import (FULL_SHAPES, QUICK_SEEDS, QUICK_SHAPES,
                                       REGIME_PRESETS, SCHEDULERS,
                                       RegimeReport, regime_spec, run_regimes,
                                       scaled_jobs)
from repro.simcluster.largescale import FLEET_SHAPES, fleet_shape
from repro.simcluster.traces import PRESETS


def test_atlas_grid_covers_acceptance_floor():
    """≥4 presets x ≥2 shapes x 3 schedulers x ≥8 paired seeds."""
    assert len(REGIME_PRESETS) >= 4
    assert len(QUICK_SHAPES) >= 2 and len(FULL_SHAPES) >= 3
    assert set(SCHEDULERS) == {"proposed", "fair", "fifo"}
    from repro.experiments.regimes import FULL_SEEDS
    assert len(FULL_SEEDS) >= 8
    assert set(QUICK_SHAPES) <= set(FULL_SHAPES)   # quick is a sub-grid
    assert set(QUICK_SEEDS) <= set(FULL_SEEDS)


def test_scaled_jobs_tracks_fleet_size():
    assert scaled_jobs("heavy_tail", 20) == PRESETS["heavy_tail"].num_jobs
    assert scaled_jobs("heavy_tail", 100) == 5 * PRESETS["heavy_tail"].num_jobs
    assert scaled_jobs("heavy_tail", 10) == PRESETS["heavy_tail"].num_jobs


def test_fleet_shape_lookup():
    spec = fleet_shape("50x2")
    assert (spec.num_machines, spec.vms_per_machine) == (50, 2)
    assert spec.replication == 1
    with pytest.raises(ValueError, match="unknown fleet shape"):
        fleet_shape("30x7")


def test_regime_spec_pairs_all_schedulers():
    spec = regime_spec("bursty", "20x2", seeds=(0, 1))
    assert spec.schedulers == SCHEDULERS
    assert spec.n_cells() == 1 * 1 * 3 * 2
    # trace seed coupled to sim seed: placements re-roll per replication
    ref = spec.traces[0]
    assert ref.seed is None
    assert ref.config.num_jobs == scaled_jobs("bursty", 20)


def test_run_regimes_report_and_cache(tmp_path):
    report = run_regimes(presets=("mix_small",), shapes=("20x2",),
                         seeds=(0, 1), cache_dir=tmp_path / "cache",
                         n_boot=200)
    assert report.simulated == 6 and report.cached == 0
    (cell,) = report.cells
    assert cell.verdict() in ("win", "loss", "tie")
    assert cell.vs_fair.n_pairs == 2 and cell.vs_fifo.n_pairs == 2
    assert set(cell.locality) == set(SCHEDULERS)
    assert all(0.0 <= v <= 1.0 for v in cell.deadline_frac.values())
    # rerun: pure cache hit
    again = run_regimes(presets=("mix_small",), shapes=("20x2",),
                        seeds=(0, 1), cache_dir=tmp_path / "cache",
                        n_boot=200)
    assert again.simulated == 0 and again.cached == 6
    assert again.cells[0].to_dict() == cell.to_dict()
    # machine-readable report round-trips through JSON
    out = report.save_json(tmp_path / "report.json")
    loaded = json.loads(out.read_text())
    assert loaded["cells"][0]["throughput_vs_fair"]["ci_lo_pct"] \
        <= loaded["cells"][0]["throughput_vs_fair"]["ci_hi_pct"]
    assert loaded["cells"][0]["verdict"] == cell.verdict()
    # renders
    assert "vs fair" in report.format()
    md = report.to_markdown()
    assert md.startswith("| regime |") and "mix_small" in md
