"""Regime atlas: spec construction, report distillation, caching, rendering,
and the adaptive-policy regression pins on the --quick sub-grid.

Full-size atlas cells are exercised by `python -m repro.experiments regimes
--quick` (and the committed EXPERIMENTS.md); here a small preset at the
smallest shape keeps the property checks fast.
"""
import dataclasses
import json

import pytest

from repro.core.policies import PolicySpec
from repro.core.tracing import LATCH_RELEASE_CAUSES
from repro.core.types import ClusterSpec, TraceConfig
from repro.experiments.regimes import (BASE_FABRIC, FABRICS, FULL_FABRICS,
                                       FULL_SHAPES, QUICK_SEEDS, QUICK_SHAPES,
                                       REGIME_PRESETS, SCHEDULERS,
                                       RegimeReport, regime_spec, run_regimes,
                                       scaled_jobs)
from repro.experiments.runner import (ExperimentSpec, TraceRef,
                                      run_experiment)
from repro.experiments.stats import compare_throughput
from repro.simcluster.largescale import FLEET_SHAPES, fleet_shape
from repro.simcluster.traces import PRESETS


def test_atlas_grid_covers_acceptance_floor():
    """≥5 presets x ≥2 shapes x 6 policy columns x ≥8 paired seeds, plus
    the remote-penalty fabric and HDFS replication axes."""
    assert len(REGIME_PRESETS) >= 5
    assert "saturated" in REGIME_PRESETS        # the §5 closed-mix bridge
    assert len(QUICK_SHAPES) >= 2 and len(FULL_SHAPES) >= 3
    assert set(SCHEDULERS) == {"proposed", "adaptive", "adaptive_ra",
                               "delay", "fair", "fifo"}
    # every atlas column is a default-spec registry preset: its cell
    # descriptor stays the bare name (cache-compatible) and it builds
    from repro.core.policies import PolicySpec
    for s in SCHEDULERS:
        assert PolicySpec(s).cache_descriptor() == s
    from repro.experiments.regimes import (FULL_REPLICATIONS, FULL_SEEDS,
                                           BASE_REPLICATION)
    assert len(FULL_SEEDS) >= 8
    assert set(QUICK_SHAPES) <= set(FULL_SHAPES)   # quick is a sub-grid
    assert set(QUICK_SEEDS) <= set(FULL_SEEDS)
    assert set(FABRICS) == {"1GbE", "10GbE", "40GbE"}
    assert FABRICS[BASE_FABRIC] == 1.0
    assert set(FULL_FABRICS) <= set(FABRICS)
    # fabric scales decrease with link speed
    assert FABRICS["1GbE"] > FABRICS["10GbE"] > FABRICS["40GbE"]
    assert BASE_REPLICATION == 1 and 3 in FULL_REPLICATIONS


def test_scaled_jobs_tracks_fleet_size():
    assert scaled_jobs("heavy_tail", 20) == PRESETS["heavy_tail"].num_jobs
    assert scaled_jobs("heavy_tail", 100) == 5 * PRESETS["heavy_tail"].num_jobs
    assert scaled_jobs("heavy_tail", 10) == PRESETS["heavy_tail"].num_jobs


def test_fleet_shape_lookup():
    spec = fleet_shape("50x2")
    assert (spec.num_machines, spec.vms_per_machine) == (50, 2)
    assert spec.replication == 1
    with pytest.raises(ValueError, match="unknown fleet shape"):
        fleet_shape("30x7")


def test_regime_spec_pairs_all_schedulers():
    spec = regime_spec("bursty", "20x2", seeds=(0, 1))
    assert tuple(s.label for s in spec.schedulers) == SCHEDULERS
    assert spec.n_cells() == 1 * 1 * len(SCHEDULERS) * 2
    # trace seed coupled to sim seed: placements re-roll per replication
    ref = spec.traces[0]
    assert ref.seed is None
    assert ref.config.num_jobs == scaled_jobs("bursty", 20)
    # base fabric leaves the cluster untouched; others scale the penalty
    assert spec.clusters[0].remote_penalty_scale == 1.0
    assert spec.clusters[0].replication == 1
    fab = regime_spec("bursty", "20x2", seeds=(0,), fabric="10GbE")
    assert fab.clusters[0].remote_penalty_scale == FABRICS["10GbE"]
    r3 = regime_spec("bursty", "20x2", seeds=(0,), replication=3)
    assert r3.clusters[0].replication == 3


def test_run_regimes_report_and_cache(tmp_path):
    n = len(SCHEDULERS)
    report = run_regimes(presets=("mix_small",), shapes=("20x2",),
                         seeds=(0, 1), cache_dir=tmp_path / "cache",
                         n_boot=200)
    assert report.simulated == 2 * n and report.cached == 0
    (cell,) = report.cells
    assert cell.verdict() in ("win", "loss", "tie")
    assert cell.adaptive_verdict() in ("win", "loss", "tie")
    assert cell.ra_verdict() in ("win", "loss", "tie")
    assert cell.delay_verdict() in ("win", "loss", "tie")
    assert cell.fabric == BASE_FABRIC
    assert cell.replication == 1
    assert cell.vs_fair.n_pairs == 2 and cell.vs_fifo.n_pairs == 2
    assert cell.adaptive_vs_fair.n_pairs == 2
    assert cell.ra_vs_fair.n_pairs == 2 and cell.delay_vs_fair.n_pairs == 2
    assert set(cell.locality) == set(SCHEDULERS)
    assert all(0.0 <= v <= 1.0 for v in cell.deadline_frac.values())
    # rerun: pure cache hit
    again = run_regimes(presets=("mix_small",), shapes=("20x2",),
                        seeds=(0, 1), cache_dir=tmp_path / "cache",
                        n_boot=200)
    assert again.simulated == 0 and again.cached == 2 * n
    assert again.cells[0].to_dict() == cell.to_dict()
    # machine-readable report round-trips through JSON
    out = report.save_json(tmp_path / "report.json")
    loaded = json.loads(out.read_text())
    assert loaded["cells"][0]["throughput_vs_fair"]["ci_lo_pct"] \
        <= loaded["cells"][0]["throughput_vs_fair"]["ci_hi_pct"]
    assert loaded["cells"][0]["verdict"] == cell.verdict()
    assert loaded["cells"][0]["adaptive_verdict"] == cell.adaptive_verdict()
    assert loaded["cells"][0]["ra_verdict"] == cell.ra_verdict()
    assert loaded["cells"][0]["delay_verdict"] == cell.delay_verdict()
    assert loaded["fabrics"] == ["1GbE"]
    assert loaded["replications"] == [1]
    # renders
    assert "adapt" in report.format()
    md = report.to_markdown()
    assert md.startswith("| regime |") and "mix_small" in md
    assert "adaptive vs fair" in md
    assert "adaptive_ra vs fair" in md and "delay vs fair" in md


def test_fabric_axis_extends_grid_and_reuses_cache(tmp_path):
    n = len(SCHEDULERS)
    base = run_regimes(presets=("mix_small",), shapes=("20x2",),
                       seeds=(0,), cache_dir=tmp_path / "cache", n_boot=100)
    assert base.simulated == n
    fab = run_regimes(presets=("mix_small",), shapes=("20x2",),
                      seeds=(0,), fabrics=("10GbE",),
                      cache_dir=tmp_path / "cache", n_boot=100)
    # base cells reused; only the 10GbE cell simulates
    assert fab.simulated == n and fab.cached == n
    assert [c.fabric for c in fab.cells] == ["1GbE", "10GbE"]
    assert fab.fabrics == ("1GbE", "10GbE")
    assert fab.cell("mix_small", "20x2", "10GbE").fabric == "10GbE"
    with pytest.raises(KeyError):
        fab.cell("mix_small", "20x2", "40GbE")
    with pytest.raises(ValueError, match="unknown fabric"):
        run_regimes(presets=("mix_small",), shapes=("20x2",), seeds=(0,),
                    fabrics=("100GbE",), cache_dir=tmp_path / "cache")


def test_replication_axis_extends_grid_and_reuses_cache(tmp_path):
    n = len(SCHEDULERS)
    base = run_regimes(presets=("mix_small",), shapes=("20x2",),
                       seeds=(0,), cache_dir=tmp_path / "cache", n_boot=100)
    assert base.simulated == n
    r3 = run_regimes(presets=("mix_small",), shapes=("20x2",),
                     seeds=(0,), replications=(3,),
                     cache_dir=tmp_path / "cache", n_boot=100)
    # base cells reused; only the replication-3 cell simulates
    assert r3.simulated == n and r3.cached == n
    assert [c.replication for c in r3.cells] == [1, 3]
    assert r3.replications == (1, 3)
    cell = r3.cell("mix_small", "20x2", replication=3)
    assert cell.replication == 3 and cell.fabric == BASE_FABRIC
    with pytest.raises(KeyError):
        r3.cell("mix_small", "20x2", replication=2)
    with pytest.raises(ValueError, match="replication"):
        run_regimes(presets=("mix_small",), shapes=("20x2",), seeds=(0,),
                    replications=(0,), cache_dir=tmp_path / "cache")


def test_fault_axis_extends_grid_and_reuses_cache(tmp_path):
    n = len(SCHEDULERS)
    base = run_regimes(presets=("mix_small",), shapes=("20x2",),
                       seeds=(0,), cache_dir=tmp_path / "cache", n_boot=100)
    assert base.simulated == n
    churn = run_regimes(presets=("mix_small",), shapes=("20x2",),
                        seeds=(0,), faults=("churn_hi",),
                        cache_dir=tmp_path / "cache", n_boot=100)
    # base cells reused; only the churn cell simulates (fault cells keep
    # their own cache keys: FaultConfig lands in the cluster descriptor)
    assert churn.simulated == n and churn.cached == n
    assert [c.faults for c in churn.cells] == ["none", "churn_hi"]
    assert churn.fault_profiles == ("none", "churn_hi")
    cell = churn.cell("mix_small", "20x2", faults="churn_hi")
    assert cell.faults == "churn_hi" and cell.fabric == BASE_FABRIC
    assert cell.to_dict()["faults"] == "churn_hi"
    with pytest.raises(KeyError):
        churn.cell("mix_small", "20x2", faults="churn_lo")
    with pytest.raises(ValueError, match="unknown fault profile"):
        run_regimes(presets=("mix_small",), shapes=("20x2",), seeds=(0,),
                    faults=("meteor",), cache_dir=tmp_path / "cache")
    # renders with the faults column
    assert "| faults |" in churn.to_markdown()


def test_fault_profiles_cover_acceptance_axes():
    """The atlas faults axis spans a crash-rate axis and a heterogeneity
    axis, and the base profile is the disabled default (so base cells'
    cache hashes are untouched by the fault layer)."""
    from repro.core.types import FaultConfig
    from repro.experiments.regimes import (BASE_FAULTS, FAULT_PROFILES,
                                           FAULT_SHAPES, FULL_FAULTS)
    assert FAULT_PROFILES[BASE_FAULTS] == FaultConfig()
    assert len(FULL_FAULTS) >= 2
    rates = {FAULT_PROFILES[f].crash_mtbf
             for f in FULL_FAULTS if not FAULT_PROFILES[f].machine_classes}
    assert len(rates) >= 2                      # crash-rate axis
    assert any(FAULT_PROFILES[f].machine_classes
               for f in FULL_FAULTS)            # heterogeneity axis
    assert set(FAULT_SHAPES) <= set(FULL_SHAPES)
    spec = regime_spec("mix_small", "20x2", seeds=(0,), faults="churn_hi")
    assert spec.clusters[0].faults == FAULT_PROFILES["churn_hi"]
    assert spec.name.endswith("-churn_hi")


def test_swim_trace_column(tmp_path):
    """The SWIM-derived trace is a first-class atlas column: committed
    fixture, importable, cache-reusing, and rendered like any preset."""
    from repro.experiments.regimes import SWIM_TRACES, scaled_jobs
    from repro.simcluster.traces import Trace
    path = SWIM_TRACES["swim_fb"]
    assert path.exists()
    trace = Trace.load(path)
    assert len(trace.jobs) >= 50
    assert scaled_jobs("swim_fb", 20) == len(trace.jobs)
    n = len(SCHEDULERS)
    report = run_regimes(presets=(), shapes=("20x2",), seeds=(0,),
                         swim=("swim_fb",), cache_dir=tmp_path / "cache",
                         n_boot=100)
    assert report.simulated == n
    assert report.swim == ("swim_fb",)
    cell = report.cell("swim_fb", "20x2")
    assert cell.verdict() in ("win", "loss", "tie")
    assert "swim_fb" in report.to_markdown()
    with pytest.raises(ValueError, match="unknown SWIM trace"):
        run_regimes(presets=(), shapes=("20x2",), seeds=(0,),
                    swim=("swim_yahoo",), cache_dir=tmp_path / "cache")


# -- the flipped loss cell must not silently regress -------------------------

@pytest.fixture(scope="module")
def quick_cells(tmp_path_factory):
    """The --quick-compatible diurnal/20x2 cell, the paper closed mix, and
    the shuffle_heavy/20x2 cell, simulated once for the regression pins
    below."""
    cache = tmp_path_factory.mktemp("atlas-cache")
    diurnal = ExperimentSpec(
        name="pin-diurnal",
        traces=(regime_spec("diurnal", "20x2").traces[0],),
        clusters=(fleet_shape("20x2"),),
        schedulers=("proposed", "adaptive", "fair"),
        seeds=QUICK_SEEDS,
    )
    paper = ExperimentSpec(
        name="pin-paper",
        traces=(TraceRef(preset="paper"),),
        clusters=(ClusterSpec(replication=1),),
        schedulers=("proposed", "adaptive", "fair"),
        seeds=QUICK_SEEDS,
    )
    shuffle = ExperimentSpec(
        name="pin-shuffle",
        traces=(regime_spec("shuffle_heavy", "20x2").traces[0],),
        clusters=(fleet_shape("20x2"),),
        schedulers=("adaptive", "adaptive_ra", "fair"),
        seeds=QUICK_SEEDS,
    )
    return (run_experiment(diurnal, cache).by_scheduler(),
            run_experiment(paper, cache).by_scheduler(),
            run_experiment(shuffle, cache).by_scheduler())


def test_adaptive_flips_diurnal_loss_cell(quick_cells):
    """On the diurnal/20x2 loss cell the adaptive policy must beat the
    fixed policy outright and sit within noise of Fair (the committed
    8-seed atlas shows the full flip; this pin is the fast canary)."""
    by, _, _ = quick_cells
    vs_proposed = compare_throughput(by["proposed"], by["adaptive"])
    vs_fair = compare_throughput(by["fair"], by["adaptive"])
    assert vs_proposed.mean_gain_pct > 5.0     # measured ~+12.6%
    assert vs_fair.mean_gain_pct > -3.0        # measured ~-0.7%


def test_adaptive_preserves_closed_mix_win(quick_cells):
    """On the paper's closed mix the adaptive policy must keep the
    throughput win over Fair (the latch and gates must never fire there)
    and stay within noise of the fixed policy."""
    _, by, _ = quick_cells
    vs_fair = compare_throughput(by["fair"], by["adaptive"])
    vs_proposed = compare_throughput(by["proposed"], by["adaptive"])
    assert vs_fair.mean_gain_pct > 10.0        # measured ~+22.1%
    assert vs_proposed.mean_gain_pct > -30.0   # measured ~-15%, noisy cell


def test_reduce_aware_latch_fixes_shuffle_heavy_cell(quick_cells):
    """The adaptive_ra policy (reduce-aware overload latch + map-open crowd
    bar) must keep the shuffle_heavy/20x2 cell recovered: on the full grid
    it turns plain adaptive's loss vs Fair into a tie (8-seed: adaptive
    -4.4% [-6.5, -2.3] vs adaptive_ra -2.6% [-7.2, +1.5]).  Since the
    win-aware latch (wide-batch exemption + win_release) also unwedged the
    plain latch here, adaptive_ra's edge over it is within noise on this
    2-seed sub-grid — the pin only requires it never falls meaningfully
    behind, and that it still recovers strictly more locality."""
    _, _, by = quick_cells
    vs_adaptive = compare_throughput(by["adaptive"], by["adaptive_ra"])
    vs_fair = compare_throughput(by["fair"], by["adaptive_ra"])
    assert vs_adaptive.mean_gain_pct > -3.0    # measured ~-0.7% (quick),
    #                                            ~+1.6% on the full grid
    assert vs_fair.mean_gain_pct > -8.0        # measured ~-5.2% (quick,
    #                                            noisy; full grid ~-2.6%)
    # the reduce-aware variant must also recover locality, not just trade
    # it away: strictly more data-local launches than the plain latch
    loc_ra = sum(r.locality_rate for r in by["adaptive_ra"])
    loc_ad = sum(r.locality_rate for r in by["adaptive"])
    assert loc_ra >= loc_ad


# -- win-aware latch + churn relief: liveness wall and verdict pins -----------

LIVENESS_SEEDS = tuple(range(12))


def _traced_cell_run(preset, shape, policy, seed, faults):
    """One atlas cell run with the decision-trace bus on: the exact cell
    spec the atlas would sweep, one policy column, one seed."""
    from repro.simcluster.sim import ClusterSim
    spec = regime_spec(preset, shape, seeds=(seed,), faults=faults)
    cluster = dataclasses.replace(
        spec.clusters[0],
        tracing=TraceConfig(enabled=True, launches=True, parks=True,
                            overload=True, faults=True))
    sched = PolicySpec.parse(policy).build(cluster)
    jobs = spec.traces[0].resolve(seed).job_specs(cluster)
    sim = ClusterSim(cluster, sched, seed=seed,
                     straggler_prob=spec.straggler_prob,
                     straggler_factor=spec.straggler_factor,
                     speculative=spec.speculative,
                     speculation_threshold=spec.speculation_threshold)
    return sim.run(jobs)


@pytest.mark.parametrize("policy", SCHEDULERS)
def test_latch_liveness_under_churn(policy):
    """Latch-liveness wall: every atlas policy column, churn_hi, 12 seeds.

    The property is twofold.  (1) Liveness proper: every attempt the run
    launches is resolved (finish or crash kill) — the latch may delay work
    but can never strand it, even on a fleet that crashes every ~60s.
    (2) The churn-relief standdown: on a crash-configured fleet the
    adaptive columns must never trip the overload latch at all (and so
    never deny a park behind it) — the latch misreading churn re-pends as
    an overload surge is exactly how pre-PR-8 adaptive surrendered the
    fixed policy's re-replication wins."""
    adaptive_cols = ("adaptive", "adaptive_ra")
    for seed in LIVENESS_SEEDS:
        res = _traced_cell_run("bursty", "20x2", policy, seed, "churn_hi")
        bus = res.trace
        assert bus.count("crash") > 0, "churn profile did not crash"
        assert bus.count("launch") == bus.count("finish") + bus.count("kill")
        if policy in adaptive_cols:
            assert bus.count("latch_trip") == 0
            assert all(d["gate"] != "overload_latch"
                       for _, k, d in bus.events if k == "park_deny")
        else:                      # no latch machinery in these columns
            assert bus.count("latch_trip") == 0
            assert bus.count("latch_release") == 0


def test_prechurn_latch_trips_but_never_wedges():
    """Ablation column (``crash_discount`` off — the pre-PR-8 churn latch):
    the latch does trip under churn, every release names a registered
    cause, and the win-aware release actually fires somewhere on the wall
    (the wide-batch signal is live, not vacuous).  A run may *end* latched
    — the plain latch's release is observed by the next arrival, and the
    tail drain has none — but liveness still holds: every attempt
    resolves, every job finishes."""
    abl = PolicySpec("adaptive", params={"crash_discount": False})
    trips = 0
    causes = set()
    for seed in LIVENESS_SEEDS:
        res = _traced_cell_run("heavy_tail", "20x2", abl, seed, "churn_hi")
        bus = res.trace
        assert bus.count("launch") == bus.count("finish") + bus.count("kill")
        trips += bus.count("latch_trip")
        causes |= {d["cause"] for _, k, d in bus.events
                   if k == "latch_release"}
    assert trips > 0
    assert causes and causes <= set(LATCH_RELEASE_CAUSES)
    assert "win_release" in causes


@pytest.fixture(scope="module")
def flip_cells(tmp_path_factory):
    """The two verdict cells the win-aware latch flips, at quick scale:
    the saturated closed mix at 50x2 (no faults) and saturated/20x2 under
    churn_hi."""
    cache = tmp_path_factory.mktemp("atlas-cache-pr8")
    sat = dataclasses.replace(
        regime_spec("saturated", "50x2", seeds=QUICK_SEEDS),
        name="pin-sat50", schedulers=("proposed", "adaptive", "fair"))
    churn = dataclasses.replace(
        regime_spec("saturated", "20x2", seeds=QUICK_SEEDS,
                    faults="churn_hi"),
        name="pin-sat20-churn", schedulers=("proposed", "adaptive", "fair"))
    return (run_experiment(sat, cache).by_scheduler(),
            run_experiment(churn, cache).by_scheduler())


def test_saturated_closed_mix_recovers_parking_win(flip_cells):
    """Win-aware latch pin, wide-batch side: on saturated/50x2 the adaptive
    column no longer surrenders the parking win to exact-Fair (+0.0): the
    wide-batch trip exemption and gate standdown recover most of the fixed
    policy's win (committed 8-seed atlas: adaptive +4.8% [+2.8, +7.1] vs
    Fair with proposed at +6.2% — 77% recovery, CI clear of zero)."""
    by, _ = flip_cells
    vs_fair = compare_throughput(by["fair"], by["adaptive"])
    vs_proposed = compare_throughput(by["proposed"], by["adaptive"])
    assert vs_fair.mean_gain_pct > 5.0         # measured ~+8.6% (quick)
    assert vs_proposed.mean_gain_pct > -3.0    # measured ~-1.1% (quick)


def test_churn_relief_never_loses_to_fixed(flip_cells):
    """Churn-relief pin: under churn_hi the relief stands every adaptive
    gate down from t=0 (crash-configured fleet), so the adaptive column
    replays the fixed policy's decisions bit-for-bit and the paired gain
    is exactly zero (the full 8-seed wall: +0.0 [+0.0, +0.0] on all five
    presets).  Any drift from 0.0 here means an adaptive code path fired
    mid-churn that the relief was supposed to stand down."""
    _, by = flip_cells
    vs_proposed = compare_throughput(by["proposed"], by["adaptive"])
    assert vs_proposed.mean_gain_pct == pytest.approx(0.0, abs=1e-9)
    # and standing down must not cost the churn win over Fair
    vs_fair = compare_throughput(by["fair"], by["adaptive"])
    assert vs_fair.mean_gain_pct > -3.0        # measured ~+1.6% (quick)
