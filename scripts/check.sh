#!/usr/bin/env bash
# CI gate: tier-1 tests + quick sim benchmark, failing on perf regression
# against the committed BENCH_sim.json numbers.
#
#   scripts/check.sh            # full gate
#   SKIP_TESTS=1 scripts/check.sh   # bench regression check only
#   BENCH_TOL=0.5 scripts/check.sh  # allowed fractional events/sec drop
#   TRACE_TOL=0.1 scripts/check.sh  # allowed enabled-tracing overhead
#
# The tolerance is deliberately loose (default 0.5: fail only when a
# scenario's indexed events/sec drops below half the committed number) —
# shared CI machines are noisy; the gate catches order-of-magnitude
# regressions like an index silently degrading to a rescan, not ±20% noise.
set -euo pipefail

REPO_ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$REPO_ROOT"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH_TOL="${BENCH_TOL:-0.5}"
TRACE_TOL="${TRACE_TOL:-0.10}"
QUICK_OUT="$(mktemp /tmp/bench_quick.XXXXXX.json)"
trap 'rm -f "$QUICK_OUT"' EXIT

if [[ "${SKIP_TESTS:-0}" != "1" ]]; then
    # The differential fuzz / invariant suites are part of tier-1 with a
    # deterministic bounded budget: a fixed scenario-seed base and example
    # caps (and, when the optional hypothesis extra is installed, the
    # derandomized `tier1` profile registered in tests/test_parity_fuzz.py).
    # Raise REPRO_FUZZ_SCENARIOS / REPRO_ADAPTIVE_FUZZ_SCENARIOS or switch
    # HYPOTHESIS_PROFILE=dev for deeper local exploration.
    export REPRO_FUZZ_SCENARIOS="${REPRO_FUZZ_SCENARIOS:-200}"
    export REPRO_ADAPTIVE_FUZZ_SCENARIOS="${REPRO_ADAPTIVE_FUZZ_SCENARIOS:-60}"
    export REPRO_FAULT_FUZZ_SCENARIOS="${REPRO_FAULT_FUZZ_SCENARIOS:-60}"
    export REPRO_FUZZ_SEED="${REPRO_FUZZ_SEED:-0}"
    export HYPOTHESIS_PROFILE="${HYPOTHESIS_PROFILE:-tier1}"
    echo "== tier-1 tests (fast suite, -m 'not fuzz') =="
    python -m pytest -x -q -m "not fuzz"
    echo "== fuzz profile (legacy parity x ${REPRO_FUZZ_SCENARIOS} + adaptive liveness x ${REPRO_ADAPTIVE_FUZZ_SCENARIOS} + chaos liveness x ${REPRO_FAULT_FUZZ_SCENARIOS}) =="
    python -m pytest -x -q -m fuzz
fi

echo "== policy smoke (every registered policy on a tiny cluster) =="
python -m repro.experiments policies --smoke

echo "== adaptive smoke (win recovery on saturated; no CI-clear churn loss) =="
# One saturated and one churn_hi quick cell through the cached experiment
# runner (first run simulates ~2x6x2 paired seeds, later runs hit the
# cache).  Guards the two failure modes PR 8 fixed: the overload latch
# surrendering the closed-mix parking win back to exact-Fair (+0.0), and
# the adaptive gates losing to the fixed proposed policy under churn with
# a CI excluding zero.
#
# If this gate fails with adaptive-vs-fair exactly +0.0 on a machine that
# last ran sweeps before PR 8: the bugfix deliberately kept the adaptive
# cells' cache keys (see ClusterSpec.to_dict — default-valued knobs are
# omitted so the pinned cell hashes stay), so a pre-PR-8 cache serves
# stale pre-fix results.  Delete the cache dir once and re-run.
ADAPTIVE_SMOKE_CACHE="${ADAPTIVE_SMOKE_CACHE:-.exp-cache}"
python - "$ADAPTIVE_SMOKE_CACHE" <<'PY'
import sys

from repro.experiments.regimes import QUICK_SEEDS, regime_spec
from repro.experiments.runner import run_experiment
from repro.experiments.stats import compare_throughput

cache = sys.argv[1]
failures = []

# saturated/50x2: the closed-mix cell where the latch used to stand the
# adaptive columns down to exact Fair.  Require a real recovered win:
# CI clear of zero vs Fair and at least half the fixed policy's gain.
by = run_experiment(regime_spec("saturated", "50x2", seeds=QUICK_SEEDS),
                    cache).by_scheduler()
ad = compare_throughput(by["fair"], by["adaptive"])
px = compare_throughput(by["fair"], by["proposed"])
print(f"  saturated/50x2: adaptive vs fair {ad.mean_gain_pct:+.1f}% "
      f"[{ad.ci_lo_pct:+.1f}%, {ad.ci_hi_pct:+.1f}%] "
      f"(proposed {px.mean_gain_pct:+.1f}%)")
if ad.mean_gain_pct == 0.0:
    failures.append("saturated/50x2: adaptive surrendered to exact Fair (+0.0)")
elif not (ad.ci_lo_pct > 0.0 and ad.mean_gain_pct >= 0.5 * px.mean_gain_pct):
    failures.append(
        f"saturated/50x2: adaptive win {ad.mean_gain_pct:+.1f}% "
        f"[{ad.ci_lo_pct:+.1f}%, ...] does not recover half of the fixed "
        f"policy's {px.mean_gain_pct:+.1f}%")

# churn_hi/20x2: under crash churn the relief gates must never make the
# adaptive column lose to the fixed policy with a CI excluding zero.
by = run_experiment(regime_spec("saturated", "20x2", seeds=QUICK_SEEDS,
                                faults="churn_hi"),
                    cache).by_scheduler()
vp = compare_throughput(by["proposed"], by["adaptive"])
print(f"  saturated/20x2/churn_hi: adaptive vs proposed "
      f"{vp.mean_gain_pct:+.1f}% [{vp.ci_lo_pct:+.1f}%, {vp.ci_hi_pct:+.1f}%]")
if vp.ci_hi_pct < 0.0:
    failures.append(
        f"saturated/20x2/churn_hi: adaptive loses to fixed with CI "
        f"excluding zero [{vp.ci_lo_pct:+.1f}%, {vp.ci_hi_pct:+.1f}%]")

if failures:
    print("\nFAIL:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print("  adaptive smoke passed")
PY

echo "== fault-injection smoke (churn fleet drains; schedule reproducible) =="
python - <<'PY'
from repro.simcluster.largescale import run_scenario

res = run_scenario("fleet_100x2_churn", scheduler="proposed", seed=0)
assert res.fault_stats["crashes"] > 0, res.fault_stats
unfinished = [j for j, r in res.jobs.items() if r.finish_time is None]
assert not unfinished, f"jobs never finished under churn: {unfinished[:5]}"
again = run_scenario("fleet_100x2_churn", scheduler="proposed", seed=0)
assert again.fault_log == res.fault_log, "fault schedule not reproducible"
print(f"  crashes={res.fault_stats['crashes']} "
      f"lost={res.fault_stats['tasks_lost']} "
      f"reexecuted={res.fault_stats['tasks_reexecuted']} "
      f"bursts={res.fault_stats['bursts']} — all "
      f"{len(res.jobs)} jobs finished; log byte-reproducible")
PY

echo "== serving smoke (SLO fleet drains; request log reproducible; harvest reconciles) =="
python - <<'PY'
import json

from repro.simcluster.largescale import SCENARIOS, run_scenario

res = run_scenario("fleet_100x2_serving", scheduler="harvest", seed=0,
                   tracing=True)
unfinished = [j for j, r in res.jobs.items() if r.finish_time is None]
assert not unfinished, f"batch jobs never finished: {unfinished[:5]}"
st = res.serve_stats
assert st["requests"] > 0, "service fleet received no requests"
bound = SCENARIOS["fleet_100x2_serving"].serve.slo_violation_bound
assert st["violation_rate"] <= bound, (
    f"SLO violation rate {st['violation_rate']:.4f} > bound {bound}")
# harvest events on the trace bus reconcile with the reconfigurator
# counters and the serving layer's own ledger
assert res.trace.count("harvest_borrow") == st["harvest_borrows"] \
    == res.reconfig_stats["harvest_borrows"], "borrow ledgers disagree"
assert res.trace.count("harvest_return") == st["harvest_returns"] \
    == res.reconfig_stats["harvest_returns"], "return ledgers disagree"
assert st["harvest_borrows"] - st["harvest_returns"] \
    == st["outstanding_borrows"], "harvest ledger leak"
# request log byte-reproducible across two identical runs
again = run_scenario("fleet_100x2_serving", scheduler="harvest", seed=0,
                     tracing=True)
assert json.dumps(again.serve_log) == json.dumps(res.serve_log), \
    "serve request log not byte-reproducible"
assert again.serve_stats == st, "serving stats not reproducible"
print(f"  requests={st['requests']} shed={st['shed']} "
      f"p99={st['p99_ms']:.0f}ms viol_rate={st['violation_rate']:.4f} "
      f"(bound {bound}); harvest {st['harvest_borrows']} borrows / "
      f"{st['harvest_returns']} returns — ledgers reconcile, "
      f"log byte-reproducible")
PY

echo "== trace smoke (traced churn run byte-reproducible; explain exits 0) =="
python - <<'PY'
from repro.simcluster.largescale import run_scenario

plain = run_scenario("fleet_100x2_churn", scheduler="proposed", seed=0)
traced = run_scenario("fleet_100x2_churn", scheduler="proposed", seed=0,
                      tracing=True)
assert traced.makespan == plain.makespan, \
    "tracing changed the schedule under churn"
assert traced.fault_log == plain.fault_log, \
    "tracing changed the fault schedule"
again = run_scenario("fleet_100x2_churn", scheduler="proposed", seed=0,
                     tracing=True)
assert again.trace.to_jsonl() == traced.trace.to_jsonl(), \
    "trace not byte-reproducible across identical runs"
print(f"  {traced.trace.total} events, JSONL byte-identical across runs, "
      f"makespan/fault_log unchanged vs untraced")
PY
EXPLAIN_CACHE="$(mktemp -d /tmp/explain_cache.XXXXXX)"
python -m repro.experiments explain saturated 20x2 \
    --cache "$EXPLAIN_CACHE" --no-store > /dev/null
rm -rf "$EXPLAIN_CACHE"
echo "  explain verb exited 0"

echo "== surrogate smoke (calibrated sweep + differential gate on heavy_tail) =="
# The surrogate verb sweeps the allowlisted 20x2 heavy_tail grid through
# the batched fluid engine, then re-runs the differential calibration
# against the event oracle on the pinned seeds and exits 1 on drift.
# Shares the persistent cache with the adaptive smoke — surrogate cells
# hash into a disjoint engine namespace, so the two engines coexist.
python -m repro.experiments surrogate heavy_tail --shape 20x2 \
    --seeds 0:4 --cache "$ADAPTIVE_SMOKE_CACHE"
echo "  surrogate smoke passed"

echo "== enabled-tracing overhead bound (tol ${TRACE_TOL}) =="
python - "$TRACE_TOL" <<'PY'
import json, sys, time
from pathlib import Path
from repro.simcluster.largescale import run_scenario

tol = float(sys.argv[1])

# Paired CPU-time reps: each pair runs untraced then traced back-to-back
# and records the traced/untraced ratio.  Single measurements on shared
# CI machines swing far more (±15-25%) than the ~10% overhead being
# bounded, so the gate passes if the *cleanest* of five pairs is within
# tolerance — noise is symmetric, so a genuine regression (an allocation
# or stringification landing back on the launch hot path) pushes every
# pair over the bar, while honest ~10% overhead always yields at least
# one clean pair.
def timed(**kw):
    c0 = time.process_time()
    r = run_scenario("fleet_100x2_sustained", seed=0, **kw)
    return time.process_time() - c0, r

overheads = []
for _ in range(5):
    cpu_u, plain = timed()
    cpu_t, traced = timed(tracing=True)
    assert traced.makespan == plain.makespan, "tracing changed the schedule"
    overheads.append(cpu_t / cpu_u - 1.0)
    print(f"  untraced {cpu_u:.3f} cpu-s, traced {cpu_t:.3f} cpu-s "
          f"({traced.trace.total} trace events): overhead "
          f"{overheads[-1]:+.1%}")
best = min(overheads)
print(f"  best of {len(overheads)} pairs: {best:+.1%} (bound {tol:.0%})")
if best > tol:
    print(f"FAIL: enabled-tracing overhead {best:.1%} > {tol:.0%} "
          f"in every pair")
    sys.exit(1)
traced_evs = traced.events_processed / cpu_t

# anchor against the committed untraced number too (loose floor — same
# philosophy as BENCH_TOL: catches order-of-magnitude collapses, not noise)
committed = json.loads(Path("BENCH_sim.json").read_text())
base = committed["scenarios"].get("fleet_100x2_sustained", {})
old = (base.get("indexed") or {}).get("events_per_sec")
if old:
    floor = old * 0.5
    print(f"  traced {traced_evs:.0f} ev/s vs committed untraced "
          f"{old:.0f} (floor {floor:.0f})")
    if traced_evs < floor:
        print("FAIL: traced throughput collapsed vs committed baseline")
        sys.exit(1)
print("  enabled-tracing overhead bound passed")
PY

echo "== quick sim benchmark =="
python benchmarks/bench_sim.py --quick --out "$QUICK_OUT"
python benchmarks/bench_surrogate.py --quick --out "$QUICK_OUT"

echo "== regression check vs committed BENCH_sim.json (tol ${BENCH_TOL}) =="
python - "$QUICK_OUT" "$BENCH_TOL" <<'PY'
import json, sys
from pathlib import Path

quick = json.loads(Path(sys.argv[1]).read_text())
tol = float(sys.argv[2])
committed = json.loads(Path("BENCH_sim.json").read_text())

failures = []
for name, entry in quick["scenarios"].items():
    base = committed["scenarios"].get(name)
    if base is None:
        print(f"  {name}: not in committed BENCH_sim.json, skipping")
        continue
    # parity between engines must hold wherever the quick run measured it
    if entry.get("parity") is False:
        failures.append(f"{name}: indexed/legacy parity broken")
    for engine in ("indexed", "legacy"):
        if engine not in entry or engine not in base:
            continue
        new = entry[engine]["events_per_sec"]
        old = base[engine]["events_per_sec"]
        floor = old * (1.0 - tol)
        status = "ok" if new >= floor else "REGRESSION"
        print(f"  {name}/{engine}: {new:.0f} ev/s vs committed {old:.0f} "
              f"(floor {floor:.0f}) {status}")
        if new < floor:
            failures.append(
                f"{name}/{engine}: {new:.0f} ev/s < floor {floor:.0f}")

sur = quick.get("surrogate")
base = committed.get("surrogate")
if sur and base:
    new = sur["surrogate"]["cells_per_sec"]
    old = base["surrogate"]["cells_per_sec"]
    floor = old * (1.0 - tol)
    status = "ok" if new >= floor else "REGRESSION"
    print(f"  surrogate: {new:.1f} cells/s vs committed {old:.1f} "
          f"(floor {floor:.1f}) {status}")
    if new < floor:
        failures.append(f"surrogate: {new:.1f} cells/s < floor {floor:.1f}")

if failures:
    print("\nFAIL:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print("\nbench regression check passed")
PY
echo "== all checks passed =="
