"""Deterministic synthetic token pipeline with host-local shard placement.

The framework analogue of HDFS blocks (DESIGN.md §2): the corpus is split
into numbered shards; each shard is assigned to specific *hosts* (a TPU v5e
host drives 4 chips).  A job's data-parallel workers read the shards local
to their host — the fleet scheduler (repro.elastic) uses this placement the
way the paper's Algorithm 1 uses HDFS block locations.

Synthetic corpus: deterministic PRNG tokens (zipfian ranks) so any shard is
reproducible from (seed, shard_id) alone — no I/O, but the locality
bookkeeping is real.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 256
    seed: int = 0
    zipf_a: float = 1.2          # token-rank distribution


def host_shard_assignment(num_shards: int, num_hosts: int,
                          replication: int = 1,
                          seed: int = 0) -> List[Tuple[int, ...]]:
    """shard -> tuple of hosts holding a replica (round-robin + offset)."""
    rng = np.random.RandomState(seed)
    out = []
    for s in range(num_shards):
        primary = s % num_hosts
        extra = rng.choice([h for h in range(num_hosts) if h != primary],
                           size=min(replication - 1, num_hosts - 1),
                           replace=False).tolist() if replication > 1 else []
        out.append(tuple([primary] + extra))
    return out


class ShardedDataset:
    """Deterministic synthetic shards + locality accounting."""

    def __init__(self, cfg: DataConfig, num_hosts: int, replication: int = 1):
        self.cfg = cfg
        self.num_hosts = num_hosts
        self.placement = host_shard_assignment(
            cfg.num_shards, num_hosts, replication, cfg.seed)
        self.local_reads = 0
        self.remote_reads = 0

    def shard_tokens(self, shard_id: int, n_seqs: int) -> np.ndarray:
        """[n_seqs, seq_len] int32 — reproducible from (seed, shard_id)."""
        rng = np.random.RandomState((self.cfg.seed * 100003 + shard_id) % 2**31)
        # zipf ranks clipped into the vocab
        toks = rng.zipf(self.cfg.zipf_a, size=(n_seqs, self.cfg.seq_len))
        return (toks % (self.cfg.vocab_size - 1) + 1).astype(np.int32)

    def read(self, shard_id: int, n_seqs: int, reader_host: int) -> np.ndarray:
        if reader_host in self.placement[shard_id]:
            self.local_reads += 1
        else:
            self.remote_reads += 1
        return self.shard_tokens(shard_id, n_seqs)

    def locality_rate(self) -> float:
        tot = self.local_reads + self.remote_reads
        return self.local_reads / tot if tot else 1.0


def make_batch_iter(ds: ShardedDataset, *, hosts: Sequence[int],
                    step0: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Round-robin over the job's assigned hosts' local shards.

    Yields {tokens, labels} with labels = tokens shifted left (next-token)."""
    cfg = ds.cfg
    # shards local to this job's hosts, in deterministic order
    local = [s for s in range(cfg.num_shards)
             if any(h in ds.placement[s] for h in hosts)]
    if not local:
        local = list(range(cfg.num_shards))
    step = step0
    while True:
        shard = local[step % len(local)]
        host = next(h for h in hosts if h in ds.placement[shard]) \
            if any(h in ds.placement[shard] for h in hosts) else hosts[0]
        toks = ds.read(shard, cfg.global_batch, host)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        yield {"tokens": toks, "labels": labels}
        step += 1
