from repro.data.pipeline import (DataConfig, ShardedDataset, make_batch_iter,
                                 host_shard_assignment)
