"""Dense decoder-only GQA transformer (llama3.2 / tinyllama / stablelm / nemotron).

Params are stacked over layers and the stack is consumed by ``lax.scan`` so
compile time and HLO size are depth-independent.  The same module provides the
attention backbone reused by the MoE / hybrid / enc-dec families.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel.activations import shard_acts
from repro.models.common import ModelConfig, register


def _stack_init(fn, key, n: int):
    """Initialize n copies of a sub-tree and stack leaves on axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_layer(cfg: ModelConfig, key) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attn(cfg, k1),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "ffn": L.init_ffn(cfg, k2),
    }


def layer_fwd(cfg: ModelConfig, lp: Dict, x: jax.Array, positions,
              kv_state=None, window=None):
    h = L.apply_norm(cfg, lp["ln1"], x)
    a, new_state = L.attn_block(cfg, lp["attn"], h, positions,
                                causal=True, window=window, kv_state=kv_state)
    if cfg.parallel_residual:
        f = L.ffn(cfg, lp["ffn"], h)
        x = x + a + f
    else:
        x = x + a
        x = x + L.ffn(cfg, lp["ffn"], L.apply_norm(cfg, lp["ln2"], x))
    return shard_acts(x), new_state


@register("dense")
class DenseTransformer:
    """Public API: init / loss / forward / prefill / decode_step / init_cache."""

    # -- params -----------------------------------------------------------
    @staticmethod
    def init(cfg: ModelConfig, key) -> Dict:
        ke, kl, kh = jax.random.split(key, 3)
        params = {
            "embed": L.init_embed(cfg, ke),
            "layers": _stack_init(lambda k: init_layer(cfg, k), kl, cfg.num_layers),
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.init_linear(kh, cfg.d_model, cfg.vocab_size,
                                              cfg.param_dtype)
        return params

    # -- forward ------------------------------------------------------------
    @staticmethod
    def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                positions: Optional[jax.Array] = None) -> jax.Array:
        """tokens [B,S] -> final hidden [B,S,D]."""
        B, S = tokens.shape
        if positions is None:
            positions = jnp.arange(S)
        x = L.embed(cfg, params["embed"], tokens)

        def body(x, lp):
            y, _ = layer_fwd(cfg, lp, x, positions, window=cfg.window)
            return y, None

        x, _ = jax.lax.scan(L.remat_wrap(cfg, body), x, params["layers"])
        return L.apply_norm(cfg, params["final_norm"], x)

    @staticmethod
    def logits(cfg: ModelConfig, params: Dict, hidden: jax.Array) -> jax.Array:
        return L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)

    @staticmethod
    def loss(cfg: ModelConfig, params: Dict, batch: Dict):
        hidden = DenseTransformer.forward(cfg, params, batch["tokens"],
                                          batch.get("positions"))
        logits = DenseTransformer.logits(cfg, params, hidden)
        loss = L.softmax_xent(logits, batch["labels"])
        return loss, {"loss": loss}

    # -- inference ------------------------------------------------------------
    @staticmethod
    def cache_len(cfg: ModelConfig, max_len: int) -> int:
        return min(max_len, cfg.window) if cfg.window else max_len

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
        hd = cfg.resolved_head_dim
        S = DenseTransformer.cache_len(cfg, max_len)
        shape = (cfg.num_layers, batch, cfg.n_kv_heads, S, hd)
        return {
            "k": jnp.zeros(shape, cfg.compute_dtype),
            "v": jnp.zeros(shape, cfg.compute_dtype),
            "len": jnp.zeros((), jnp.int32),
        }

    @staticmethod
    def prefill(cfg: ModelConfig, params: Dict, batch: Dict):
        """Full forward returning (last-position logits, populated cache)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = batch.get("positions")
        pos1 = jnp.arange(S) if positions is None else None
        x = L.embed(cfg, params["embed"], tokens)

        def body(x, lp):
            h = L.apply_norm(cfg, lp["ln1"], x)
            a, st = L.attn_block(cfg, lp["attn"], h,
                                 pos1 if pos1 is not None else positions,
                                 causal=True, window=cfg.window)
            if cfg.parallel_residual:
                x = x + a + L.ffn(cfg, lp["ffn"], h)
            else:
                x = x + a
                x = x + L.ffn(cfg, lp["ffn"], L.apply_norm(cfg, lp["ln2"], x))
            k, v = st["k"], st["v"]
            if cfg.window and S > cfg.window:
                # keep last `window` positions, ring-indexed (slot = pos % window)
                k = jnp.roll(k[:, :, -cfg.window:], shift=S % cfg.window, axis=2)
                v = jnp.roll(v[:, :, -cfg.window:], shift=S % cfg.window, axis=2)
            return x, (k, v)

        x, (ks, vs) = jax.lax.scan(L.remat_wrap(cfg, body), x, params["layers"])
        hidden = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
        logits = DenseTransformer.logits(cfg, params, hidden)
        cache = {"k": ks, "v": vs, "len": jnp.asarray(S, jnp.int32)}
        return logits, cache

    @staticmethod
    def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
        """tokens [B,1] + cache -> (logits [B,1,V], cache)."""
        tokens = batch["tokens"]
        B, S1 = tokens.shape
        cur = cache["len"]
        positions = (cur + jnp.arange(S1))[None, :].repeat(B, 0)
        if cfg.mrope_sections is not None:
            positions = positions[:, None, :].repeat(3, 1)
        x = L.embed(cfg, params["embed"], tokens)

        def body(x, inp):
            lp, ck, cv = inp
            st = {"k": ck, "v": cv, "len": cur}
            y, new_st = layer_fwd(cfg, lp, x, positions, kv_state=st,
                                  window=cfg.window)
            return y, (new_st["k"], new_st["v"])

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        hidden = L.apply_norm(cfg, params["final_norm"], x)
        logits = DenseTransformer.logits(cfg, params, hidden)
        return logits, {"k": ks, "v": vs, "len": cur + S1}


@register("vlm")
class VLMTransformer(DenseTransformer):
    """Qwen2-VL backbone: dense GQA transformer with M-RoPE.

    The vision frontend is a STUB per the assignment: ``batch`` may carry
    precomputed patch embeddings ``vision_embeds`` [B, S_v, D] which are
    prepended to the token embeddings; 3-D M-RoPE position ids come in
    ``batch["positions"]`` [B, 3, S].  Text-only batches synthesize
    positions = arange broadcast to the three streams.
    """

    @staticmethod
    def loss(cfg: ModelConfig, params: Dict, batch: Dict):
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.arange(S)[None, None, :].repeat(B, 0).repeat(3, 1)
        x = L.embed(cfg, params["embed"], tokens)
        if "vision_embeds" in batch:
            x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x], axis=1)
            sv = batch["vision_embeds"].shape[1]
            vis_pos = jnp.arange(sv)[None, None, :].repeat(B, 0).repeat(3, 1)
            positions = jnp.concatenate([vis_pos, positions + sv], axis=2)

        def body(x, lp):
            y, _ = layer_fwd(cfg, lp, x, positions, window=cfg.window)
            return y, None

        x, _ = jax.lax.scan(L.remat_wrap(cfg, body), x, params["layers"])
        hidden = L.apply_norm(cfg, params["final_norm"], x)
        logits = DenseTransformer.logits(cfg, params, hidden)
        if "vision_embeds" in batch:
            logits = logits[:, batch["vision_embeds"].shape[1]:]
        return L.softmax_xent(logits, batch["labels"]), {}
