"""Mixture-of-Experts transformers: Mixtral (GQA + SWA) and DeepSeek-V2-Lite (MLA).

Design notes
------------
* **Grouped dispatch**: token routing (argsort + scatter) is performed inside a
  vmapped "dispatch group" dimension of size ``cfg.moe_dispatch_groups`` which
  the launcher shards over the ``data`` mesh axis.  GSPMD therefore keeps every
  sort/scatter *local to its data shard* — no global all-gather of the token
  stream (the data-locality principle of the paper, applied to expert routing).
* **Expert parallelism**: expert weights keep ``d_ff`` sharded over ``model``
  (TP-within-expert), so dispatch needs no all-to-all; the down-projection
  produces a partial sum that GSPMD all-reduces over ``model``.
* **MLA** (DeepSeek): compressed KV cache (c_kv ⊕ rope-key); the *naive* decode
  expands c_kv per step — the absorbed-matmul variant is a §Perf hillclimb.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models import layers as L
from repro.parallel.activations import shard_acts
from repro.models.common import ModelConfig, register
from repro.models.transformer import DenseTransformer, _stack_init

# ---------------------------------------------------------------------------
# Routed expert FFN
# ---------------------------------------------------------------------------


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_id_bwd(x, axis_name):
    return jax.lax.psum(x, axis_name)


def _psum_id_fwd(x, axis_name):
    return jax.lax.psum(x, axis_name), None


def _psum_id_rev(axis_name, _, dy):
    # Megatron "g" op: fwd = psum over tp, bwd = identity — the cotangent is
    # already replicated across tp (downstream compute is tp-replicated), so
    # autodiff's default psum-in-bwd would be a redundant 16-way all-reduce.
    return (dy,)


_psum_id_bwd.defvjp(_psum_id_fwd, _psum_id_rev)


def init_moe_ffn(cfg: ModelConfig, key) -> Dict:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f * 2 * cfg.num_layers)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale_in
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale_in
                   ).astype(cfg.param_dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale_in
                 ).astype(cfg.param_dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) * scale_out
                   ).astype(cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_ffn(cfg, ks[4], d_ff=cfg.n_shared_experts * f)
    return p


def _dispatch_group(cfg: ModelConfig, p: Dict, xg: jax.Array,
                    partial_sum_axis=None) -> Tuple[jax.Array, jax.Array]:
    """Route one dispatch group.  xg: [T, d] -> (out [T, d], aux_loss scalar).

    ``partial_sum_axis``: inside shard_map, the down-projection contracts a
    tp-sharded d_ff — psum the partial over that axis."""
    T, d = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(T * k / E * cfg.capacity_factor))

    logits = jnp.einsum("td,de->te", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # [T, E]
    # Layout-stable expert selection: after one optimizer step the fp32
    # probs differ by ~1 ulp between the single-device and shard_map
    # layouts (different all-gather/psum reduction orders), and a
    # near-tied pair of experts can then top_k apart — a discrete routing
    # flip that amplifies float noise into ~1e-2 loss divergence by step
    # two.  Select on a bf16-rounded key: layout noise vanishes below the
    # rounding step, exact bf16 ties collapse to top_k's deterministic
    # lowest-index-first order, and the gate weights still come from the
    # full-precision probs via the selected indices.
    _, idx = jax.lax.top_k(probs.astype(jnp.bfloat16), k)        # [T, k]
    gate = jnp.take_along_axis(probs, idx, axis=-1)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch (local to this group) ----------------------
    flat_e = idx.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)                     # [T*k]
    sorted_e = flat_e[order]
    tok_of = order // k
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))        # [E]
    pos = jnp.arange(T * k) - seg_start[sorted_e]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)

    buf = jnp.zeros((E, cap, d), xg.dtype)
    contrib = jnp.where(keep[:, None], xg[tok_of], 0)
    buf = buf.at[sorted_e, pos_c].add(contrib)                   # dropped -> +0

    # ---- expert compute (f sharded over `model`) -------------------------
    dt = xg.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))    # partial-sum AR
    if partial_sum_axis is not None:
        y = _psum_id_bwd(y, partial_sum_axis)
        y = checkpoint_name(y, "moe_y")

    # ---- un-dispatch ------------------------------------------------------
    gflat = gate.reshape(T * k)[order]
    back = jnp.where(keep[:, None], y[sorted_e, pos_c] * gflat[:, None].astype(dt), 0)
    out = jnp.zeros((T, d), dt).at[tok_of].add(back)

    # ---- load-balancing aux (Switch-style) -------------------------------
    frac_tokens = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def _moe_ffn_shard_map(cfg: ModelConfig, p: Dict, x: jax.Array):
    """§Perf: fully-manual MoE layer via shard_map.

    GSPMD's auto-partitioning of the vmapped dispatch generated ~1.6 TB/chip
    of all-reduce on deepseek train_4k (it replicates the scatter/gather
    chains).  shard_map makes every step explicit and local:

      * tokens stay on their data shard (the paper's locality principle);
      * expert weights: FSDP-sharded over data -> one explicit all-gather
        per layer (bwd: reduce-scatter of the weight grads), tp-sharded on
        d_ff so the expert matmuls are column-parallel;
      * ONE psum over `model` after the down-projection;
      * dispatch (sort/scatter) runs on local tokens only — zero comm.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.activations import _STATE as _ACT

    mesh = _ACT["mesh"]
    dp, tp, fsdp = _ACT["dp"], _ACT["tp"], _ACT["fsdp"]
    B, S, d = x.shape
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    # Capacity pooling must not depend on the layout: the single-device path
    # splits the token stream into ``cfg.moe_dispatch_groups`` contiguous
    # capacity groups, and each data shard here holds a contiguous slice of
    # that stream.  Carving the local slice into G/dp subgroups reproduces
    # the exact same group boundaries — and therefore the same per-group
    # token drops — as the unsharded layout.  One fused local group (the old
    # behaviour, G_l=1) pools capacity across the whole shard and drops a
    # *different* token set, which showed up as ~1e-2 train-loss divergence
    # on the deepseek parity check.
    G_l = 1
    if cfg.moe_dispatch_groups % _ACT["dp_size"] == 0:
        G_l = cfg.moe_dispatch_groups // _ACT["dp_size"]

    def body(xl, router, wg, wu, wd):
        # xl: [B_l, S, d]; wg/wu: [E, d(/fsdp), f_l]; wd: [E, f_l, d(/fsdp)]
        if fsdp is not None:
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        wg = checkpoint_name(wg, "fsdp_w")
        wu = checkpoint_name(wu, "fsdp_w")
        wd = checkpoint_name(wd, "fsdp_w")
        pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        T_l = xl.shape[0] * xl.shape[1]
        g = G_l
        while T_l % g:
            g -= 1
        xf = xl.reshape(g, T_l // g, d)
        out_l, aux_l = jax.vmap(
            lambda xg: _dispatch_group(cfg, pl, xg, partial_sum_axis=tp))(xf)
        aux_l = jax.lax.pmean(jnp.mean(aux_l), dp_axes)
        return out_l.reshape(xl.shape), aux_l

    in_specs = (P(dp, None, None),               # x: batch over dp
                P(),                             # router replicated
                P(None, fsdp, tp),               # w_gate [E, d, f]
                P(None, fsdp, tp),               # w_up
                P(None, tp, fsdp))               # w_down [E, f, d]
    out_specs = (P(dp, None, None), P())
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    out, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


def moe_ffn(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out, aux)."""
    from repro.parallel.activations import _STATE as _ACT
    B, S, d = x.shape
    use_sm = (_ACT["mesh"] is not None and _ACT["dp"] is not None
              and B % _ACT["dp_size"] == 0 and S > 1
              and cfg.d_ff_expert % max(_ACT["tp_size"], 1) == 0)
    # S == 1 (decode): the per-step explicit FSDP weight gather would cost
    # more than it saves on 1 token/seq (§Perf: measured 0.05x regression);
    # decode keeps the GSPMD path.
    if use_sm:
        out, aux = _moe_ffn_shard_map(cfg, p, x)
    else:
        G = max(1, min(cfg.moe_dispatch_groups, B * S))
        while (B * S) % G:
            G -= 1
        xf = x.reshape(G, (B * S) // G, d)
        out, aux = jax.vmap(lambda xg: _dispatch_group(cfg, p, xg))(xf)
        out = out.reshape(B, S, d)
        aux = jnp.mean(aux)
    if cfg.n_shared_experts:
        out = out + L.ffn(cfg, p["shared"], x)
    return out, aux


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(cfg: ModelConfig, key) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope, vdim, lora = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    return {
        "wq": L.init_linear(ks[0], d, H * (nope + rope), cfg.param_dtype),
        "w_dkv": L.init_linear(ks[1], d, lora + rope, cfg.param_dtype),
        "w_uk": L.init_linear(ks[2], lora, H * nope, cfg.param_dtype),
        "w_uv": L.init_linear(ks[3], lora, H * vdim, cfg.param_dtype),
        "wo": L.init_linear(ks[4], H * vdim, d, cfg.param_dtype,
                            scale=1.0 / math.sqrt(H * vdim * 2 * cfg.num_layers)),
    }


def _mla_qkv(cfg: ModelConfig, p: Dict, x: jax.Array, positions: jax.Array):
    """Project x to (q, c_kv, k_rope).  positions: [S] absolute."""
    B, S, _ = x.shape
    H, nope, rope = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    dt = x.dtype
    q = jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt))
    q = q.reshape(B, S, H, nope + rope).transpose(0, 2, 1, 3)     # [B,H,S,nope+rope]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    posb = positions[None, :].repeat(B, 0)
    q_rope = L.apply_rope(q_rope, posb, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    dkv = jnp.einsum("bsd,df->bsf", x, p["w_dkv"].astype(dt))
    c_kv, k_rope = dkv[..., :cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    k_rope = L.apply_rope(k_rope[:, None], posb, cfg.rope_theta)  # [B,1,S,rope]
    return q, c_kv, k_rope


def _mla_expand(cfg: ModelConfig, p: Dict, c_kv: jax.Array, k_rope: jax.Array):
    """Expand compressed cache to per-head K/V.  c_kv [B,S,lora]."""
    B, S, _ = c_kv.shape
    H, nope, vdim = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    dt = c_kv.dtype
    k_nope = jnp.einsum("bsl,lf->bsf", c_kv, p["w_uk"].astype(dt))
    k_nope = k_nope.reshape(B, S, H, nope).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsl,lf->bsf", c_kv, p["w_uv"].astype(dt))
    v = v.reshape(B, S, H, vdim).transpose(0, 2, 1, 3)
    k_rope_b = jnp.broadcast_to(k_rope, (B, H, S, cfg.qk_rope_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_block(cfg: ModelConfig, p: Dict, x: jax.Array, positions: jax.Array,
              kv_state: Optional[Dict] = None):
    B, S, _ = x.shape
    q, c_kv, k_rope = _mla_qkv(cfg, p, x, positions)
    if kv_state is None:
        k, v = _mla_expand(cfg, p, c_kv, k_rope)
        out = L.attention(cfg, q, k, v, causal=True,
                          q_positions=positions, kv_positions=positions)
        new_state = {"c_kv": c_kv, "k_rope": k_rope[:, 0], "len": None}
    else:
        cur = kv_state["len"]
        cc = jax.lax.dynamic_update_slice_in_dim(
            kv_state["c_kv"], c_kv.astype(kv_state["c_kv"].dtype), cur, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            kv_state["k_rope"], k_rope[:, 0].astype(kv_state["k_rope"].dtype), cur, 1)
        k, v = _mla_expand(cfg, p, cc.astype(x.dtype), cr.astype(x.dtype)[:, None])
        Smax = cc.shape[1]
        out = L.attention(cfg, q, k, v, causal=True,
                          q_positions=positions,
                          kv_positions=jnp.arange(Smax),
                          kv_len=cur + S)
        new_state = {"c_kv": cc, "k_rope": cr, "len": cur + S}
    y = jnp.einsum("bsf,fd->bsd", L._merge_heads(out), p["wo"].astype(x.dtype))
    return y, new_state


# ---------------------------------------------------------------------------
# MoE transformer
# ---------------------------------------------------------------------------


def init_moe_layer(cfg: ModelConfig, key, dense_ffn: bool = False) -> Dict:
    k1, k2 = jax.random.split(key)
    attn = init_mla(cfg, k1) if cfg.kv_lora_rank else L.init_attn(cfg, k1)
    ff = (L.init_ffn(cfg, k2, d_ff=cfg.d_ff_dense or cfg.d_ff)
          if dense_ffn else init_moe_ffn(cfg, k2))
    return {"ln1": L.init_norm(cfg, cfg.d_model), "attn": attn,
            "ln2": L.init_norm(cfg, cfg.d_model), "ffn": ff}


def moe_layer_fwd(cfg: ModelConfig, lp: Dict, x: jax.Array, positions,
                  kv_state=None, dense_ffn: bool = False):
    h = L.apply_norm(cfg, lp["ln1"], x)
    if cfg.kv_lora_rank:
        a, new_state = mla_block(cfg, lp["attn"], h, positions, kv_state=kv_state)
    else:
        a, new_state = L.attn_block(cfg, lp["attn"], h, positions, causal=True,
                                    window=cfg.window, kv_state=kv_state)
    x = x + a
    h2 = L.apply_norm(cfg, lp["ln2"], x)
    if dense_ffn:
        f, aux = L.ffn(cfg, lp["ffn"], h2), jnp.float32(0)
    else:
        f, aux = moe_ffn(cfg, lp["ffn"], h2)
    return shard_acts(x + f), new_state, aux


@register("moe")
class MoETransformer:
    @staticmethod
    def init(cfg: ModelConfig, key) -> Dict:
        ke, k0, kl, kh = jax.random.split(key, 4)
        n_scan = cfg.num_layers - cfg.n_dense_layers
        params = {
            "embed": L.init_embed(cfg, ke),
            "layers": _stack_init(lambda k: init_moe_layer(cfg, k), kl, n_scan),
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }
        if cfg.n_dense_layers:
            dks = jax.random.split(k0, cfg.n_dense_layers)
            params["dense_layers"] = [
                init_moe_layer(cfg, dk, dense_ffn=True) for dk in dks]
        if not cfg.tie_embeddings:
            params["lm_head"] = L.init_linear(kh, cfg.d_model, cfg.vocab_size,
                                              cfg.param_dtype)
        return params

    @staticmethod
    def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array):
        B, S = tokens.shape
        positions = jnp.arange(S)
        x = L.embed(cfg, params["embed"], tokens)
        aux_total = jnp.float32(0)
        for lp in params.get("dense_layers", []):
            x, _, _ = moe_layer_fwd(cfg, lp, x, positions, dense_ffn=True)

        def body(carry, lp):
            x, aux = carry
            y, _, a = moe_layer_fwd(cfg, lp, x, positions)
            return (y, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            L.remat_wrap(cfg, body), (x, aux_total), params["layers"])
        return L.apply_norm(cfg, params["final_norm"], x), aux_total

    @staticmethod
    def loss(cfg: ModelConfig, params: Dict, batch: Dict):
        hidden, aux = MoETransformer.forward(cfg, params, batch["tokens"])
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        xent = L.softmax_xent(logits, batch["labels"])
        n_moe = cfg.num_layers - cfg.n_dense_layers
        loss = xent + cfg.router_aux_weight * aux / max(n_moe, 1)
        return loss, {"loss": loss, "xent": xent, "aux": aux}

    # -- inference ----------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
        n_scan = cfg.num_layers - cfg.n_dense_layers
        if cfg.kv_lora_rank:
            mk = lambda n: {
                "c_kv": jnp.zeros((n, batch, max_len, cfg.kv_lora_rank), cfg.compute_dtype),
                "k_rope": jnp.zeros((n, batch, max_len, cfg.qk_rope_dim), cfg.compute_dtype),
            }
        else:
            S = min(max_len, cfg.window) if cfg.window else max_len
            hd = cfg.resolved_head_dim
            mk = lambda n: {
                "k": jnp.zeros((n, batch, cfg.n_kv_heads, S, hd), cfg.compute_dtype),
                "v": jnp.zeros((n, batch, cfg.n_kv_heads, S, hd), cfg.compute_dtype),
            }
        cache = {"scan": mk(n_scan), "len": jnp.zeros((), jnp.int32)}
        if cfg.n_dense_layers:
            cache["dense"] = mk(cfg.n_dense_layers)
        return cache

    @staticmethod
    def _layer_cache_slices(cfg, cache_tree):
        if cfg.kv_lora_rank:
            return (cache_tree["c_kv"], cache_tree["k_rope"])
        return (cache_tree["k"], cache_tree["v"])

    @staticmethod
    def prefill(cfg: ModelConfig, params: Dict, batch: Dict):
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.arange(S)
        x = L.embed(cfg, params["embed"], tokens)
        dense_states = []
        for lp in params.get("dense_layers", []):
            x, st, _ = moe_layer_fwd(cfg, lp, x, positions, dense_ffn=True)
            dense_states.append(st)

        def body(x, lp):
            y, st, _ = moe_layer_fwd(cfg, lp, x, positions)
            if cfg.kv_lora_rank:
                return y, (st["c_kv"], st["k_rope"])
            k, v = st["k"], st["v"]
            if cfg.window and S > cfg.window:
                k = jnp.roll(k[:, :, -cfg.window:], shift=S % cfg.window, axis=2)
                v = jnp.roll(v[:, :, -cfg.window:], shift=S % cfg.window, axis=2)
            return y, (k, v)

        x, (c1, c2) = jax.lax.scan(L.remat_wrap(cfg, body), x, params["layers"])
        hidden = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        key1, key2 = ("c_kv", "k_rope") if cfg.kv_lora_rank else ("k", "v")
        cache = {"scan": {key1: c1, key2: c2}, "len": jnp.asarray(S, jnp.int32)}
        if dense_states:
            cache["dense"] = {
                key1: jnp.stack([st[key1 if cfg.kv_lora_rank else "k"] for st in dense_states]),
                key2: jnp.stack([st[key2 if cfg.kv_lora_rank else "v"] for st in dense_states]),
            }
        return logits, cache

    @staticmethod
    def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
        tokens = batch["tokens"]
        B, S1 = tokens.shape
        cur = cache["len"]
        positions = cur + jnp.arange(S1)
        x = L.embed(cfg, params["embed"], tokens)
        new_dense = None
        if cfg.n_dense_layers:
            c1s, c2s = MoETransformer._layer_cache_slices(cfg, cache["dense"])
            outs1, outs2 = [], []
            for i, lp in enumerate(params["dense_layers"]):
                key1, key2 = ("c_kv", "k_rope") if cfg.kv_lora_rank else ("k", "v")
                st = {key1: c1s[i], key2: c2s[i], "len": cur}
                x, st, _ = moe_layer_fwd(cfg, lp, x, positions, kv_state=st,
                                         dense_ffn=True)
                outs1.append(st[key1]); outs2.append(st[key2])
            new_dense = {key1: jnp.stack(outs1), key2: jnp.stack(outs2)}

        c1s, c2s = MoETransformer._layer_cache_slices(cfg, cache["scan"])
        key1, key2 = ("c_kv", "k_rope") if cfg.kv_lora_rank else ("k", "v")

        def body(x, inp):
            lp, c1, c2 = inp
            st = {key1: c1, key2: c2, "len": cur}
            y, st, _ = moe_layer_fwd(cfg, lp, x, positions, kv_state=st)
            return y, (st[key1], st[key2])

        x, (n1, n2) = jax.lax.scan(body, x, (params["layers"], c1s, c2s))
        hidden = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        new_cache = {"scan": {key1: n1, key2: n2}, "len": cur + S1}
        if new_dense is not None:
            new_cache["dense"] = new_dense
        return logits, new_cache
