"""Shared neural-net layers (pure jnp, functional).

Conventions
-----------
* Activations ``[batch, seq, d_model]`` (attention internally ``[B, H, S, D]``).
* All matmuls run in ``cfg.compute_dtype`` (bf16); softmax / norms / losses
  accumulate in fp32.
* Attention has two implementations:
    - ``dense``   : full [Sq, Skv] logits (fine for short seq / decode-step)
    - ``chunked`` : online-softmax over KV blocks inside a q-block loop —
      O(block²) live memory, used for long-context prefill/train.  With
      ``causal_pack=True`` q-blocks are paired (i, nq-1-i) so causal skipping
      wastes no FLOPs (the beyond-paper perf optimization; see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models.common import ModelConfig
from repro.models.flash import flash_attention
from repro.parallel.activations import (bh_flat_entry, shard_acts,
                                        shard_attn_qkv, shard_bh,
                                        shard_embed_out, shard_logits)

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(cfg: ModelConfig, d: int):
    if cfg.norm == "rms":
        return {"scale": jnp.ones((d,), cfg.param_dtype)}
    return {"scale": jnp.ones((d,), cfg.param_dtype), "bias": jnp.zeros((d,), cfg.param_dtype)}


def apply_norm(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE, partial RoPE, M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions: jax.Array, rot_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...]: angles for rot_dim//2 frequencies -> cos/sin [..., rot_dim//2]."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # [..., rot_dim//2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, fraction: float = 1.0) -> jax.Array:
    """x: [B, H, S, D]; positions: [B, S].  Rotates the first ``fraction`` of D.

    Uses the half-split convention (rotate_half), matching llama."""
    D = x.shape[-1]
    rot = int(D * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    cos, sin = _rope_angles(positions, rot, theta)          # [B, S, rot//2]
    cos = cos[:, None, :, :]                                 # [B, 1, S, rot//2]
    sin = sin[:, None, :, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1) if rot < D else out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: Tuple[int, int, int]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, H, S, D]; positions: [B, 3, S] -- (temporal, height, width) ids.
    ``sections`` partitions the D//2 frequency slots among the 3 position
    streams (e.g. (16, 24, 24) for D=128)."""
    D = x.shape[-1]
    assert sum(sections) == D // 2, (sections, D)
    cos_t, sin_t = _rope_angles(positions, D, theta)         # [B, 3, S, D//2]
    # pick, per frequency slot, which positional stream drives it
    sel = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )                                                         # [D//2]
    onehot = jax.nn.one_hot(sel, 3, dtype=jnp.float32)        # [D//2, 3]
    cos = jnp.einsum("bksf,fk->bsf", cos_t, onehot)           # [B, S, D//2]
    sin = jnp.einsum("bksf,fk->bsf", sin_t, onehot)
    cos, sin = cos[:, None], sin[:, None]                     # [B,1,S,D//2]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def rope_for(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.mrope_sections is not None and positions.ndim == 3:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    if positions.ndim == 3:  # text-only batch through an mrope model
        positions = positions[:, 0]
    return apply_rope(x, positions, cfg.rope_theta, cfg.rope_fraction)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def attention_dense(
    q: jax.Array,            # [B, Hq, Sq, D]
    k: jax.Array,            # [B, Hkv, Skv, D]
    v: jax.Array,            # [B, Hkv, Skv, Dv]
    *,
    causal: bool,
    q_positions: jax.Array,  # [Sq] absolute positions of queries
    kv_positions: jax.Array, # [Skv]
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    kv_len: Optional[jax.Array] = None,   # dynamic valid cache length
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D)
    logits = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32),
        precision=jax.lax.Precision.DEFAULT,
    ) * (1.0 / math.sqrt(D))
    logits = _softcap(logits, softcap)
    mask = jnp.ones((Sq, k.shape[2]), dtype=bool)
    if causal:
        mask &= q_positions[:, None] >= kv_positions[None, :]
    if window is not None:
        mask &= q_positions[:, None] - kv_positions[None, :] < window
    if kv_len is not None:
        mask &= (jnp.arange(k.shape[2]) < kv_len)[None, :]
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, Sq, v.shape[-1]).astype(q.dtype)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_block: int = 1024,
    kv_block: int = 1024,
    causal_pack: bool = False,
) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp.

    Outer ``lax.map`` over q blocks, inner ``lax.scan`` over kv blocks; live
    memory is O(q_block · kv_block).  Baseline scans ALL kv blocks per q block
    (masked) — `causal_pack=True` pairs q block i with q block nq-1-i and scans
    nk+1 joint steps, eliminating the ~2x causal FLOP waste (§Perf).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    # pad to block multiples
    pad_q = (-Sq) % qb
    pad_k = (-Skv) % kb
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    qpos = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    kpos = jnp.pad(kv_positions, (0, pad_k), constant_values=jnp.iinfo(jnp.int32).max)
    nq = qp.shape[2] // qb
    nk = kp.shape[2] // kb

    qp = qp.reshape(B, Hkv, G, nq, qb, D).transpose(3, 0, 1, 2, 4, 5)   # [nq,B,Hkv,G,qb,D]
    kp = kp.reshape(B, Hkv, nk, kb, D).transpose(2, 0, 1, 3, 4)          # [nk,B,Hkv,kb,D]
    vp = vp.reshape(B, Hkv, nk, kb, Dv).transpose(2, 0, 1, 3, 4)
    qpos_b = qpos.reshape(nq, qb)
    kpos_b = kpos.reshape(nk, kb)

    def block_update(carry, q_blk, qpos_blk, k_blk, v_blk, kpos_blk, valid):
        """One online-softmax update; ``valid`` gates the whole block."""
        acc, m, l = carry
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                       k_blk.astype(jnp.float32)) * scale
        s = _softcap(s, softcap)
        mask = jnp.ones((qb, kb), dtype=bool)
        if causal:
            mask &= qpos_blk[:, None] >= kpos_blk[None, :]
        if window is not None:
            mask &= qpos_blk[:, None] - kpos_blk[None, :] < window
        mask &= (qpos_blk >= 0)[:, None] & (kpos_blk < jnp.iinfo(jnp.int32).max)[None, :]
        mask &= valid
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
        return acc_new, m_new, l_new

    zero_carry = lambda: (
        jnp.zeros((B, Hkv, G, qb, Dv), jnp.float32),
        jnp.full((B, Hkv, G, qb), -jnp.inf, jnp.float32),
        jnp.zeros((B, Hkv, G, qb), jnp.float32),
    )

    if not (causal and causal_pack):
        def per_q_block(args):
            q_blk, qpos_blk = args
            def kv_step(carry, kv):
                k_blk, v_blk, kpos_blk = kv
                return block_update(carry, q_blk, qpos_blk, k_blk, v_blk,
                                    kpos_blk, jnp.bool_(True)), None
            (acc, m, l), _ = jax.lax.scan(kv_step, zero_carry(), (kp, vp, kpos_b))
            return acc / jnp.maximum(l, 1e-30)[..., None]
        out = jax.lax.map(per_q_block, (qp, qpos_b))           # [nq,B,Hkv,G,qb,Dv]
    else:
        # ---- causal pair-packing: q block i teams with q block nq-1-i ------
        assert nq == nk and Sq == Skv, "causal_pack requires square self-attn"
        npairs = (nq + 1) // 2
        idx_lo = jnp.arange(npairs)
        idx_hi = nq - 1 - idx_lo

        def per_pair(pair):
            i_lo, i_hi = pair
            q_lo, qpos_lo = qp[i_lo], qpos_b[i_lo]
            q_hi, qpos_hi = qp[i_hi], qpos_b[i_hi]

            def step(carry, s_idx):
                c_lo, c_hi = carry
                # steps 0..i_lo serve the low q block (kv = s); the remaining
                # steps serve the high q block (kv = s - i_lo - 1)
                serve_lo = s_idx <= i_lo
                kv_idx = jnp.where(serve_lo, s_idx, s_idx - i_lo - 1)
                kv_idx = jnp.clip(kv_idx, 0, nk - 1)
                k_blk = jax.lax.dynamic_index_in_dim(kp, kv_idx, 0, keepdims=False)
                v_blk = jax.lax.dynamic_index_in_dim(vp, kv_idx, 0, keepdims=False)
                kpos_blk = jax.lax.dynamic_index_in_dim(kpos_b, kv_idx, 0, keepdims=False)
                q_blk = jnp.where(serve_lo, q_lo, q_hi)
                qpos_blk = jnp.where(serve_lo, qpos_lo, qpos_hi)
                carry_in = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(serve_lo, a, b), c_lo, c_hi)
                upd = block_update(carry_in, q_blk, qpos_blk, k_blk, v_blk,
                                   kpos_blk, jnp.bool_(True))
                c_lo = jax.tree_util.tree_map(
                    lambda old, new: jnp.where(serve_lo, new, old), c_lo, upd)
                c_hi = jax.tree_util.tree_map(
                    lambda old, new: jnp.where(serve_lo, old, new), c_hi, upd)
                return (c_lo, c_hi), None

            n_steps = nq + 1  # (i_lo+1) + (i_hi+1) = nq + 1 joint kv visits
            (c_lo, c_hi), _ = jax.lax.scan(
                step, (zero_carry(), zero_carry()), jnp.arange(n_steps))
            fin = lambda c: c[0] / jnp.maximum(c[2], 1e-30)[..., None]
            return fin(c_lo), fin(c_hi)

        out_lo, out_hi = jax.lax.map(per_pair, (idx_lo, idx_hi))
        # stitch pairs back into q-block order
        out = jnp.zeros((nq, B, Hkv, G, qb, Dv), jnp.float32)
        out = out.at[idx_lo].set(out_lo)
        out = out.at[idx_hi].set(out_hi)

    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, nq * qb, Dv)
    return out[:, :, :Sq].astype(q.dtype)


def attention(
    cfg: ModelConfig,
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = True,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    window: Optional[int] = None,
    kv_len: Optional[jax.Array] = None,
    causal_pack: Optional[bool] = None,
) -> jax.Array:
    """Dispatching attention core.  Decode (Sq small) and short-seq use the
    dense path; long sequences use the chunked online-softmax path."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)
    if Sq <= 2048 or kv_len is not None or cfg.attn_impl == "dense":
        return attention_dense(
            q, k, v, causal=causal, q_positions=q_positions,
            kv_positions=kv_positions, window=window,
            softcap=cfg.attn_logit_softcap, kv_len=kv_len)
    pack = cfg.attn_impl == "chunked_packed" if causal_pack is None else causal_pack
    tp_size = 16  # decision only needs divisibility vs the activation policy
    from repro.parallel.activations import _STATE as _ACT
    tp_size = _ACT["tp_size"]
    heads_misaligned = (_ACT["tp"] is not None and tp_size > 1
                        and (Hq % tp_size or Hkv % tp_size))
    if (heads_misaligned and Sq == Skv and cfg.attn_impl == "bh_flat"
            and bh_flat_entry(B, Hq) is not None):
        # §Perf, refuted: GSPMD replicates through the repeat+flatten chain
        # (+1.7 TB all-gather, 5x dot FLOPs).  Kept opt-in for the record.
        # §Perf: flattened (batch·head)-parallel attention — when heads do
        # not divide tp, GSPMD splits *within* heads (g=2 partial-softmax
        # all-reduces every kv block).  Flattening B×H and sharding jointly
        # over dp×tp makes attention embarrassingly parallel; the kv-repeat
        # and boundary all-to-alls are orders of magnitude cheaper.
        rep = Hq // Hkv
        kr = jnp.repeat(k, rep, axis=1).reshape(B * Hq, 1, Skv, D)
        vr = jnp.repeat(v, rep, axis=1).reshape(B * Hq, 1, Skv, v.shape[-1])
        qf = shard_bh(q.reshape(B * Hq, 1, Sq, D))
        kr, vr = shard_bh(kr), shard_bh(vr)
        out = flash_attention(
            qf, kr, vr, jnp.asarray(q_positions, jnp.int32),
            jnp.asarray(kv_positions, jnp.int32),
            causal, window, cfg.attn_q_block, cfg.attn_kv_block, pack)
        return out.reshape(B, Hq, Sq, v.shape[-1])
    if heads_misaligned and Sq == Skv and cfg.attn_row_parallel:
        from repro.models import attn_sm
        if attn_sm.applicable(B, Hq, Sq, Skv):
            # §Perf winner: explicit row-parallel attention via shard_map —
            # one boundary all-gather instead of per-kv-block g=2 ARs
            return attn_sm.flash_attention_shard_map(
                q, k, v, jnp.asarray(q_positions, jnp.int32),
                jnp.asarray(kv_positions, jnp.int32),
                causal, window, cfg.attn_q_block, cfg.attn_kv_block, pack)
    return flash_attention(
        q, k, v, jnp.asarray(q_positions, jnp.int32),
        jnp.asarray(kv_positions, jnp.int32),
        causal, window, cfg.attn_q_block, cfg.attn_kv_block, pack)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + core + out proj)
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def init_attn(cfg: ModelConfig, key) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], cfg.d_model, cfg.n_heads * hd, cfg.param_dtype),
        "wk": init_linear(ks[1], cfg.d_model, cfg.n_kv_heads * hd, cfg.param_dtype),
        "wv": init_linear(ks[2], cfg.d_model, cfg.n_kv_heads * hd, cfg.param_dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, cfg.d_model, cfg.param_dtype,
                          scale=1.0 / math.sqrt(cfg.n_heads * hd * 2 * cfg.num_layers)),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:    # [B,S,n*hd] -> [B,n,S,hd]
    B, S, _ = x.shape
    return x.reshape(B, S, n, -1).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:             # [B,n,S,hd] -> [B,S,n*hd]
    B, n, S, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, n * hd)


def attn_block(
    cfg: ModelConfig, p: dict, x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_state: Optional[dict] = None,    # decode: {"k","v","len"} cache for this layer
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Standard multi-head GQA attention.  Returns (out, new_kv_state).

    * training/prefill: kv_state None -> self-attention over x.
    * decode: kv_state holds the cache; x is the new token(s).
    * cross attention (whisper): cross_kv = (k, v) precomputed from encoder.
    """
    dt = x.dtype
    q = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt)), cfg.n_heads)
    if cross_kv is not None:
        k, v = cross_kv
        out = attention(cfg, q, k, v, causal=False)
        new_state = None
    else:
        k = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wk"].astype(dt)), cfg.n_kv_heads)
        v = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wv"].astype(dt)), cfg.n_kv_heads)
        # NOTE(§Perf, refuted): sequence-sharding the attention interior when
        # heads misalign with tp (llama 24H/16) was tried here and REGRESSED
        # (+109 GB wire, +42 TF: GSPMD fights the blocked flash reshapes).
        # positions: [S] | [B,S] | [B,3,S] (mrope). Broadcast to batched form
        # for rope; 1-D masking positions use batch row 0 / temporal stream.
        posb = positions[None].repeat(x.shape[0], 0) if positions.ndim == 1 else positions
        qpos1 = posb[0] if posb.ndim == 2 else posb[0, 0]
        q = rope_for(cfg, q, posb)
        k = rope_for(cfg, k, posb)
        if kv_state is None:
            out = attention(cfg, q, k, v, causal=causal, window=window,
                            q_positions=qpos1, kv_positions=qpos1)
            new_state = {"k": k, "v": v}
        else:
            # append new kv at position ``len`` (ring for SWA windows)
            cache_k, cache_v, cur_len = kv_state["k"], kv_state["v"], kv_state["len"]
            S_cache = cache_k.shape[2]
            if window is not None and S_cache == window:
                slot = cur_len % window
            else:
                slot = cur_len
            cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, 2)
            cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, 2)
            # absolute positions of cache entries
            if window is not None and S_cache == window:
                ring_idx = jnp.arange(S_cache)
                abs_pos = cur_len - ((slot - ring_idx) % window)
                kvpos = jnp.where(abs_pos >= 0, abs_pos, jnp.iinfo(jnp.int32).max)
                kv_valid = None
            else:
                kvpos = jnp.arange(S_cache)
                kv_valid = cur_len + q.shape[2]
            out = attention(cfg, q, cache_k.astype(dt), cache_v.astype(dt),
                            causal=True, window=window,
                            q_positions=qpos1, kv_positions=kvpos, kv_len=kv_valid)
            new_state = {"k": cache_k, "v": cache_v, "len": cur_len + q.shape[2]}
    y = jnp.einsum("bsf,fd->bsd", _merge_heads(out), p["wo"].astype(dt))
    y = checkpoint_name(y, "attn_out")   # post-AR (TP)
    return y, new_state


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------


def init_ffn(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / math.sqrt(d_ff * 2 * cfg.num_layers)
    if cfg.act == "swiglu":
        return {
            "w_gate": init_linear(ks[0], cfg.d_model, d_ff, cfg.param_dtype),
            "w_up": init_linear(ks[1], cfg.d_model, d_ff, cfg.param_dtype),
            "w_down": init_linear(ks[2], d_ff, cfg.d_model, cfg.param_dtype, scale=out_scale),
        }
    return {
        "w_up": init_linear(ks[1], cfg.d_model, d_ff, cfg.param_dtype),
        "w_down": init_linear(ks[2], d_ff, cfg.d_model, cfg.param_dtype, scale=out_scale),
    }


def ffn(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        if cfg.act == "relu2":
            h = jnp.square(jax.nn.relu(u.astype(jnp.float32))).astype(dt)
        else:
            h = jax.nn.gelu(u.astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    return checkpoint_name(out, "ffn_out")  # post-AR (TP)


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------


def init_embed(cfg: ModelConfig, key) -> dict:
    p = {"tok": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model), jnp.float32)
                 * 0.02).astype(cfg.param_dtype)}
    return p


def embed(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    return shard_embed_out(
        jnp.take(p["tok"], tokens, axis=0).astype(cfg.compute_dtype))


def unembed(cfg: ModelConfig, p_embed: dict, p_head, x: jax.Array) -> jax.Array:
    w = p_embed["tok"].T if (cfg.tie_embeddings or p_head is None) else p_head
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    logits = shard_logits(logits)
    return logits.astype(jnp.float32) if cfg.logits_fp32 else logits


def softmax_xent(logits: jax.Array, labels: jax.Array, z_weight: float = 1e-4):
    """Cross-entropy with z-loss; labels==-100 are masked.  fp32 accumulation."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom + z_weight * jnp.sum(zl * mask) / denom
    return loss


def remat_wrap(cfg: ModelConfig, fn):
    """Wrap a layer body in jax.checkpoint per the config policy.

    ``comm`` (§Perf winner): full remat EXCEPT collective outputs — gathered
    FSDP weights, post-psum MoE outputs, AR'd attention/FFN outputs are
    saved, so the backward recompute never re-runs collectives (which the
    dry-run showed cost ~35% of total wire bytes under plain full remat).
    """
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    if cfg.remat == "comm":
        policy = jax.checkpoint_policies.save_only_these_names(
            "fsdp_w", "moe_y", "attn_out", "ffn_out")
        return jax.checkpoint(fn, policy=policy)
    if cfg.remat == "comm_lite":
        # like comm but re-gathers FSDP weights in bwd (trades ~2x weight
        # all-gather wire for not pinning gathered weights in HBM)
        policy = jax.checkpoint_policies.save_only_these_names(
            "moe_y", "attn_out", "ffn_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)
