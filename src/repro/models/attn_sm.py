"""shard_map attention: explicit (batch x head)-parallel flash attention.

§Perf iteration for head-misaligned TP (llama3.2: 24 q-heads / 8 kv-heads on
a 16-way model axis).  GSPMD splits *within* heads and emits g=2
partial-softmax all-reduces on every kv block (~360 GB/step/chip measured).
Here we take explicit control:

  * enter shard_map with qkv replicated over tp (one boundary all-gather,
    explicit and cheap relative to the per-block ARs it replaces);
  * flatten (B_local x Hq) rows, pad to a multiple of tp, each tp rank
    slices its own rows — attention is then embarrassingly parallel;
  * all-gather the output rows once at exit.

GQA is handled by repeating KV to query heads before the row flatten.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.flash import flash_attention


def applicable(B: int, Hq: int, Sq: int, Skv: int) -> bool:
    from repro.parallel.activations import _STATE as _ACT
    if _ACT["mesh"] is None or _ACT["dp"] is None or _ACT["tp"] is None:
        return False
    if _ACT["tp_size"] <= 1 or B % _ACT["dp_size"] != 0:
        return False
    return Sq == Skv


def flash_attention_shard_map(q, k, v, q_positions, kv_positions,
                              causal, window, q_block, kv_block, pack):
    from jax.experimental.shard_map import shard_map
    from repro.parallel.activations import _STATE as _ACT

    mesh, dp, tp = _ACT["mesh"], _ACT["dp"], _ACT["tp"]
    tp_size = _ACT["tp_size"]
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    B_l = B // _ACT["dp_size"]
    rows = B_l * Hq
    rows_pad = -(-rows // tp_size) * tp_size
    rpl = rows_pad // tp_size                     # rows per tp rank

    def body(ql, kl, vl):
        # ql: [B_l, Hq, S, D]; kl/vl: [B_l, Hkv, S, D] (replicated over tp)
        rep = Hq // Hkv
        kr = jnp.repeat(kl, rep, axis=1).reshape(rows, Skv, D)
        vr = jnp.repeat(vl, rep, axis=1).reshape(rows, Skv, Dv)
        qf = ql.reshape(rows, Sq, D)
        if rows_pad != rows:
            padn = rows_pad - rows
            qf = jnp.pad(qf, ((0, padn), (0, 0), (0, 0)))
            kr = jnp.pad(kr, ((0, padn), (0, 0), (0, 0)))
            vr = jnp.pad(vr, ((0, padn), (0, 0), (0, 0)))
        r = jax.lax.axis_index(tp)
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, r * rpl, rpl, 0)
        out_l = flash_attention(sl(qf)[:, None], sl(kr)[:, None],
                                sl(vr)[:, None],
                                q_positions, kv_positions,
                                causal, window, q_block, kv_block, pack)
        out_l = out_l[:, 0]                        # [rpl, Sq, Dv]
        out = jax.lax.all_gather(out_l, tp, axis=0, tiled=True)
        return out[:rows].reshape(B_l, Hq, Sq, Dv)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(dp, None, None, None),) * 3,
                   out_specs=P(dp, None, None, None),
                   check_rep=False)
    return fn(q, k, v)
