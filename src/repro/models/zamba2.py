"""Zamba2-style hybrid: Mamba2 backbone + a single *shared* attention block
applied every ``cfg.shared_attn_period`` layers (arXiv:2411.15242).

Faithfulness notes (recorded in DESIGN.md):
* the shared block's input is ``concat([hidden, original_embedding])``
  projected 2d -> d (Zamba's concatenation trick), then a standard
  pre-norm GQA attention + SwiGLU MLP with ONE weight bank reused at every
  application;
* Zamba2's per-application LoRA deltas on the shared block are implemented
  as small rank-r additive adapters (one per application site).
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel.activations import shard_acts
from repro.models.common import ModelConfig, register
from repro.models.transformer import _stack_init
from repro.models.mamba2 import (
    Mamba2LM, init_mamba_layer, mamba_layer_fwd, mamba_block_fwd)

_LORA_RANK = 8


def _segments(num_layers: int, period: int) -> List[int]:
    """Layer counts between successive shared-block applications."""
    sizes = []
    done = 0
    while done < num_layers:
        sizes.append(min(period, num_layers - done))
        done += sizes[-1]
    return sizes


def n_applications(cfg: ModelConfig) -> int:
    return len(_segments(cfg.num_layers, cfg.shared_attn_period))


def init_shared_block(cfg: ModelConfig, key) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    napp = n_applications(cfg)
    hd = cfg.resolved_head_dim
    return {
        "in_proj": L.init_linear(k1, 2 * cfg.d_model, cfg.d_model, cfg.param_dtype),
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attn(cfg, k2),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "ffn": L.init_ffn(cfg, k3),
        # per-application LoRA on the q projection (Zamba2's adapter trick)
        "lora_a": (jax.random.normal(k4, (napp, cfg.d_model, _LORA_RANK), jnp.float32)
                   * 0.01).astype(cfg.param_dtype),
        "lora_b": jnp.zeros((napp, _LORA_RANK, cfg.n_heads * hd), cfg.param_dtype),
    }


def shared_block_fwd(cfg: ModelConfig, sp: Dict, x: jax.Array, x0: jax.Array,
                     app_idx: int, positions, kv_state=None):
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", jnp.concatenate([x, x0], axis=-1),
                   sp["in_proj"].astype(dt))
    hn = L.apply_norm(cfg, sp["ln1"], h)
    a, new_state = L.attn_block(cfg, sp["attn"], hn, positions,
                                causal=True, kv_state=kv_state)
    # LoRA delta on q-path output (additive, per application site)
    la = sp["lora_a"][app_idx].astype(dt)
    lb = sp["lora_b"][app_idx].astype(dt)
    a = a + jnp.einsum("bsr,rf->bsf",
                       jnp.einsum("bsd,dr->bsr", hn, la), lb)[..., :cfg.d_model]
    h = h + a
    h = h + L.ffn(cfg, sp["ffn"], L.apply_norm(cfg, sp["ln2"], h))
    return shard_acts(x + h), new_state


def _seg_params(layers, start: int, size: int):
    return jax.tree_util.tree_map(lambda a: a[start:start + size], layers)


@register("hybrid")
class Zamba2LM:
    @staticmethod
    def init(cfg: ModelConfig, key) -> Dict:
        ke, kl, ks, kh = jax.random.split(key, 4)
        return {
            "embed": L.init_embed(cfg, ke),
            "layers": _stack_init(lambda k: init_mamba_layer(cfg, k), kl,
                                  cfg.num_layers),
            "shared": init_shared_block(cfg, ks),
            "final_norm": L.init_norm(cfg, cfg.d_model),
            "lm_head": L.init_linear(kh, cfg.d_model, cfg.vocab_size,
                                     cfg.param_dtype),
        }

    @staticmethod
    def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array) -> jax.Array:
        S = tokens.shape[1]
        positions = jnp.arange(S)
        x0 = L.embed(cfg, params["embed"], tokens)
        x = x0

        def body(x, lp):
            y, _ = mamba_layer_fwd(cfg, lp, x)
            return y, None

        start = 0
        for app, size in enumerate(_segments(cfg.num_layers, cfg.shared_attn_period)):
            x, _ = shared_block_fwd(cfg, params["shared"], x, x0, app, positions)
            x, _ = jax.lax.scan(L.remat_wrap(cfg, body), x,
                                _seg_params(params["layers"], start, size))
            start += size
        return L.apply_norm(cfg, params["final_norm"], x)

    @staticmethod
    def loss(cfg: ModelConfig, params: Dict, batch: Dict):
        hidden = Zamba2LM.forward(cfg, params, batch["tokens"])
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        loss = L.softmax_xent(logits, batch["labels"])
        return loss, {"loss": loss}

    # -- inference ----------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
        cache = Mamba2LM.init_cache(cfg, batch, max_len)
        napp = n_applications(cfg)
        hd = cfg.resolved_head_dim
        cache["attn_k"] = jnp.zeros((napp, batch, cfg.n_kv_heads, max_len, hd),
                                    cfg.compute_dtype)
        cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
        return cache

    @staticmethod
    def prefill(cfg: ModelConfig, params: Dict, batch: Dict):
        tokens = batch["tokens"]
        S = tokens.shape[1]
        positions = jnp.arange(S)
        x0 = L.embed(cfg, params["embed"], tokens)
        x = x0

        def body(x, lp):
            h = L.apply_norm(cfg, lp["ln"], x)
            y, st = mamba_block_fwd(cfg, lp["mamba"], h)
            return x + y, (st["ssm"], st["conv_x"], st["conv_B"], st["conv_C"])

        segs = _segments(cfg.num_layers, cfg.shared_attn_period)
        attn_k, attn_v, mb_parts = [], [], []
        start = 0
        for app, size in enumerate(segs):
            x, st = shared_block_fwd(cfg, params["shared"], x, x0, app, positions)
            attn_k.append(st["k"]); attn_v.append(st["v"])
            x, ys = jax.lax.scan(L.remat_wrap(cfg, body), x,
                                 _seg_params(params["layers"], start, size))
            mb_parts.append(ys)
            start += size
        ssm, cx, cB, cC = (jnp.concatenate([p[i] for p in mb_parts])
                           for i in range(4))
        hidden = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        cache = {"ssm": ssm, "conv_x": cx, "conv_B": cB, "conv_C": cC,
                 "attn_k": jnp.stack(attn_k), "attn_v": jnp.stack(attn_v),
                 "len": jnp.asarray(S, jnp.int32)}
        return logits, cache

    @staticmethod
    def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
        tokens = batch["tokens"]
        B, S1 = tokens.shape
        cur = cache["len"]
        positions = (cur + jnp.arange(S1))[None, :].repeat(B, 0)
        x0 = L.embed(cfg, params["embed"], tokens)
        x = x0

        def body(x, inp):
            lp, ssm, cx, cB, cC = inp
            st = {"ssm": ssm, "conv_x": cx, "conv_B": cB, "conv_C": cC}
            y, st = mamba_layer_fwd(cfg, lp, x, state=st)
            return y, (st["ssm"], st["conv_x"], st["conv_B"], st["conv_C"])

        segs = _segments(cfg.num_layers, cfg.shared_attn_period)
        new_k, new_v, mb_parts = [], [], []
        start = 0
        for app, size in enumerate(segs):
            kv = {"k": cache["attn_k"][app], "v": cache["attn_v"][app], "len": cur}
            x, st = shared_block_fwd(cfg, params["shared"], x, x0, app,
                                     positions, kv_state=kv)
            new_k.append(st["k"]); new_v.append(st["v"])
            seg_cache = tuple(
                cache[k][start:start + size]
                for k in ("ssm", "conv_x", "conv_B", "conv_C"))
            x, ys = jax.lax.scan(
                body, x, (_seg_params(params["layers"], start, size),) + seg_cache)
            mb_parts.append(ys)
            start += size
        ssm, cx, cB, cC = (jnp.concatenate([p[i] for p in mb_parts])
                           for i in range(4))
        hidden = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        return logits, {"ssm": ssm, "conv_x": cx, "conv_B": cB, "conv_C": cC,
                        "attn_k": jnp.stack(new_k), "attn_v": jnp.stack(new_v),
                        "len": cur + S1}
