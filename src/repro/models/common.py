"""Shared model configuration and registry for the assigned architectures.

Every architecture is a pure-functional JAX model:

* ``init(cfg, key)``         -> params pytree (stacked over layers for scan)
* ``loss_fn(cfg, params, batch)``    -> scalar loss  (train_* shapes)
* ``prefill(cfg, params, batch)``    -> (logits, cache)  (prefill_* shapes)
* ``decode_step(cfg, params, cache, batch)`` -> (logits, cache)  (decode_*/long_* shapes)

Params are dict pytrees with human-readable keys; sharding rules in
``repro.parallel.sharding`` key off those names.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    """Superset config covering all assigned model families."""

    arch: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # -- attention ----------------------------------------------------------
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0        # stablelm partial rotary
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl M-RoPE
    window: Optional[int] = None      # sliding-window attention (mixtral)
    attn_logit_softcap: Optional[float] = None

    # -- FFN ----------------------------------------------------------------
    act: str = "swiglu"               # swiglu | relu2 | gelu
    norm: str = "rms"                 # rms | ln
    parallel_residual: bool = False

    # -- embeddings ---------------------------------------------------------
    tie_embeddings: bool = False
    use_abs_pos: bool = False         # learned absolute positions (whisper dec)

    # -- MoE ------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0           # deepseek: first k layers use dense FFN
    d_ff_dense: int = 0               # width of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # token stream is split into this many dispatch groups; the launcher
    # shards the group dim over `data` so routing stays shard-local
    moe_dispatch_groups: int = 16

    # -- MLA (deepseek) -------------------------------------------------------
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # -- SSM (mamba2 / zamba2) ------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # -- hybrid (zamba2) --------------------------------------------------------
    shared_attn_period: int = 0       # apply shared attn block every k layers

    # -- enc-dec (whisper) ------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    max_target_positions: int = 8192

    # -- numerics ---------------------------------------------------------------
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    # activation-checkpoint policy for the layer scan: none|full|dots
    remat: str = "full"
    # attention implementation: "chunked" (online-softmax lax loop, the
    # XLA path used for lowering) or "pallas" (TPU kernel path)
    attn_impl: str = "chunked_packed"   # §Perf: causal pair-packing, -32% attn dots
    # §Perf: explicit row-parallel shard_map attention for head-misaligned
    # TP (wins for llama3.2: -152 GB/chip; regresses qwen/whisper)
    attn_row_parallel: bool = False
    attn_q_block: int = 1024
    attn_kv_block: int = 1024
    # logits in fp32 for loss stability
    logits_fp32: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:          # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Any] = {}


def register(family: str):
    def deco(cls):
        _REGISTRY[family] = cls
        return cls
    return deco


def get_model(cfg: ModelConfig):
    """Return the model implementation class for ``cfg.family``."""
    # import for side-effect registration
    from repro.models import transformer, moe, mamba2, zamba2, whisper  # noqa: F401
    try:
        return _REGISTRY[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}; have {sorted(_REGISTRY)}")


def param_count(params) -> int:
    import jax
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
