"""Mamba-2 (state-space duality / SSD) language model, pure JAX.

Implements the chunked SSD algorithm of arXiv:2405.21060 §6: within a chunk
the recurrence is computed in its "attention" (quadratic) dual form; chunk
boundary states are passed through a linear scan.  Decode is the O(1)
recurrent update on a ``[B, H, P, N]`` state.

TPU adaptation note: the chunk size (``cfg.ssm_chunk``) is the VMEM tile of
the Pallas kernel (`repro.kernels.ssd_scan`); this jnp implementation is the
oracle and the lowering path.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel.activations import shard_acts
from repro.models.common import ModelConfig, register
from repro.models.transformer import _stack_init


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """Stable 'segment sum' producing L[i, j] = sum_{k=j+1..i} x[k] (i >= j).

    x: [..., Q] -> [..., Q, Q] lower-triangular log-decay matrix."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_{j+1..i}
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # [B, S, H, P]
    dt: jax.Array,     # [B, S, H]   (already softplus'd, >0)
    A: jax.Array,      # [H]         (negative)
    B_: jax.Array,     # [B, S, G, N]
    C: jax.Array,      # [B, S, G, N]
    *,
    chunk: int,
    init_state: jax.Array | None = None,   # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,H,P], final_state [B,H,P,N]).  fp32 internals."""
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = B_.reshape(Bsz, nc, chunk, G, N).astype(f32)
    Cc = C.reshape(Bsz, nc, chunk, G, N).astype(f32)

    dA = dtc * A.astype(f32)[None, None, None, :]          # [B,nc,Q,H]
    dA_cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum
    dA_total = dA_cum[:, :, -1]                            # [B,nc,H]

    # ---- intra-chunk (dual quadratic form) -------------------------------
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))      # [B,nc,H,Q,Q]
    # scores over groups; broadcast G->H
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)          # [B,nc,G,Q,Q]
    CB = jnp.repeat(CB, rep, axis=2)                       # [B,nc,H,Q,Q]
    M = CB * Lmat
    xdt = xc * dtc[..., None]                              # [B,nc,Q,H,P]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, xdt)

    # ---- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(dA_total[:, :, None, :] - dA_cum)   # [B,nc,Q,H]
    Bh = jnp.repeat(Bc, rep, axis=3)                       # [B,nc,Q,H,N]
    states = jnp.einsum("bcqhn,bcqhp,bcqh->bchpn", Bh, xdt, decay_to_end)

    # ---- inter-chunk scan ---------------------------------------------------
    h0 = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def scan_fn(h, inp):
        st, dA_tot = inp                                   # [B,H,P,N], [B,H]
        h_out = h                                           # state BEFORE chunk
        h_next = h * jnp.exp(dA_tot)[:, :, None, None] + st
        return h_next, h_out

    hT, h_before = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), dA_total.transpose(1, 0, 2)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)           # [B,nc,H,P,N]

    Ch = jnp.repeat(Cc, rep, axis=3)                       # [B,nc,Q,H,N]
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Ch, h_before, jnp.exp(dA_cum))
    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(x.dtype), hT


def ssd_decode_step(
    x: jax.Array,      # [B, 1, H, P]
    dt: jax.Array,     # [B, 1, H]
    A: jax.Array,      # [H]
    B_: jax.Array,     # [B, 1, G, N]
    C: jax.Array,      # [B, 1, G, N]
    state: jax.Array,  # [B, H, P, N] fp32
) -> Tuple[jax.Array, jax.Array]:
    f32 = jnp.float32
    H = x.shape[2]
    rep = H // B_.shape[2]
    xb = x[:, 0].astype(f32)                                # [B,H,P]
    dtb = dt[:, 0].astype(f32)                              # [B,H]
    Bb = jnp.repeat(B_[:, 0], rep, axis=1).astype(f32)      # [B,H,N]
    Cb = jnp.repeat(C[:, 0], rep, axis=1).astype(f32)
    decay = jnp.exp(dtb * A.astype(f32)[None])              # [B,H]
    new_state = (state * decay[..., None, None]
                 + jnp.einsum("bhp,bhn,bh->bhpn", xb, Bb, dtb))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cb)
    return y[:, None].astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block (projections + conv + SSD + gated norm)
# ---------------------------------------------------------------------------


def init_mamba_block(cfg: ModelConfig, key) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    H, G, N = cfg.ssm_heads, cfg.ssm_groups, cfg.ssm_state
    cw = cfg.ssm_conv_width
    ks = jax.random.split(key, 7)
    dt_init = jnp.log(jnp.exp(jnp.linspace(1e-3, 0.1, H)) - 1.0)  # inv softplus
    return {
        "w_z": L.init_linear(ks[0], d, di, cfg.param_dtype),
        "w_x": L.init_linear(ks[1], d, di, cfg.param_dtype),
        "w_B": L.init_linear(ks[2], d, G * N, cfg.param_dtype),
        "w_C": L.init_linear(ks[3], d, G * N, cfg.param_dtype),
        "w_dt": L.init_linear(ks[4], d, H, cfg.param_dtype),
        "dt_bias": dt_init.astype(jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),               # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "conv_x": (jax.random.normal(ks[5], (cw, di), jnp.float32)
                   / math.sqrt(cw)).astype(cfg.param_dtype),
        "conv_B": (jax.random.normal(ks[6], (cw, G * N), jnp.float32)
                   / math.sqrt(cw)).astype(cfg.param_dtype),
        "conv_C": (jax.random.normal(ks[6], (cw, G * N), jnp.float32)
                   / math.sqrt(cw)).astype(cfg.param_dtype),
        "gate_norm": {"scale": jnp.ones((di,), cfg.param_dtype)},
        "w_out": L.init_linear(ks[4], di, d, cfg.param_dtype,
                               scale=1.0 / math.sqrt(di * 2 * cfg.num_layers)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv.  x [B,S,Cd], w [K,Cd].

    Returns (y, new_state) where state is the trailing K-1 inputs."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
            for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def mamba_block_fwd(cfg: ModelConfig, p: Dict, u: jax.Array,
                    state: Dict | None = None):
    """u: [B,S,d].  state (decode): {"ssm": [B,H,P,N] f32, "conv_*": trailing}."""
    Bsz, S, _ = u.shape
    H, G, N, P = cfg.ssm_heads, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_headdim
    dt_ = u.dtype
    z = jnp.einsum("bsd,df->bsf", u, p["w_z"].astype(dt_))
    x = jnp.einsum("bsd,df->bsf", u, p["w_x"].astype(dt_))
    Bp = jnp.einsum("bsd,df->bsf", u, p["w_B"].astype(dt_))
    Cp = jnp.einsum("bsd,df->bsf", u, p["w_C"].astype(dt_))
    dt = jnp.einsum("bsd,df->bsf", u, p["w_dt"].astype(dt_)).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"][None, None])

    cs = {} if state is None else state
    x, cx = _causal_conv(x, p["conv_x"], cs.get("conv_x"))
    Bp, cB = _causal_conv(Bp, p["conv_B"], cs.get("conv_B"))
    Cp, cC = _causal_conv(Cp, p["conv_C"], cs.get("conv_C"))

    xh = x.reshape(Bsz, S, H, P)
    Bh = Bp.reshape(Bsz, S, G, N)
    Ch = Cp.reshape(Bsz, S, G, N)
    A = -jnp.exp(p["A_log"])

    if state is None:
        y, hT = ssd_chunked(xh, dt, A, Bh, Ch, chunk=cfg.ssm_chunk)
    else:
        y, hT = ssd_decode_step(xh, dt, A, Bh, Ch, state["ssm"])
    y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_),
                   p["gate_norm"]["scale"])
    out = jnp.einsum("bsf,fd->bsd", y, p["w_out"].astype(dt_))
    new_state = {"ssm": hT, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return out, new_state


def init_mamba_layer(cfg: ModelConfig, key) -> Dict:
    return {"ln": L.init_norm(cfg, cfg.d_model),
            "mamba": init_mamba_block(cfg, key)}


def mamba_layer_fwd(cfg: ModelConfig, lp: Dict, x: jax.Array, state=None):
    h = L.apply_norm(cfg, lp["ln"], x)
    y, new_state = mamba_block_fwd(cfg, lp["mamba"], h, state)
    return shard_acts(x + y), new_state


@register("ssm")
class Mamba2LM:
    @staticmethod
    def init(cfg: ModelConfig, key) -> Dict:
        ke, kl, kh = jax.random.split(key, 3)
        return {
            "embed": L.init_embed(cfg, ke),
            "layers": _stack_init(lambda k: init_mamba_layer(cfg, k), kl,
                                  cfg.num_layers),
            "final_norm": L.init_norm(cfg, cfg.d_model),
            "lm_head": L.init_linear(kh, cfg.d_model, cfg.vocab_size,
                                     cfg.param_dtype),
        }

    @staticmethod
    def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array) -> jax.Array:
        x = L.embed(cfg, params["embed"], tokens)

        def body(x, lp):
            y, _ = mamba_layer_fwd(cfg, lp, x)
            return y, None

        x, _ = jax.lax.scan(L.remat_wrap(cfg, body), x, params["layers"])
        return L.apply_norm(cfg, params["final_norm"], x)

    @staticmethod
    def loss(cfg: ModelConfig, params: Dict, batch: Dict):
        hidden = Mamba2LM.forward(cfg, params, batch["tokens"])
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        loss = L.softmax_xent(logits, batch["labels"])
        return loss, {"loss": loss}

    # -- inference ----------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
        H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        cw, di, gn = cfg.ssm_conv_width, cfg.d_inner, cfg.ssm_groups * cfg.ssm_state
        Lr = cfg.num_layers
        return {
            "ssm": jnp.zeros((Lr, batch, H, P, N), jnp.float32),
            "conv_x": jnp.zeros((Lr, batch, cw - 1, di), cfg.compute_dtype),
            "conv_B": jnp.zeros((Lr, batch, cw - 1, gn), cfg.compute_dtype),
            "conv_C": jnp.zeros((Lr, batch, cw - 1, gn), cfg.compute_dtype),
            "len": jnp.zeros((), jnp.int32),
        }

    @staticmethod
    def prefill(cfg: ModelConfig, params: Dict, batch: Dict):
        """Prefill = full forward, capturing final recurrent state per layer."""
        tokens = batch["tokens"]
        S = tokens.shape[1]
        x = L.embed(cfg, params["embed"], tokens)

        def body(x, lp):
            h = L.apply_norm(cfg, lp["ln"], x)
            y, st = mamba_block_fwd(cfg, lp["mamba"], h)
            return x + y, (st["ssm"], st["conv_x"], st["conv_B"], st["conv_C"])

        x, (ssm, cx, cB, cC) = jax.lax.scan(L.remat_wrap(cfg, body), x,
                                            params["layers"])
        hidden = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        cache = {"ssm": ssm, "conv_x": cx, "conv_B": cB, "conv_C": cC,
                 "len": jnp.asarray(S, jnp.int32)}
        return logits, cache

    @staticmethod
    def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
        tokens = batch["tokens"]
        x = L.embed(cfg, params["embed"], tokens)

        def body(x, inp):
            lp, ssm, cx, cB, cC = inp
            st = {"ssm": ssm, "conv_x": cx, "conv_B": cB, "conv_C": cC}
            y, st = mamba_layer_fwd(cfg, lp, x, state=st)
            return y, (st["ssm"], st["conv_x"], st["conv_B"], st["conv_C"])

        x, (ssm, cx, cB, cC) = jax.lax.scan(
            body, x, (params["layers"], cache["ssm"], cache["conv_x"],
                      cache["conv_B"], cache["conv_C"]))
        hidden = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.unembed(cfg, params["embed"], params.get("lm_head"), hidden)
        return logits, {"ssm": ssm, "conv_x": cx, "conv_B": cB, "conv_C": cC,
                        "len": cache["len"] + tokens.shape[1]}
