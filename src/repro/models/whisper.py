"""Whisper-large-v3 style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: ``batch["enc_embeds"]``
carries precomputed frame embeddings [B, S_enc, d] (what the two conv layers
would produce).  ``seq_len`` in the assigned shapes is the *encoder frame
count*; the decoder length is ``seq_len // 4`` (see DESIGN.md).

Encoder: bidirectional self-attention + GELU FFN, sinusoidal positions.
Decoder: causal self-attention + cross-attention + GELU FFN, learned
positions.  Decode shapes lower one decoder token against a self-KV cache of
the given length plus the precomputed cross-KV.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel.activations import shard_acts
from repro.models.common import ModelConfig, register
from repro.models.transformer import _stack_init


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper's sinusoidal position embedding."""
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def init_enc_layer(cfg: ModelConfig, key) -> Dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_norm(cfg, cfg.d_model), "attn": L.init_attn(cfg, k1),
            "ln2": L.init_norm(cfg, cfg.d_model), "ffn": L.init_ffn(cfg, k2)}


def init_dec_layer(cfg: ModelConfig, key) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model), "self_attn": L.init_attn(cfg, k1),
        "ln_x": L.init_norm(cfg, cfg.d_model), "cross_attn": L.init_attn(cfg, k2),
        "ln2": L.init_norm(cfg, cfg.d_model), "ffn": L.init_ffn(cfg, k3),
    }


def _no_rope(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(rope_fraction=0.0)     # whisper uses absolute positions


def encode(cfg: ModelConfig, params: Dict, enc_embeds: jax.Array) -> jax.Array:
    B, S, _ = enc_embeds.shape
    cfg_nr = _no_rope(cfg)
    x = enc_embeds.astype(cfg.compute_dtype)
    x = x + sinusoids(S, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(S)

    def body(x, lp):
        h = L.apply_norm(cfg, lp["ln1"], x)
        a, _ = L.attn_block(cfg_nr, lp["attn"], h, positions, causal=False)
        x = x + a
        x = x + L.ffn(cfg, lp["ffn"], L.apply_norm(cfg, lp["ln2"], x))
        return shard_acts(x), None

    x, _ = jax.lax.scan(L.remat_wrap(cfg, body), x, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def _cross_kv(cfg: ModelConfig, params: Dict, memory: jax.Array):
    """Precompute per-decoder-layer cross K/V from encoder output."""
    def one(lp):
        dt = memory.dtype
        k = L._split_heads(jnp.einsum("bsd,df->bsf", memory,
                                      lp["cross_attn"]["wk"].astype(dt)),
                           cfg.n_kv_heads)
        v = L._split_heads(jnp.einsum("bsd,df->bsf", memory,
                                      lp["cross_attn"]["wv"].astype(dt)),
                           cfg.n_kv_heads)
        return k, v
    return jax.vmap(one)(params["dec_layers"])     # [L,B,H,S_enc,hd] each


def dec_layer_fwd(cfg: ModelConfig, lp: Dict, x, positions, cross_k, cross_v,
                  kv_state=None):
    cfg_nr = _no_rope(cfg)
    h = L.apply_norm(cfg, lp["ln1"], x)
    a, new_state = L.attn_block(cfg_nr, lp["self_attn"], h, positions,
                                causal=True, kv_state=kv_state)
    x = x + a
    h = L.apply_norm(cfg, lp["ln_x"], x)
    c, _ = L.attn_block(cfg_nr, lp["cross_attn"], h, positions,
                        cross_kv=(cross_k, cross_v))
    x = x + c
    x = x + L.ffn(cfg, lp["ffn"], L.apply_norm(cfg, lp["ln2"], x))
    return shard_acts(x), new_state


@register("encdec")
class WhisperModel:
    @staticmethod
    def init(cfg: ModelConfig, key) -> Dict:
        ke, k1, k2, kp = jax.random.split(key, 4)
        return {
            "embed": L.init_embed(cfg, ke),          # decoder token embedding
            "pos_embed": (jax.random.normal(kp, (cfg.max_target_positions,
                                                 cfg.d_model), jnp.float32)
                          * 0.01).astype(cfg.param_dtype),
            "enc_layers": _stack_init(lambda k: init_enc_layer(cfg, k), k1,
                                      cfg.enc_layers),
            "enc_norm": L.init_norm(cfg, cfg.d_model),
            "dec_layers": _stack_init(lambda k: init_dec_layer(cfg, k), k2,
                                      cfg.dec_layers),
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }

    @staticmethod
    def decode_fwd(cfg: ModelConfig, params: Dict, tokens, memory):
        B, S = tokens.shape
        positions = jnp.arange(S)
        x = L.embed(cfg, params["embed"], tokens)
        x = x + params["pos_embed"][:S].astype(x.dtype)[None]
        ck, cv = _cross_kv(cfg, params, memory)

        def body(x, inp):
            lp, k, v = inp
            y, _ = dec_layer_fwd(cfg, lp, x, positions, k, v)
            return y, None

        x, _ = jax.lax.scan(L.remat_wrap(cfg, body), x,
                            (params["dec_layers"], ck, cv))
        return L.apply_norm(cfg, params["final_norm"], x)

    @staticmethod
    def loss(cfg: ModelConfig, params: Dict, batch: Dict):
        memory = encode(cfg, params, batch["enc_embeds"])
        hidden = WhisperModel.decode_fwd(cfg, params, batch["tokens"], memory)
        logits = L.unembed(cfg.replace(tie_embeddings=True), params["embed"],
                           None, hidden)           # whisper ties embeddings
        loss = L.softmax_xent(logits, batch["labels"])
        return loss, {"loss": loss}

    # -- inference ----------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   enc_len: int = 1500) -> Dict:
        hd = cfg.resolved_head_dim
        Ld = cfg.dec_layers
        return {
            "k": jnp.zeros((Ld, batch, cfg.n_kv_heads, max_len, hd),
                           cfg.compute_dtype),
            "v": jnp.zeros((Ld, batch, cfg.n_kv_heads, max_len, hd),
                           cfg.compute_dtype),
            "cross_k": jnp.zeros((Ld, batch, cfg.n_kv_heads, enc_len, hd),
                                 cfg.compute_dtype),
            "cross_v": jnp.zeros((Ld, batch, cfg.n_kv_heads, enc_len, hd),
                                 cfg.compute_dtype),
            "len": jnp.zeros((), jnp.int32),
        }

    @staticmethod
    def prefill(cfg: ModelConfig, params: Dict, batch: Dict):
        """Encode + teacher-forced decoder prefill; returns decode-ready cache."""
        memory = encode(cfg, params, batch["enc_embeds"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.arange(S)
        x = L.embed(cfg, params["embed"], tokens)
        x = x + params["pos_embed"][:S].astype(x.dtype)[None]
        ck, cv = _cross_kv(cfg, params, memory)

        def body(x, inp):
            lp, k, v = inp
            y, st = dec_layer_fwd(cfg, lp, x, positions, k, v)
            return y, (st["k"], st["v"])

        x, (ks, vs) = jax.lax.scan(L.remat_wrap(cfg, body), x,
                                   (params["dec_layers"], ck, cv))
        hidden = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
        logits = L.unembed(cfg.replace(tie_embeddings=True), params["embed"],
                           None, hidden)
        cache = {"k": ks, "v": vs, "cross_k": ck, "cross_v": cv,
                 "len": jnp.asarray(S, jnp.int32)}
        return logits, cache

    @staticmethod
    def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
        tokens = batch["tokens"]
        B, S1 = tokens.shape
        cur = cache["len"]
        positions = (cur + jnp.arange(S1))[None, :].repeat(B, 0)
        x = L.embed(cfg, params["embed"], tokens)
        pos_e = jax.lax.dynamic_slice_in_dim(params["pos_embed"], cur, S1, 0)
        x = x + pos_e.astype(x.dtype)[None]

        def body(x, inp):
            lp, k0, v0, ck, cv = inp
            st = {"k": k0, "v": v0, "len": cur}
            y, st = dec_layer_fwd(cfg, lp, x, positions, ck, cv, kv_state=st)
            return y, (st["k"], st["v"])

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        hidden = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.unembed(cfg.replace(tie_embeddings=True), params["embed"],
                           None, hidden)
        return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                        "cross_v": cache["cross_v"], "len": cur + S1}
