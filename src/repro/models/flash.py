"""Flash-style chunked attention with a custom VJP (pure jnp / XLA path).

Forward: online-softmax over KV blocks inside a q-block loop; residuals are
ONLY (q, k, v, out, lse) — per-block probabilities are never materialized,
which is what keeps long-context train/prefill within HBM (the naive scan
saves an [nq, nk, B, H, qb, kb] probability stack for backward).

Backward: one pass over q blocks (lax.scan); for each q block an inner scan
over kv blocks recomputes s = qk^T and p = exp(s - lse), accumulating
  dq(block)  = Σ_j dS_ij · k_j
  dk_j      += dS_ij^T · q_i         (scatter into the carried dK buffer)
  dv_j      += p_ij^T · dO_i
This mirrors the Pallas kernel structure (repro.kernels.flash_attention);
the kernel and this implementation validate against the same oracle.

``causal_pack=True`` (beyond-paper §Perf optimization) pairs q block i with
q block nq-1-i so the causal triangle is computed without ~2× masked waste;
it applies to the forward pass (the backward always visits the full
rectangle per q block when packing is off; with packing on, the backward
inner loop spans only the causal range via the same pairing).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _mask_block(qpos, kpos, causal, window):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    m &= (qpos >= 0)[:, None]
    m &= (kpos < jnp.iinfo(jnp.int32).max)[None, :]
    return m


def _prep(q, k, v, q_positions, kv_positions, q_block, kv_block):
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    pad_q = (-Sq) % qb
    pad_k = (-Skv) % kb
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    qpos = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    kpos = jnp.pad(kv_positions, (0, pad_k),
                   constant_values=jnp.iinfo(jnp.int32).max)
    nq = qp.shape[2] // qb
    nk = kp.shape[2] // kb
    qp = qp.reshape(B, Hkv, G, nq, qb, D).transpose(3, 0, 1, 2, 4, 5)
    kp = kp.reshape(B, Hkv, nk, kb, D).transpose(2, 0, 1, 3, 4)
    vp = vp.reshape(B, Hkv, nk, kb, Dv).transpose(2, 0, 1, 3, 4)
    return qp, kp, vp, qpos.reshape(nq, qb), kpos.reshape(nk, kb), (
        B, Hq, Hkv, G, Sq, Skv, D, Dv, qb, kb, nq, nk)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_qblock(q_blk, qpos_blk, kp, vp, kpos_b, *, scale, causal, window,
                kv_lo=None, kv_hi=None):
    """Online softmax of one q block against all kv blocks.

    Returns (out_unnormalized... actually normalized out, m, l)."""
    B, Hkv, G, qb, D = q_blk.shape
    Dv = vp.shape[-1]

    def kv_step(carry, inp):
        acc, m, l = carry
        k_blk, v_blk, kpos_blk, kv_idx = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                       k_blk.astype(jnp.float32)) * scale
        mask = _mask_block(qpos_blk, kpos_blk, causal, window)
        if kv_lo is not None:
            mask &= (kv_idx >= kv_lo) & (kv_idx < kv_hi)
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    nk = kp.shape[0]
    init = (jnp.zeros((B, Hkv, G, qb, Dv), jnp.float32),
            jnp.full((B, Hkv, G, qb), -jnp.inf, jnp.float32),
            jnp.zeros((B, Hkv, G, qb), jnp.float32))
    (acc, m, l), _ = jax.lax.scan(kv_step, init,
                                  (kp, vp, kpos_b, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(jnp.isfinite(m), m + jnp.log(jnp.maximum(l, 1e-30)),
                    -jnp.inf)
    return out, lse


def _flash_fwd(q, k, v, q_positions, kv_positions, causal, window,
               q_block, kv_block, causal_pack):
    qp, kp, vp, qpos_b, kpos_b, dims = _prep(
        q, k, v, q_positions, kv_positions, q_block, kv_block)
    B, Hq, Hkv, G, Sq, Skv, D, Dv, qb, kb, nq, nk = dims
    scale = 1.0 / math.sqrt(D)

    if not (causal and causal_pack and nq == nk and nq > 1):
        def per_q(args):
            q_blk, qpos_blk = args
            return _fwd_qblock(q_blk, qpos_blk, kp, vp, kpos_b, scale=scale,
                               causal=causal, window=window)
        out, lse = jax.lax.map(per_q, (qp, qpos_b))
    else:
        npairs = (nq + 1) // 2
        idx_lo = jnp.arange(npairs)
        idx_hi = nq - 1 - idx_lo

        def per_pair(pair):
            i_lo, i_hi = pair
            q_lo, qpos_lo = qp[i_lo], qpos_b[i_lo]
            q_hi, qpos_hi = qp[i_hi], qpos_b[i_hi]
            zero = lambda: (
                jnp.zeros((B, Hkv, G, qb, Dv), jnp.float32),
                jnp.full((B, Hkv, G, qb), -jnp.inf, jnp.float32),
                jnp.zeros((B, Hkv, G, qb), jnp.float32))

            def step(carry, s_idx):
                c_lo, c_hi = carry
                serve_lo = s_idx <= i_lo
                kv_idx = jnp.where(serve_lo, s_idx, s_idx - i_lo - 1)
                kv_idx = jnp.clip(kv_idx, 0, nk - 1)
                k_blk = jax.lax.dynamic_index_in_dim(kp, kv_idx, 0, False)
                v_blk = jax.lax.dynamic_index_in_dim(vp, kv_idx, 0, False)
                kpos_blk = jax.lax.dynamic_index_in_dim(kpos_b, kv_idx, 0, False)
                q_blk = jnp.where(serve_lo, q_lo, q_hi)
                qpos_blk = jnp.where(serve_lo, qpos_lo, qpos_hi)
                acc, m, l = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(serve_lo, a, b), c_lo, c_hi)
                s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                               k_blk.astype(jnp.float32)) * scale
                mask = _mask_block(qpos_blk, kpos_blk, causal, window)
                s = jnp.where(mask[None, None, None], s, _NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
                p = jnp.exp(s - m_safe[..., None])
                p = jnp.where(mask[None, None, None], p, 0.0)
                corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
                upd = (acc * corr[..., None] + jnp.einsum(
                    "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32)),
                    m_new, l * corr + jnp.sum(p, axis=-1))
                c_lo = jax.tree_util.tree_map(
                    lambda old, new: jnp.where(serve_lo, new, old), c_lo, upd)
                c_hi = jax.tree_util.tree_map(
                    lambda old, new: jnp.where(serve_lo, old, new), c_hi, upd)
                return (c_lo, c_hi), None

            (c_lo, c_hi), _ = jax.lax.scan(step, (zero(), zero()),
                                           jnp.arange(nq + 1))
            fin = lambda c: (
                c[0] / jnp.maximum(c[2], 1e-30)[..., None],
                jnp.where(jnp.isfinite(c[1]),
                          c[1] + jnp.log(jnp.maximum(c[2], 1e-30)), -jnp.inf))
            (o_lo, l_lo), (o_hi, l_hi) = fin(c_lo), fin(c_hi)
            return o_lo, l_lo, o_hi, l_hi

        o_lo, l_lo, o_hi, l_hi = jax.lax.map(per_pair, (idx_lo, idx_hi))
        out = jnp.zeros((nq, B, Hkv, G, qb, Dv), jnp.float32)
        lse = jnp.zeros((nq, B, Hkv, G, qb), jnp.float32)
        out = out.at[idx_lo].set(o_lo).at[idx_hi].set(o_hi)
        lse = lse.at[idx_lo].set(l_lo).at[idx_hi].set(l_hi)

    # out: [nq, B, Hkv, G, qb, Dv] -> [B, Hq, Sq, Dv]
    o = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv * G, nq * qb, Dv)
    o = o[:, :, :Sq].astype(q.dtype)
    lse_full = lse.transpose(1, 2, 3, 0, 4).reshape(B, Hkv * G, nq * qb)[:, :, :Sq]
    return o, lse_full


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _flash_bwd_impl(q, k, v, out, lse, do, q_positions, kv_positions,
                    causal, window, q_block, kv_block):
    qp, kp, vp, qpos_b, kpos_b, dims = _prep(
        q, k, v, q_positions, kv_positions, q_block, kv_block)
    B, Hq, Hkv, G, Sq, Skv, D, Dv, qb, kb, nq, nk = dims
    scale = 1.0 / math.sqrt(D)

    pad_q = nq * qb - Sq
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    outp = jnp.pad(out, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)), constant_values=0.0)
    # delta = rowsum(dO * O)   [B, Hq, Sq]
    delta = jnp.sum(dop.astype(jnp.float32) * outp.astype(jnp.float32), -1)
    resh_q = lambda x, last: x.reshape(B, Hkv, G, nq, qb, last).transpose(
        3, 0, 1, 2, 4, 5)
    dop_b = resh_q(dop, Dv)
    delta_b = delta.reshape(B, Hkv, G, nq, qb).transpose(3, 0, 1, 2, 4)
    lse_b = lsep.reshape(B, Hkv, G, nq, qb).transpose(3, 0, 1, 2, 4)

    dK0 = jnp.zeros((nk, B, Hkv, kb, D), jnp.float32)
    dV0 = jnp.zeros((nk, B, Hkv, kb, Dv), jnp.float32)

    def q_step(carry, inp):
        dK, dV = carry
        q_blk, qpos_blk, do_blk, dl_blk, lse_blk = inp

        def kv_step(kcarry, kv_idx):
            dK, dV, dq = kcarry
            k_blk = jax.lax.dynamic_index_in_dim(kp, kv_idx, 0, False)
            v_blk = jax.lax.dynamic_index_in_dim(vp, kv_idx, 0, False)
            kpos_blk = jax.lax.dynamic_index_in_dim(kpos_b, kv_idx, 0, False)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            mask = _mask_block(qpos_blk, kpos_blk, causal, window)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lse_blk[..., None]), 0.0)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_blk.astype(jnp.float32),
                            v_blk.astype(jnp.float32))
            ds = p * (dp - dl_blk[..., None]) * scale
            dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds,
                                 k_blk.astype(jnp.float32))
            dk_c = jnp.einsum("bhgqk,bhgqd->bhkd", ds, q_blk.astype(jnp.float32))
            dv_c = jnp.einsum("bhgqk,bhgqd->bhkd", p, do_blk.astype(jnp.float32))
            dK = dK.at[kv_idx].add(dk_c)
            dV = dV.at[kv_idx].add(dv_c)
            return (dK, dV, dq), None

        dq0 = jnp.zeros((B, Hkv, G, qb, D), jnp.float32)
        (dK, dV, dq), _ = jax.lax.scan(kv_step, (dK, dV, dq0), jnp.arange(nk))
        return (dK, dV), dq

    (dK, dV), dQ = jax.lax.scan(
        q_step, (dK0, dV0), (qp, qpos_b, dop_b, delta_b, lse_b))

    dq = dQ.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, nq * qb, D)[:, :, :Sq]
    dk = dK.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, nk * kb, D)[:, :, :Skv]
    dv = dV.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, nk * kb, Dv)[:, :, :Skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def flash_attention(q, k, v, q_positions, kv_positions,
                    causal=True, window=None,
                    q_block=1024, kv_block=1024, causal_pack=False):
    out, _ = _flash_fwd(q, k, v, q_positions, kv_positions, causal, window,
                        q_block, kv_block, causal_pack)
    return out


def _vjp_fwd(q, k, v, q_positions, kv_positions,
             causal, window, q_block, kv_block, causal_pack):
    out, lse = _flash_fwd(q, k, v, q_positions, kv_positions, causal, window,
                          q_block, kv_block, causal_pack)
    return out, (q, k, v, out, lse, q_positions, kv_positions)


def _vjp_bwd(causal, window, q_block, kv_block, causal_pack, res, do):
    q, k, v, out, lse, q_positions, kv_positions = res
    dq, dk, dv = _flash_bwd_impl(q, k, v, out, lse, do, q_positions,
                                 kv_positions, causal, window,
                                 q_block, kv_block)
    return dq, dk, dv, None, None


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
