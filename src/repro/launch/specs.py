"""ShapeDtypeStruct stand-ins for every (architecture × input-shape) cell.

No device allocation — these are the abstract inputs for ``.lower()``.
Shape semantics per the assignment:
  train_4k     seq=4096   global_batch=256   -> train_step
  prefill_32k  seq=32768  global_batch=32    -> prefill_step
  decode_32k   seq=32768  global_batch=128   -> decode_step (1 new token, KV cache=seq)
  long_500k    seq=524288 global_batch=1     -> decode_step; sub-quadratic archs only

Whisper convention (DESIGN.md): assigned seq = encoder frames; decoder
length = seq // 4; decode cells use self-KV seq//4 + cross-KV seq.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, get_model

SHAPES: Dict[str, Tuple[str, int, int]] = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}

# archs with sub-quadratic attention state (SSM / hybrid / SWA) — the only
# ones that run long_500k (per the assignment; skips noted in DESIGN.md §4)
LONG_OK = {"mamba2-1.3b", "zamba2-1.2b", "mixtral-8x22b"}


def cell_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_OK:
        return False, "pure full-attention arch: 500k KV infeasible (skip per brief)"
    return True, ""


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def batch_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch for the given cell (the model-input side)."""
    kind, S, B = SHAPES[shape_name]
    if cfg.family == "encdec":
        Sd = max(S // 4, 8)
        if kind == "train":
            return {"enc_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                    "tokens": _i32(B, Sd), "labels": _i32(B, Sd)}
        if kind == "prefill":
            return {"enc_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                    "tokens": _i32(B, Sd)}
        return {"tokens": _i32(B, 1)}
    if kind == "train":
        out = {"tokens": _i32(B, S), "labels": _i32(B, S)}
        if cfg.family == "vlm":
            out["positions"] = _i32(B, 3, S)
        return out
    if kind == "prefill":
        return {"tokens": _i32(B, S)}
    return {"tokens": _i32(B, 1)}


def cache_specs(cfg: ModelConfig, shape_name: str):
    """Abstract KV/state cache for decode cells (via eval_shape, no alloc)."""
    kind, S, B = SHAPES[shape_name]
    assert kind == "decode"
    model = get_model(cfg)
    if cfg.family == "encdec":
        fn = partial(model.init_cache, cfg, B, max(S // 4, 8), enc_len=S)
    else:
        fn = partial(model.init_cache, cfg, B, S)
    return jax.eval_shape(fn)


def params_shapes(cfg: ModelConfig):
    model = get_model(cfg)
    return jax.eval_shape(partial(model.init, cfg), jax.random.PRNGKey(0))


def default_grad_accum(cfg: ModelConfig, shape_name: str) -> int:
    """Microbatch count: keep per-µb logits+activations modest."""
    kind, S, B = SHAPES[shape_name]
    if kind != "train":
        return 1
    if cfg.arch == "mixtral-8x22b":
        return 16          # §Perf: halves per-µb activation footprint -> fits HBM
    return 8 if B >= 64 else 1
