import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.

DOC = """Multi-pod dry-run: lower + compile every (architecture × input-shape × mesh)
cell against the production mesh, with 512 placeholder CPU devices standing in
for the 2×256-chip TPU v5e pods.

For each cell we record:
  * compile wall time, per-device memory analysis (proves it fits),
  * cost_analysis (raw XLA numbers; NOTE: while-bodies counted once),
  * trip-scaled dot FLOPs + collective wire bytes from the HLO parser
    (repro.analysis.hlo) — these feed EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
Hillclimb knobs: --no-fsdp --remat=none|dots|full --attn=chunked|chunked_packed
                 --grad-accum N --fsdp-pod --tag label
"""

import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis.hlo import analyze_hlo
from repro.configs import ALL_ARCHS, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step, make_prefill_step, make_decode_step
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.activations import set_activation_sharding
from repro.parallel.sharding import (
    ShardingPolicy, attach, make_batch_specs, make_cache_specs,
    make_opt_specs, make_param_specs)


def build_policy(multi_pod: bool, fsdp: bool, fsdp_pod: bool) -> ShardingPolicy:
    dp = ("pod", "data") if multi_pod else ("data",)
    fa = (("pod", "data") if (fsdp_pod and multi_pod) else ("data",))
    return ShardingPolicy(fsdp=fsdp, fsdp_axes=fa, dp_axes=dp)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               fsdp: bool = True, fsdp_pod: bool = False,
               remat: str | None = None, attn: str | None = None,
               grad_accum: int | None = None, save_hlo: Path | None = None,
               extra_cfg: dict | None = None) -> dict:
    """Lower + compile one cell; return the result record."""
    t0 = time.time()
    cfg = get_config(arch)
    if remat:
        cfg = cfg.replace(remat=remat)
    if attn:
        cfg = cfg.replace(attn_impl=attn)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    kind, seq, batch = S.SHAPES[shape_name]
    ok, reason = S.cell_applicable(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "kind": kind,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "fsdp": fsdp, "remat": cfg.remat, "attn": cfg.attn_impl}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    pol = build_policy(multi_pod, fsdp, fsdp_pod)
    dp_size = 1
    for a in pol.dp_axes:
        dp_size *= mesh.shape[a]
    set_activation_sharding(dp=pol.dp_entry(), dp_size=dp_size,
                            tp=pol.tp_axis, tp_size=mesh.shape[pol.tp_axis],
                            mesh=mesh, fsdp=pol.fsdp_entry())

    pshapes = S.params_shapes(cfg)
    pspecs = make_param_specs(cfg, pshapes, mesh, pol)
    p_in = attach(mesh, pshapes, pspecs)

    bshapes = S.batch_specs(cfg, shape_name)
    bspecs = make_batch_specs(cfg, bshapes, mesh, pol)
    b_in = attach(mesh, bshapes, bspecs)

    if kind == "train":
        ga = grad_accum if grad_accum is not None else S.default_grad_accum(cfg, shape_name)
        rec["grad_accum"] = ga
        step = make_train_step(cfg, AdamWConfig(), grad_accum=ga,
                               dp_entry=pol.dp_entry(), grad_specs=pspecs)
        oshapes = jax.eval_shape(adamw_init, pshapes)
        ospecs = make_opt_specs(pspecs)
        o_in = attach(mesh, oshapes, ospecs)
        args = (p_in, o_in, b_in)
        jfn = jax.jit(step, donate_argnums=(0, 1))
    elif kind == "prefill":
        step = make_prefill_step(cfg)
        args = (p_in, b_in)
        jfn = jax.jit(step)
    else:
        step = make_decode_step(cfg)
        cshapes = S.cache_specs(cfg, shape_name)
        cspecs = make_cache_specs(cfg, cshapes, mesh, pol)
        c_in = attach(mesh, cshapes, cspecs)
        args = (p_in, c_in, b_in)
        jfn = jax.jit(step, donate_argnums=(1,))

    try:
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo_text = compiled.as_text()
        hs = analyze_hlo(hlo_text)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={k: int(getattr(ma, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes") if hasattr(ma, k)},
            cost={k: float(v) for k, v in ca.items()
                  if k in ("flops", "bytes accessed", "transcendentals")},
            hlo=hs.to_json(),
        )
        if save_hlo is not None:
            save_hlo.parent.mkdir(parents=True, exist_ok=True)
            with gzip.open(save_hlo, "wt") as f:
                f.write(hlo_text)
    except Exception as e:  # noqa: BLE001 — record the failure, keep the matrix going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fsdp-pod", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--attn", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--cfg", default=None, help="extra cfg overrides k=v,k=v")
    args = ap.parse_args()

    extra = {}
    if args.cfg:
        for kv in args.cfg.split(","):
            k, v = kv.split("=")
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
            extra[k] = v

    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(S.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    outfile = outdir / f"dryrun_{args.tag}.jsonl"
    done = set()
    if outfile.exists():
        for line in outfile.read_text().splitlines():
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))
            except Exception:
                pass

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape, mesh_name) in done:
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...", flush=True)
                hlo_path = (outdir / "hlo" / f"{args.tag}_{arch}_{shape}_{mesh_name}.txt.gz"
                            if args.save_hlo else None)
                rec = lower_cell(
                    arch, shape, multi_pod=mp, fsdp=not args.no_fsdp,
                    fsdp_pod=args.fsdp_pod, remat=args.remat, attn=args.attn,
                    grad_accum=args.grad_accum, save_hlo=hlo_path,
                    extra_cfg=extra or None)
                rec["tag"] = args.tag
                with open(outfile, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                status = rec.get("status")
                extra_info = (f" compile={rec.get('compile_s')}s"
                              f" temp={rec.get('memory', {}).get('temp_size_in_bytes', 0)/2**30:.2f}GiB"
                              if status == "ok" else rec.get("error", rec.get("reason", "")))
                print(f"[dryrun]   -> {status}{extra_info}", flush=True)


if __name__ == "__main__":
    main()
