"""Training launcher: any assigned architecture on the local device set.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --preset smoke --steps 50 --deadline 1800

On a real pod this binary runs once per host (jax.distributed); here it
drives whatever jax.devices() exposes.  The deadline flows into the paper's
Eq.-10 estimator, which logs the minimum chip allocation for the completion
goal as training progresses (the fleet controller consumes the same signal,
see repro.elastic).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.data import DataConfig, ShardedDataset, make_batch_iter
from repro.elastic.fleet import EstimatorBridge
from repro.launch.steps import make_train_step
from repro.models.common import get_model
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.activations import set_activation_sharding
from repro.parallel.sharding import (ShardingPolicy, make_opt_specs,
                                     make_param_specs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ALL_ARCHS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--deadline", type=float, default=3600.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-parallel size (0 = all devices)")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.preset == "smoke"
           else get_config(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("use a seq2seq driver for whisper (see examples)")
    model = get_model(cfg)

    ndev = len(jax.devices())
    dp = args.data_axis or ndev
    mesh = Mesh(np.array(jax.devices()[:dp]).reshape(dp, 1), ("data", "model"))
    pol = ShardingPolicy(fsdp=dp > 1)
    set_activation_sharding(dp="data", dp_size=dp, tp="model", tp_size=1,
                            mesh=mesh, fsdp=pol.fsdp_entry())

    params = model.init(cfg, jax.random.PRNGKey(0))
    pshapes = jax.eval_shape(lambda p: p, params)
    pspecs = make_param_specs(cfg, pshapes, mesh, pol)
    params = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs)
    opt = adamw_init(params)
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {args.arch} ({n/1e6:.1f}M params) on {dp} device(s)")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, num_shards=64)
    ds = ShardedDataset(data, num_hosts=max(dp // 4, 1))
    batches = make_batch_iter(ds, hosts=list(range(max(dp // 4, 1))))

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=args.grad_accum,
                                      dp_entry="data", grad_specs=pspecs))

    ck = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = (latest_step(args.ckpt_dir) or 0) if args.ckpt_dir else 0
    if start:
        state = restore_checkpoint(args.ckpt_dir, start,
                                   {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"[train] restored step {start}")

    t_run = time.time()
    times = []
    with mesh:
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
            t0 = time.time()
            params, opt, metrics = step_fn(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            times.append(time.time() - t0)
            if i % 10 == 0 or i == args.steps - 1:
                t_step = sum(times[-10:]) / len(times[-10:])
                chips = EstimatorBridge.demand(
                    max(args.steps - i - 1, 1), t_step, dp,
                    args.deadline - (time.time() - t_run), total_chips=256)
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"({t_step*1e3:.0f} ms/step, Eq.10 min-chips={chips})")
            if ck and i and i % args.ckpt_every == 0:
                ck.save(i, {"params": params, "opt": opt})
    if ck:
        ck.save(args.steps, {"params": params, "opt": opt})
        ck.wait()
    toks = (args.steps - start) * args.batch * args.seq
    print(f"[train] done: {toks/(time.time()-t_run):.0f} tok/s, "
          f"data locality {ds.locality_rate():.0%}")


if __name__ == "__main__":
    main()
