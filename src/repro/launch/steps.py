"""Step builders: train / prefill / decode, shared by the launcher, the
dry-run and the examples.

``train_step`` does gradient accumulation over ``grad_accum`` microbatches —
the framework analogue of the paper's map tasks (each microbatch is one "map
task"; the gradient reduce-scatter + optimizer update is the "reduce" phase;
see DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, get_model
from repro.optim import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_accum: int = 1, dp_entry=None, grad_specs=None):
    model = get_model(cfg)

    def loss_fn(params, mb):
        loss, _ = model.loss(cfg, params, mb)
        return loss

    def constrain_grads(g):
        # keep per-µb grads in the params' sharding so GSPMD emits
        # reduce-scatters instead of all-reduce + slice (§Perf iteration)
        if grad_specs is None:
            return g
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, g, grad_specs)

    def train_step(params, opt_state, batch):
        M = grad_accum
        if M <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
            grads = constrain_grads(grads)
        else:
            def resh(x):
                y = x.reshape((M, x.shape[0] // M) + x.shape[1:])
                if dp_entry is not None:
                    spec = jax.sharding.PartitionSpec(
                        None, dp_entry, *([None] * (y.ndim - 2)))
                    y = jax.lax.with_sharding_constraint(y, spec)
                return y
            mbs = jax.tree_util.tree_map(resh, batch)

            def body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g = constrain_grads(g)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / M, gsum)
            loss = lsum / M

        new_params, new_opt = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(cfg, params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    model = get_model(cfg)

    def decode_step(params, cache, batch):
        return model.decode_step(cfg, params, cache, batch)

    return decode_step
