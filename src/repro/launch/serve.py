"""Serving launcher: batched prefill + KV-cache decode for any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.common import get_model


def pad_cache_to(cache, max_len: int, seq_keys=("k", "v", "attn_k", "attn_v",
                                                "c_kv", "k_rope")):
    """Grow the seq dim of a prefill cache so decode can append."""
    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k in seq_keys and hasattr(v, "ndim") and v.ndim >= 3:
                seq_ax = v.ndim - 2
                pad = max_len - v.shape[seq_ax]
                if pad > 0:
                    pads = [(0, 0)] * v.ndim
                    pads[seq_ax] = (0, pad)
                    v = jnp.pad(v, pads)
                out[k] = v
            else:
                out[k] = v
        return out
    return walk(cache)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ALL_ARCHS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.preset == "smoke"
           else get_config(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("whisper serving needs audio frontend inputs; "
                         "see tests/test_models_smoke.py for the API")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    cache = pad_cache_to(cache, args.prompt_len + args.gen)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    key = jax.random.PRNGKey(2)

    def sample(logits, key):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return jax.random.categorical(key, logits[:, -1] / args.temperature
                                      )[:, None]

    tok = sample(logits, key)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode(params, cache, {"tokens": tok})
        tok = sample(logits, sub)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] {args.arch}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f} ms; decode {args.gen-1} steps in "
          f"{t_decode*1e3:.0f} ms ({args.batch*(args.gen-1)/t_decode:.0f} tok/s)")
    print("[serve] sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
