"""Production mesh builders.

Functions, not module-level constants, so importing never touches jax device
state.  The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
*before* any jax import; smoke tests and benches see the single real device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data","model").
    Two pods: 2x16x16 = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for unit tests on the real device set."""
    return jax.make_mesh((data, model), ("data", "model"))
