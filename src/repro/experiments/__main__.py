"""CLI for the trace-driven experiment harness.

Subcommands::

    generate   synthesize a trace (preset or custom knobs) to a JSONL file
    import     convert a SWIM/Facebook-format cluster log to repro-trace/v1
    run        sweep a (trace x cluster x policy x seeds) grid, cached
    compare    run two policies on the same grid, paired-bootstrap stats
    regimes    fleet-scale preset x cluster-shape atlas (regime report)
    surrogate  sweep a preset grid through the batched fluid engine
               (calibrated cells only by default) and print per-policy
               estimates plus the calibration error vs paired oracle cells
    explain    replay one atlas cell with the decision-trace bus on and
               print a decision-attribution summary (park/latch story)
    paper      reproduce the paper's §5 evaluation and check its claims
    policies   list the registered scheduler policies (--smoke: run each
               on a tiny cluster and flag stranded work)
    faults     list the named fault-injection profiles (--faults values)
    serve      list the named serving profiles (--serve values)

Scheduler arguments accept either a registered policy name (``proposed``,
``adaptive``, ``adaptive_ra``, ``delay``, ``fair``, ``fifo``, ...) or an
inline policy JSON object, e.g. ``'{"name": "delay", "params":
{"locality_delay": 4}}'`` — see ``repro.core.policies``.

Examples::

    PYTHONPATH=src python -m repro.experiments generate --preset bursty \
        --seed 0 --out traces/bursty.jsonl
    PYTHONPATH=src python -m repro.experiments import --log cluster.tsv \
        --out traces/cluster.jsonl
    PYTHONPATH=src python -m repro.experiments run --trace traces/bursty.jsonl \
        --schedulers proposed fair --seeds 0:3 --machines 20 --vms 2
    PYTHONPATH=src python -m repro.experiments compare --preset mix_small \
        --a proposed --b fair --seeds 0:5
    PYTHONPATH=src python -m repro.experiments regimes --quick
    PYTHONPATH=src python -m repro.experiments paper --quick
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from pathlib import Path
from typing import List, Tuple

from repro.core.policies import (PolicyError, PolicySpec,
                                 registered_policies, smoke_test_policies)
from repro.core.types import ClusterSpec
from repro.experiments import regimes as regimes_mod
from repro.experiments.paperfig import (FULL_SEEDS, QUICK_SEEDS, run_paper)
from repro.experiments.runner import (ExperimentSpec, TraceRef, run_experiment)
from repro.experiments.stats import (compare_completion_by_workload,
                                     compare_deadlines, compare_throughput)
from repro.simcluster.largescale import FLEET_SHAPES
from repro.simcluster.traces import (PRESETS, Trace, TraceConfig,
                                     TraceImportError, generate_trace,
                                     import_swim_file, paper_trace)

DEFAULT_CACHE = Path(".exp-cache")


def _parse_seeds(tokens: List[str]) -> Tuple[int, ...]:
    """Accept explicit seeds and half-open ``a:b`` ranges: ``0 1 4:8``."""
    out: List[int] = []
    for tok in tokens:
        if ":" in tok:
            a, b = tok.split(":", 1)
            out.extend(range(int(a), int(b)))
        else:
            out.append(int(tok))
    if not out:
        raise argparse.ArgumentTypeError("no seeds given")
    return tuple(dict.fromkeys(out))    # dedup, keep order


def _parse_policy(token: str) -> PolicySpec:
    """A scheduler CLI token: registered name or inline policy JSON."""
    try:
        return PolicySpec.parse(token)
    except PolicyError as e:
        raise SystemExit(f"bad policy {token!r}: {e}")


def _parse_faults(token):
    """A --faults CLI token: named profile from ``FAULT_PROFILES`` or an
    inline ``FaultConfig`` JSON object."""
    from repro.core.types import FaultConfig
    if token in regimes_mod.FAULT_PROFILES:
        return regimes_mod.FAULT_PROFILES[token]
    if token.lstrip().startswith("{"):
        import json
        try:
            return FaultConfig.from_dict(json.loads(token))
        except (ValueError, TypeError) as e:
            raise SystemExit(f"bad fault config {token!r}: {e}")
    raise SystemExit(
        f"bad --faults {token!r}: expected a profile name "
        f"({', '.join(regimes_mod.FAULT_PROFILES)}) or FaultConfig JSON")


def _parse_serve(token, machines: int):
    """A --serve CLI token: named profile from ``SERVE_PROFILES`` (scaled
    to the cluster's machine count) or an inline ``ServeConfig`` JSON."""
    from repro.core.types import ServeConfig
    if token in regimes_mod.SERVE_PROFILES:
        return regimes_mod.serve_profile(token, machines)
    if token.lstrip().startswith("{"):
        import json
        try:
            return ServeConfig.from_dict(json.loads(token))
        except (ValueError, TypeError) as e:
            raise SystemExit(f"bad serve config {token!r}: {e}")
    raise SystemExit(
        f"bad --serve {token!r}: expected a profile name "
        f"({', '.join(regimes_mod.SERVE_PROFILES)}) or ServeConfig JSON")


def _cluster_from_args(args) -> ClusterSpec:
    spec = ClusterSpec(num_machines=args.machines,
                       vms_per_machine=args.vms,
                       replication=args.replication,
                       remote_penalty_scale=args.remote_penalty_scale)
    if getattr(args, "faults", None):
        spec = dataclasses.replace(spec, faults=_parse_faults(args.faults))
    if getattr(args, "serve", None):
        spec = dataclasses.replace(
            spec, serve=_parse_serve(args.serve, args.machines))
    return spec


def _trace_ref_from_args(args) -> TraceRef:
    if args.trace is not None:
        return TraceRef(path=str(args.trace))
    if args.preset is not None:
        return TraceRef(preset=args.preset,
                        seed=getattr(args, "trace_seed", None))
    raise SystemExit("one of --trace / --preset is required")


def _add_grid_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--trace", type=Path, default=None,
                   help="trace JSONL file (from `generate`)")
    p.add_argument("--preset", default=None,
                   help="named trace preset: paper, "
                        + ", ".join(sorted(PRESETS)))
    p.add_argument("--trace-seed", type=int, default=None,
                   help="pin the trace seed (default: couple to each sim seed)")
    p.add_argument("--seeds", nargs="+", default=["0"],
                   help="sim seeds; accepts `a:b` ranges (default: 0)")
    p.add_argument("--machines", type=int, default=20)
    p.add_argument("--vms", type=int, default=2)
    p.add_argument("--replication", type=int, default=1)
    p.add_argument("--remote-penalty-scale", type=float, default=1.0,
                   help="network-fabric calibration of the remote-read "
                        "penalty (1.0 = 1GbE, 0.25 ~ 10GbE, 0.0625 ~ 40GbE)")
    p.add_argument("--faults", default=None,
                   help="fault-injection profile (churn_lo, churn_hi, "
                        "churn_hetero) or inline FaultConfig JSON, e.g. "
                        '\'{"enabled": true, "crash_mtbf": 1800}\'')
    p.add_argument("--serve", default=None,
                   help="co-located serving profile ("
                        + ", ".join(regimes_mod.SERVE_PROFILES)
                        + ") or inline ServeConfig JSON (see `serve --list`)")
    p.add_argument("--cache", type=Path, default=DEFAULT_CACHE,
                   help=f"result cache directory (default: {DEFAULT_CACHE})")
    p.add_argument("--workers", type=int, default=0,
                   help="multiprocessing pool size; 0 = inline (default)")


def cmd_generate(args) -> int:
    if args.preset == "paper":
        if args.num_jobs is not None:
            raise SystemExit("--num-jobs is incompatible with --preset paper "
                             "(the Table-2 mix is fixed at 5 jobs)")
        trace = paper_trace(args.seed)
    else:
        if args.preset is None:
            config = TraceConfig()
        elif args.preset in PRESETS:
            config = PRESETS[args.preset]
        else:
            raise SystemExit(f"unknown preset {args.preset!r}; available: "
                             f"paper, {', '.join(sorted(PRESETS))}")
        if args.num_jobs is not None:
            config = dataclasses.replace(config, num_jobs=args.num_jobs)
        trace = generate_trace(config, args.seed)
    path = trace.save(args.out)
    counts = ", ".join(f"{w}:{c}" for w, c in
                       sorted(trace.workload_counts().items()))
    print(f"wrote {path}: {len(trace.jobs)} jobs over "
          f"{trace.duration():.0f}s, {trace.total_input_gb():.1f} GB total "
          f"({counts})")
    return 0


def cmd_import(args) -> int:
    try:
        trace = import_swim_file(
            args.log,
            **({"name": args.name} if args.name else {}),
            deadline_slack=args.deadline_slack,
            skew=args.skew,
            max_jobs=args.max_jobs)
    except TraceImportError as e:
        raise SystemExit(f"import failed: {e}")
    path = trace.save(args.out)
    counts = ", ".join(f"{w}:{c}" for w, c in
                       sorted(trace.workload_counts().items()))
    print(f"imported {args.log} -> {path}: {len(trace.jobs)} jobs over "
          f"{trace.duration():.0f}s, {trace.total_input_gb():.1f} GB total "
          f"({counts})")
    return 0


def cmd_regimes(args) -> int:
    presets = tuple(args.presets)
    for p in presets:
        if p not in PRESETS:
            raise SystemExit(f"unknown preset {p!r}; available: "
                             f"{', '.join(sorted(PRESETS))}")
    shapes = tuple(args.shapes) if args.shapes is not None else (
        regimes_mod.QUICK_SHAPES if args.quick else regimes_mod.FULL_SHAPES)
    for s in shapes:
        if s not in FLEET_SHAPES:
            raise SystemExit(f"unknown shape {s!r}; available: "
                             f"{', '.join(FLEET_SHAPES)}")
    seeds = (_parse_seeds(args.seeds) if args.seeds is not None
             else (regimes_mod.QUICK_SEEDS if args.quick
                   else regimes_mod.FULL_SEEDS))
    fabrics = tuple(args.fabrics) if args.fabrics is not None else (
        regimes_mod.QUICK_FABRICS if args.quick
        else regimes_mod.FULL_FABRICS)
    for f in fabrics:
        if f not in regimes_mod.FABRICS:
            raise SystemExit(f"unknown fabric {f!r}; available: "
                             f"{', '.join(regimes_mod.FABRICS)}")
    replications = (tuple(args.replications)
                    if args.replications is not None else (
                        regimes_mod.QUICK_REPLICATIONS if args.quick
                        else regimes_mod.FULL_REPLICATIONS))
    faults = tuple(args.faults) if args.faults is not None else (
        regimes_mod.QUICK_FAULTS if args.quick else regimes_mod.FULL_FAULTS)
    for fp in faults:
        if fp not in regimes_mod.FAULT_PROFILES:
            raise SystemExit(f"unknown fault profile {fp!r}; available: "
                             f"{', '.join(regimes_mod.FAULT_PROFILES)}")
    swim = tuple(args.swim) if args.swim is not None else (
        regimes_mod.QUICK_SWIM if args.quick else regimes_mod.FULL_SWIM)
    for sw in swim:
        if sw not in regimes_mod.SWIM_TRACES:
            raise SystemExit(f"unknown SWIM trace {sw!r}; available: "
                             f"{', '.join(regimes_mod.SWIM_TRACES)}")
    serve = tuple(args.serve) if args.serve is not None else (
        regimes_mod.QUICK_SERVE if args.quick else regimes_mod.FULL_SERVE)
    for sp in serve:
        if sp not in regimes_mod.SERVE_PROFILES:
            raise SystemExit(f"unknown serve profile {sp!r}; available: "
                             f"{', '.join(regimes_mod.SERVE_PROFILES)}")
    report = regimes_mod.run_regimes(
        presets, shapes, seeds, args.cache, fabrics=fabrics,
        replications=replications, faults=faults, swim=swim,
        workers=args.workers,
        progress=print if args.verbose else None)
    out = report.save_json(args.out)
    print(report.format())
    print(f"regime report -> {out}")
    if args.markdown is not None:
        md = Path(args.markdown)
        md.parent.mkdir(parents=True, exist_ok=True)
        _write_markdown_table(md, report.to_markdown())
        print(f"markdown table -> {md}")
    if serve:
        serve_shapes = tuple(s for s in regimes_mod.SERVE_SHAPES
                             if s in shapes) or (shapes[0],)
        sreport = regimes_mod.run_serve_regimes(
            serve, serve_shapes, seeds, args.cache, workers=args.workers,
            progress=print if args.verbose else None)
        sout = sreport.save_json(args.serve_out)
        print(sreport.format())
        print(f"serve report -> {sout}")
        if args.markdown is not None:
            _write_marked_section(Path(args.markdown),
                                  sreport.to_markdown(),
                                  SERVE_TABLE_START, SERVE_TABLE_END)
            print(f"serve markdown table -> {args.markdown}")
    return 0


MD_TABLE_START = "<!-- regimes:table:start"
MD_TABLE_END = "<!-- regimes:table:end -->"
SERVE_TABLE_START = "<!-- serve:table:start"
SERVE_TABLE_END = "<!-- serve:table:end -->"


def _write_markdown_table(md: Path, table: str) -> None:
    """Write the regime table to ``md``.  If the file already exists and
    carries the ``regimes:table`` markers (the committed EXPERIMENTS.md
    does), only the marked section is replaced — regenerating the atlas
    must not clobber the surrounding narrative."""
    if md.exists():
        text = md.read_text()
        start = text.find(MD_TABLE_START)
        end = text.find(MD_TABLE_END)
        if start != -1 and end != -1 and end > start:
            head = text[:text.index("\n", start) + 1]   # keep the marker line
            md.write_text(head + table + "\n" + text[end:])
            return
    md.write_text(table + "\n")


def _write_marked_section(md: Path, table: str, start: str,
                          end: str) -> None:
    """Replace (or append) a marker-delimited table in ``md`` without
    touching anything outside the markers — the serving table lives in
    the same EXPERIMENTS.md as the regime table, so a missing-marker
    fallback must append a new marked section, never clobber the file."""
    if md.exists():
        text = md.read_text()
        s, e = text.find(start), text.find(end)
        if s != -1 and e != -1 and e > s:
            head = text[:text.index("\n", s) + 1]       # keep the marker line
            md.write_text(head + table + "\n" + text[e:])
            return
        md.write_text(text.rstrip("\n")
                      + f"\n\n{start} -->\n{table}\n{end}\n")
        return
    md.write_text(f"{start} -->\n{table}\n{end}\n")


def _print_records(report) -> None:
    print(f"[{report.spec_name}] {len(report.records)} runs "
          f"({report.simulated} simulated, {report.cached} cached)")
    print(f"{'scheduler':10s} {'seed':>4s} {'makespan':>9s} {'tput/h':>7s} "
          f"{'done':>5s} {'ddl':>4s} {'local%':>7s} {'spec':>5s}")
    for r in report.records:
        print(f"{r.scheduler:10s} {r.seed:4d} {r.makespan:9.1f} "
              f"{r.throughput_jph:7.1f} {r.jobs_finished:3d}/{r.jobs_total:<3d}"
              f"{r.deadlines_met:4d} {r.locality_rate:7.1%} "
              f"{r.speculative_launches:5d}")


def cmd_run(args) -> int:
    policies = [_parse_policy(tok) for tok in args.schedulers]
    policies += [_parse_policy(tok) for tok in (args.policy or [])]
    try:
        spec = ExperimentSpec(
            name=args.name,
            traces=(_trace_ref_from_args(args),),
            clusters=(_cluster_from_args(args),),
            schedulers=tuple(policies),
            seeds=_parse_seeds(args.seeds),
        )
    except ValueError as e:               # duplicate policies etc.
        raise SystemExit(f"bad sweep spec: {e}")
    report = run_experiment(spec, args.cache, workers=args.workers,
                            progress=print if args.verbose else None)
    _print_records(report)
    return 0


def cmd_compare(args) -> int:
    pol_a, pol_b = _parse_policy(args.a), _parse_policy(args.b)
    try:
        spec = ExperimentSpec(
            name=args.name,
            traces=(_trace_ref_from_args(args),),
            clusters=(_cluster_from_args(args),),
            schedulers=(pol_a, pol_b),
            seeds=_parse_seeds(args.seeds),
        )
    except ValueError as e:               # e.g. --a and --b the same policy
        raise SystemExit(f"bad sweep spec: {e}")
    report = run_experiment(spec, args.cache, workers=args.workers,
                            progress=print if args.verbose else None)
    by_sched = report.by_scheduler()
    a, b = pol_a.label, pol_b.label
    ra, rb = by_sched[a], by_sched[b]
    print(f"[{report.spec_name}] {b} vs {a} "
          f"({report.simulated} simulated, {report.cached} cached)")
    print("  " + compare_throughput(ra, rb).format(a, b))
    dl = compare_deadlines(ra, rb)
    print(f"  deadlines met/run: {a} {dl['mean_a']:.1f} -> "
          f"{b} {dl['mean_b']:.1f}")
    print("  per-workload completion-time gain:")
    for w, cmp in compare_completion_by_workload(ra, rb).items():
        print(f"    {w:16s} {cmp.mean_gain_pct:+6.1f}% "
              f"[{cmp.ci_lo_pct:+6.1f}%, {cmp.ci_hi_pct:+6.1f}%] "
              f"win {cmp.win_rate:.0%}")
    return 0


def cmd_policies(args) -> int:
    print(f"{'policy':12s} {'ordering':13s} {'park':9s} {'overload':13s} "
          f"{'harvest':8s} parameters")
    for name, pol in registered_policies().items():
        params = ", ".join(f"{k}={v}" for k, v in sorted(pol.defaults.items()))
        c = pol.components
        print(f"{name:12s} {c['ordering']:13s} {c['park']:9s} "
              f"{c['overload']:13s} {c.get('harvest', 'off'):8s} "
              f"{params or '-'}")
        if args.verbose:
            print(f"             {pol.description}")
    if args.smoke:
        failures = smoke_test_policies()
        if failures:
            print("policy smoke FAILED:")
            for f in failures:
                print(f"  - {f}")
            return 1
        print(f"policy smoke passed: {len(registered_policies())} policies "
              "ran clean (every job finished, no stranded tasks)")
    return 0


def cmd_surrogate(args) -> int:
    from repro.experiments import surrogate as sur_mod
    from repro.simcluster.surrogate import SurrogateUnsupported

    shape = args.shape
    if shape not in FLEET_SHAPES:
        raise SystemExit(f"unknown shape {shape!r}; available: "
                         f"{', '.join(FLEET_SHAPES)}")
    if args.presets:
        pairs = [(p, shape) for p in args.presets]
        for p, s in pairs:
            if p not in PRESETS:
                raise SystemExit(f"unknown preset {p!r}; available: "
                                 f"{', '.join(sorted(PRESETS))}")
            if (p, s) not in sur_mod.CALIBRATED and not args.policies:
                raise SystemExit(
                    f"({p}, {s}) is not in the calibration allowlist; "
                    f"pass --policies to sweep uncalibrated estimates "
                    f"anyway (allowlisted: "
                    f"{', '.join(f'{k[0]}/{k[1]}' for k in sorted(sur_mod.CALIBRATED))})")
    else:
        pairs = [k for k in sorted(sur_mod.CALIBRATED) if k[1] == shape]
        if not pairs:
            raise SystemExit(f"no calibrated presets at shape {shape!r}")
    seeds = _parse_seeds(args.seeds)
    rc = 0
    for preset, shp in pairs:
        allow = sur_mod.CALIBRATED.get((preset, shp), ())
        pols = tuple(args.policies) if args.policies else allow
        pols = tuple(p for p in pols if p != "fair")
        base = regimes_mod.regime_spec(preset, shp, seeds=seeds)
        spec = ExperimentSpec(name=f"surrogate-{preset}-{shp}",
                              traces=base.traces, clusters=base.clusters,
                              schedulers=pols + ("fair",), seeds=seeds)
        try:
            rep = sur_mod.run_surrogate(
                spec, args.cache, progress=print if args.verbose else None)
        except SurrogateUnsupported as e:
            raise SystemExit(f"surrogate: {e}")
        by = rep.by_scheduler()
        print(f"[{preset}/{shp}] {rep.simulated + rep.cached} surrogate "
              f"cells ({rep.cached} cached), seeds {seeds[0]}..{seeds[-1]}")
        print(f"  {'policy':11s} {'tput/h':>7s} {'vs fair':>8s} "
              f"{'local%':>7s} {'ddl':>6s} calibrated")
        for pol in pols + ("fair",):
            recs = by[pol]
            jph = sum(r.throughput_jph for r in recs) / len(recs)
            loc = sum(r.locality_rate for r in recs) / len(recs)
            ddl = sum(r.deadlines_met for r in recs) / len(recs)
            gain = ("       -" if pol == "fair" else
                    f"{compare_throughput(by['fair'], recs).mean_gain_pct:+7.1f}%")
            tag = "yes" if pol in allow else ("-" if pol == "fair"
                                              else "NO (oracle-only)")
            print(f"  {pol:11s} {jph:7.1f} {gain:>8s} {loc:7.1%} "
                  f"{ddl:6.1f} {tag}")
        if not args.no_calibrate and allow:
            cal = sur_mod.calibrate(
                preset, shp, args.cache, workers=args.workers,
                progress=print if args.verbose else None)
            print(f"  calibration vs event oracle "
                  f"(seeds {cal.seeds[0]}..{cal.seeds[-1]}):")
            for pc in cal.policies:
                status = "IN" if pc.inside else "OUT"
                print(f"    {pc.policy:11s} surrogate "
                      f"{pc.surrogate_gain_pct:+6.1f}% vs oracle CI "
                      f"[{pc.oracle.ci_lo_pct:+6.1f}%, "
                      f"{pc.oracle.ci_hi_pct:+6.1f}%]  {status}")
            if not cal.wall_green:
                print(f"  CALIBRATION DRIFT: an allowlisted policy left "
                      f"the oracle CI — rerun tests/test_surrogate.py")
                rc = 1
    return rc


def cmd_explain(args) -> int:
    from repro.experiments.telemetry import explain_cell
    if args.preset not in PRESETS:
        raise SystemExit(f"unknown preset {args.preset!r}; available: "
                         f"{', '.join(sorted(PRESETS))}")
    if args.shape not in FLEET_SHAPES:
        raise SystemExit(f"unknown shape {args.shape!r}; available: "
                         f"{', '.join(FLEET_SHAPES)}")
    if args.fabric not in regimes_mod.FABRICS:
        raise SystemExit(f"unknown fabric {args.fabric!r}; available: "
                         f"{', '.join(regimes_mod.FABRICS)}")
    if args.faults not in regimes_mod.FAULT_PROFILES:
        raise SystemExit(f"unknown fault profile {args.faults!r}; available: "
                         f"{', '.join(regimes_mod.FAULT_PROFILES)}")
    try:
        text, _, _ = explain_cell(
            args.preset, args.shape,
            policy=args.policy, baseline=args.baseline, seed=args.seed,
            fabric=args.fabric, replication=args.replication,
            faults=args.faults, cache_dir=args.cache,
            store=not args.no_store, export_dir=args.export)
    except (PolicyError, ValueError) as e:
        raise SystemExit(f"explain failed: {e}")
    print(text)
    return 0


def cmd_faults(args) -> int:
    if not args.list:
        raise SystemExit("faults: nothing to do (did you mean --list?)")
    print(f"{'profile':14s} {'enabled':8s} {'mtbf':>7s} {'mttr':>6s} "
          f"{'rerepl':>7s} machine classes")
    for name, fc in regimes_mod.FAULT_PROFILES.items():
        classes = ", ".join(
            f"{mc.name}(w={mc.weight}, speed={mc.speed}, "
            f"mtbf_scale={mc.mtbf_scale})"
            for mc in fc.machine_classes) or "-"
        mtbf = f"{fc.crash_mtbf:.0f}" if fc.enabled else "-"
        mttr = f"{fc.crash_mttr:.0f}" if fc.enabled else "-"
        rer = f"{fc.rereplicate_after:.0f}" if fc.enabled else "-"
        print(f"{name:14s} {str(fc.enabled):8s} {mtbf:>7s} {mttr:>6s} "
              f"{rer:>7s} {classes}")
    return 0


def cmd_serve(args) -> int:
    if not args.list:
        raise SystemExit("serve: nothing to do (did you mean --list?)")
    machines = args.machines
    print(f"serving profiles at {machines} machines (replicas scale with "
          f"the fleet; pass a name to --serve on run/compare/regimes):")
    print(f"{'profile':16s} {'svc':5s} {'repl':>4s} {'vcpus':>5s} "
          f"{'rps':>5s} {'diurnal':>7s} {'burst':>5s} {'svc_ms':>6s} "
          f"{'slo_p99':>8s} {'bound':>6s}")
    for name in regimes_mod.SERVE_PROFILES:
        cfg = regimes_mod.serve_profile(name, machines)
        for svc in cfg.services:
            print(f"{name:16s} {svc.name:5s} {svc.replicas:4d} "
                  f"{svc.vcpus:5d} {svc.base_rps:5.0f} "
                  f"{svc.diurnal_amplitude:7.2f} {svc.burst_prob:5.2f} "
                  f"{svc.service_time * 1000:6.0f} "
                  f"{svc.slo_p99_ms:6.0f}ms {cfg.slo_violation_bound:6.2f}")
    print("harvest policy: `harvest` (= adaptive + the ewma harvest "
          "component); borrow under util EWMA "
          "< harvest_headroom, preemptive return past harvest_return_util "
          "or at the tick p99 SLO")
    return 0


def cmd_paper(args) -> int:
    seeds = (QUICK_SEEDS if args.quick else FULL_SEEDS)
    if args.seeds is not None:
        seeds = _parse_seeds(args.seeds)
    report = run_paper(seeds, cache_dir=args.cache, workers=args.workers,
                       progress=print if args.verbose else None)
    print(report.format())
    if args.quick:
        return 0                      # quick mode reports, full mode enforces
    return 1 if report.failures() else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.experiments",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    g = sub.add_parser("generate", help="synthesize a trace to JSONL")
    g.add_argument("--preset", default=None,
                   help="paper, " + ", ".join(sorted(PRESETS)))
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--num-jobs", type=int, default=None,
                   help="override the preset's job count")
    g.add_argument("--out", type=Path, required=True)
    g.set_defaults(func=cmd_generate)

    im = sub.add_parser("import",
                        help="convert a SWIM-format cluster log to "
                             "repro-trace/v1 JSONL")
    im.add_argument("--log", type=Path, required=True,
                    help="SWIM/Facebook-format log: job_id submit_time gap "
                         "input_bytes shuffle_bytes output_bytes per line")
    im.add_argument("--out", type=Path, required=True)
    im.add_argument("--name", default=None,
                    help="trace name (default: log file stem)")
    im.add_argument("--deadline-slack", type=float, default=2.2)
    im.add_argument("--skew", type=float, default=1.0,
                    help="VM-level placement skew applied at replay")
    im.add_argument("--max-jobs", type=int, default=None,
                    help="import at most this many rows")
    im.set_defaults(func=cmd_import)

    r = sub.add_parser("run", help="run a sweep grid (cached)")
    _add_grid_args(r)
    r.add_argument("--schedulers", nargs="+", default=["proposed", "fair"],
                   help="policy names or inline policy JSON objects")
    r.add_argument("--policy", action="append", default=None,
                   help='extra policy JSON, e.g. \'{"name": "delay", '
                        '"params": {"locality_delay": 4}}\' (repeatable)')
    r.add_argument("--name", default="sweep")
    r.add_argument("--verbose", action="store_true")
    r.set_defaults(func=cmd_run)

    c = sub.add_parser("compare", help="paired policy comparison")
    _add_grid_args(c)
    c.add_argument("--a", default="fair",
                   help="baseline policy (name or JSON)")
    c.add_argument("--b", default="proposed",
                   help="candidate policy (name or JSON)")
    c.add_argument("--name", default="compare")
    c.add_argument("--verbose", action="store_true")
    c.set_defaults(func=cmd_compare)

    rg = sub.add_parser("regimes",
                        help="fleet-scale regime atlas: presets x cluster "
                             "shapes (x fabrics) x {proposed, adaptive, "
                             "fair, fifo}")
    rg.add_argument("--quick", action="store_true",
                    help=f"sub-grid: shapes {regimes_mod.QUICK_SHAPES}, "
                         f"seeds {regimes_mod.QUICK_SEEDS} (cache-compatible "
                         "with the full atlas)")
    rg.add_argument("--presets", nargs="+",
                    default=list(regimes_mod.REGIME_PRESETS))
    rg.add_argument("--shapes", nargs="+", default=None,
                    help="cluster shapes: " + ", ".join(FLEET_SHAPES))
    rg.add_argument("--seeds", nargs="+", default=None,
                    help="paired seeds; accepts `a:b` ranges")
    rg.add_argument("--fabrics", nargs="*", default=None,
                    help="extra remote-penalty fabrics swept on the first "
                         "shape: " + ", ".join(regimes_mod.FULL_FABRICS)
                         + f" (full default: {regimes_mod.FULL_FABRICS})")
    rg.add_argument("--replications", nargs="*", type=int, default=None,
                    help="extra HDFS replication factors swept on the first "
                         f"shape (full default: "
                         f"{regimes_mod.FULL_REPLICATIONS})")
    rg.add_argument("--faults", nargs="*", default=None,
                    help="fault profiles swept over the fault shapes "
                         f"({', '.join(regimes_mod.FAULT_SHAPES)}): "
                         + ", ".join(p for p in regimes_mod.FAULT_PROFILES
                                     if p != regimes_mod.BASE_FAULTS)
                         + f" (full default: {regimes_mod.FULL_FAULTS})")
    rg.add_argument("--swim", nargs="*", default=None,
                    help="committed SWIM trace columns on the first shape: "
                         + ", ".join(regimes_mod.SWIM_TRACES)
                         + f" (full default: {regimes_mod.FULL_SWIM})")
    rg.add_argument("--serve", nargs="*", default=None,
                    help="serving profiles swept over the serve shapes "
                         f"({', '.join(regimes_mod.SERVE_SHAPES)}), pairing "
                         "harvest vs adaptive: "
                         + ", ".join(regimes_mod.SERVE_PROFILES)
                         + " (full default: all; quick default: none)")
    rg.add_argument("--serve-out", type=Path,
                    default=Path("serve_regimes.json"),
                    help="machine-readable serving report (default: "
                         "serve_regimes.json)")
    rg.add_argument("--cache", type=Path, default=DEFAULT_CACHE)
    rg.add_argument("--workers", type=int, default=0)
    rg.add_argument("--out", type=Path, default=Path("regimes.json"),
                    help="machine-readable regime report (default: "
                         "regimes.json)")
    rg.add_argument("--markdown", type=Path, default=None,
                    help="also write the markdown regime table here "
                         "(e.g. EXPERIMENTS.md)")
    rg.add_argument("--verbose", action="store_true")
    rg.set_defaults(func=cmd_regimes)

    sg = sub.add_parser(
        "surrogate",
        help="batched fluid-engine sweep over calibrated atlas cells, "
             "with differential calibration vs paired oracle cells")
    sg.add_argument("presets", nargs="*",
                    help="presets to sweep (default: every allowlisted "
                         "preset at --shape)")
    sg.add_argument("--shape", default="20x2",
                    help="fleet shape (default: 20x2, the calibrated shape)")
    sg.add_argument("--seeds", nargs="+", default=["0:8"],
                    help="sim seeds; accepts `a:b` ranges (default: 0:8)")
    sg.add_argument("--policies", nargs="*", default=None,
                    help="override the calibrated policy set (uncalibrated "
                         "estimates are labeled as such)")
    sg.add_argument("--cache", type=Path, default=DEFAULT_CACHE,
                    help=f"shared result cache (default: {DEFAULT_CACHE}); "
                         "surrogate cells hash into their own namespace")
    sg.add_argument("--no-calibrate", action="store_true",
                    help="skip the paired event-oracle calibration pass")
    sg.add_argument("--workers", type=int, default=0,
                    help="pool size for the oracle side of calibration")
    sg.add_argument("--verbose", action="store_true")
    sg.set_defaults(func=cmd_surrogate)

    ex = sub.add_parser("explain",
                        help="replay one atlas cell with tracing on and "
                             "attribute its scheduling decisions")
    ex.add_argument("preset", help="regime preset: "
                    + ", ".join(sorted(PRESETS)))
    ex.add_argument("shape", help="cluster shape: " + ", ".join(FLEET_SHAPES))
    ex.add_argument("--policy", default="adaptive",
                    help="policy to explain (default: adaptive)")
    ex.add_argument("--baseline", default="proposed",
                    help="comparison policy run on identical inputs "
                         "(default: proposed)")
    ex.add_argument("--seed", type=int, default=0)
    ex.add_argument("--fabric", default="1GbE",
                    help="network fabric: " + ", ".join(regimes_mod.FABRICS))
    ex.add_argument("--replication", type=int, default=1)
    ex.add_argument("--faults", default="none",
                    help="fault profile: "
                         + ", ".join(regimes_mod.FAULT_PROFILES))
    ex.add_argument("--cache", type=Path, default=DEFAULT_CACHE,
                    help="warehouse dir; the policy's folded summary is "
                         "stored next to the cell's RunRecord "
                         f"(default: {DEFAULT_CACHE})")
    ex.add_argument("--export", type=Path, default=None,
                    help="also write trace.jsonl + trace.chrome.json "
                         "(Perfetto) for both runs into this directory")
    ex.add_argument("--no-store", action="store_true",
                    help="skip writing the summary into the warehouse")
    ex.set_defaults(func=cmd_explain)

    fl = sub.add_parser("faults",
                        help="fault-injection profiles accepted by --faults")
    fl.add_argument("--list", action="store_true",
                    help="list the named profiles and their knobs")
    fl.set_defaults(func=cmd_faults)

    sv = sub.add_parser("serve",
                        help="serving profiles accepted by --serve")
    sv.add_argument("--list", action="store_true",
                    help="list the named profiles and their knobs")
    sv.add_argument("--machines", type=int, default=20,
                    help="fleet size to scale replica counts for "
                         "(default: 20)")
    sv.set_defaults(func=cmd_serve)

    pl = sub.add_parser("policies",
                        help="list registered scheduler policies "
                             "(repro.core.policies)")
    pl.add_argument("--smoke", action="store_true",
                    help="instantiate every policy on a 2-machine scenario "
                         "and fail on stranded work")
    pl.add_argument("--verbose", action="store_true",
                    help="include policy descriptions")
    pl.set_defaults(func=cmd_policies)

    p = sub.add_parser("paper", help="reproduce the paper's §5 evaluation")
    p.add_argument("--quick", action="store_true",
                   help=f"{len(QUICK_SEEDS)} seeds, report only (no claim "
                        "enforcement)")
    p.add_argument("--seeds", nargs="+", default=None,
                   help="override the seed list; accepts `a:b` ranges")
    p.add_argument("--cache", type=Path, default=None,
                   help="cache directory (default: temp dir)")
    p.add_argument("--workers", type=int, default=0)
    p.add_argument("--verbose", action="store_true")
    p.set_defaults(func=cmd_paper)

    args = ap.parse_args(argv)
    return args.func(args)


if __name__ == "__main__":
    sys.exit(main())
