"""Metrics warehouse: per-job and per-run records serialized from
``SimResult`` so sweep results can be cached, merged and compared offline.

A ``RunRecord`` is the unit the cache stores and the stats layer consumes.
It is deliberately plain JSON (no pickles): records written by one engine
version remain readable by the next.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.simcluster.sim import SimResult
from repro.simcluster.traces import Trace, _dumps

RECORD_VERSION = 1


@dataclass
class JobRecord:
    job_id: str
    workload: str
    input_gb: float
    submit_time: float
    deadline: float                      # relative, seconds from submit
    finish_time: Optional[float]         # absolute sim time; None = unfinished
    completion_time: Optional[float]     # finish - submit
    deadline_met: bool
    local_map_launches: int
    remote_map_launches: int
    reconfig_map_launches: int

    def to_dict(self) -> Dict[str, object]:
        return dict(self.__dict__)

    @classmethod
    def from_dict(cls, d) -> "JobRecord":
        return cls(**d)


@dataclass
class RunRecord:
    """One simulated cell of a sweep: (trace, cluster, scheduler, seed)."""

    trace_name: str
    trace_seed: int
    cluster: Dict[str, object]           # ClusterSpec.to_dict()
    scheduler: str                       # PolicySpec.label (bare preset name
                                         # when the spec has no overrides)
    seed: int
    makespan: float
    throughput_jph: float
    jobs_total: int
    jobs_finished: int
    deadlines_met: int
    locality_rate: float
    speculative_launches: int
    events_processed: int
    wall_time_s: float
    reconfig_stats: Dict[str, float] = field(default_factory=dict)
    jobs: List[JobRecord] = field(default_factory=list)
    # canonical PolicySpec.to_dict() of the policy that produced the run;
    # None on records written before the policy API existed (their
    # ``scheduler`` string is the preset name, which parses to the spec)
    policy: Optional[Dict[str, object]] = None
    # SimResult.serve_stats (latency/SLO/harvest fold); empty when the
    # run had no serving layer, so pre-serving records load unchanged
    serve: Dict[str, object] = field(default_factory=dict)
    version: int = RECORD_VERSION

    # -- identity -----------------------------------------------------------
    def pair_key(self):
        """Records with equal pair keys differ only in policy — the unit
        paired statistics match on.  The cluster dict is canonical-JSON
        encoded (the cache's ``_dumps``): it can hold nested config dicts
        (``adaptive``), which a tuple-of-items would leave unhashable.
        The policy stays *out* of the key on purpose: ``scheduler`` (the
        spec's label) is the column axis the pairing compares across."""
        return (self.trace_name, self.trace_seed, _dumps(self.cluster),
                self.seed)

    def policy_spec(self):
        """The ``PolicySpec`` this record was produced under (parsed from
        the stored canonical dict, falling back to the label string for
        pre-policy records)."""
        from repro.core.policies import PolicySpec
        return PolicySpec.parse(self.policy if self.policy is not None
                                else self.scheduler)

    # -- aggregation --------------------------------------------------------
    def mean_completion_by_workload(self) -> Dict[str, float]:
        """Mean completion time per workload over finished jobs; an
        unfinished job contributes ``inf`` so it cannot silently improve
        the average."""
        sums: Dict[str, float] = {}
        counts: Dict[str, int] = {}
        for j in self.jobs:
            ct = j.completion_time if j.completion_time is not None else math.inf
            sums[j.workload] = sums.get(j.workload, 0.0) + ct
            counts[j.workload] = counts.get(j.workload, 0) + 1
        return {w: sums[w] / counts[w] for w in sums}

    def mean_completion_time(self) -> float:
        if not self.jobs:
            return 0.0
        return sum(j.completion_time if j.completion_time is not None
                   else math.inf for j in self.jobs) / len(self.jobs)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> Dict[str, object]:
        d = dict(self.__dict__)
        d["jobs"] = [j.to_dict() for j in self.jobs]
        return d

    @classmethod
    def from_dict(cls, d) -> "RunRecord":
        d = dict(d)
        d["jobs"] = [JobRecord.from_dict(j) for j in d.get("jobs", [])]
        return cls(**d)


def run_record_from_result(result: SimResult, *, trace: Trace,
                           cluster_dict: Dict[str, object], scheduler: str,
                           seed: int, wall_time_s: float,
                           policy: Optional[Dict[str, object]] = None
                           ) -> RunRecord:
    """Flatten a ``SimResult`` into the warehouse record."""
    by_id = {tj.job_id: tj for tj in trace.jobs}
    jobs: List[JobRecord] = []
    for jid, rt in result.jobs.items():
        tj = by_id.get(jid)
        finish = rt.finish_time
        ct = None if finish is None else finish - rt.spec.submit_time
        jobs.append(JobRecord(
            job_id=jid,
            workload=tj.workload if tj else rt.spec.profile.name,
            input_gb=rt.spec.input_size_gb,
            submit_time=rt.spec.submit_time,
            deadline=rt.spec.deadline,
            finish_time=finish,
            completion_time=ct,
            deadline_met=(finish is not None
                          and finish <= rt.absolute_deadline + 1e-9),
            local_map_launches=rt.local_map_launches,
            remote_map_launches=rt.remote_map_launches,
            reconfig_map_launches=rt.reconfig_map_launches,
        ))
    return RunRecord(
        trace_name=trace.name,
        trace_seed=trace.seed,
        cluster=cluster_dict,
        scheduler=scheduler,
        seed=seed,
        makespan=result.makespan,
        throughput_jph=result.throughput_jobs_per_hour(),
        jobs_total=len(result.jobs),
        jobs_finished=sum(1 for j in jobs if j.finish_time is not None),
        deadlines_met=result.deadlines_met(),
        locality_rate=result.locality_rate(),
        speculative_launches=result.speculative_launches,
        events_processed=result.events_processed,
        wall_time_s=wall_time_s,
        reconfig_stats=dict(result.reconfig_stats),
        jobs=jobs,
        policy=policy,
        serve=dict(result.serve_stats),
    )
