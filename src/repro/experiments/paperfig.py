"""Statistical reproduction of the paper's evaluation (§5, Fig. 3).

The preset runs the Table-2 five-workload mix on the calibrated paper
cluster (20 machines x 2 VMs, per-VM virtual disks => replication 1,
VM-level placement skew) under the proposed completion-time scheduler and
the Fair baseline, paired per seed (each seed re-rolls placement + jitter
for *both* schedulers), and checks the paper's two claims:

1. positive job-throughput gain of proposed over Fair (paper: ~12%);
2. the Fig.-3 per-workload ordering — shuffle-heavy Permutation Generator
   is the weakest-gain workload (the paper measures ~no gain for it).
"""
from __future__ import annotations

import tempfile
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.experiments.runner import (ExperimentSpec, SweepReport, TraceRef,
                                      run_experiment)
from repro.experiments.stats import (PairedComparison, compare_completion_by_workload,
                                     compare_deadlines, compare_throughput)
from repro.simcluster.workloads import paper_cluster

PAPER_CLAIM_GAIN_PCT = 12.0
FULL_SEEDS: Tuple[int, ...] = tuple(range(1, 13))
QUICK_SEEDS: Tuple[int, ...] = (1, 2, 3)


@dataclass
class PaperReport:
    seeds: Tuple[int, ...]
    throughput: PairedComparison          # fair -> proposed
    per_workload: Dict[str, PairedComparison]
    deadlines: Dict[str, float]
    simulated: int
    cached: int

    def weakest_workload(self) -> str:
        return min(self.per_workload, key=lambda w: self.per_workload[w].mean_gain_pct)

    def failures(self) -> List[str]:
        """Empty list = the paper's claims reproduce."""
        out = []
        if self.throughput.mean_gain_pct <= 0:
            out.append(
                f"throughput gain not positive: {self.throughput.mean_gain_pct:+.1f}%")
        if self.throughput.ci_lo_pct <= 0:
            out.append(
                "throughput-gain 95% CI includes zero: "
                f"[{self.throughput.ci_lo_pct:+.1f}%, {self.throughput.ci_hi_pct:+.1f}%]")
        weakest = self.weakest_workload()
        if weakest != "permutation":
            out.append(
                f"Fig.3 ordering: weakest-gain workload is {weakest!r}, "
                "expected 'permutation'")
        return out

    def format(self) -> str:
        lines = [
            f"== paper reproduction (proposed vs fair, {len(self.seeds)} paired "
            f"seeds; {self.simulated} simulated, {self.cached} cached) ==",
            "  " + self.throughput.format("fair", "proposed")
            + f"   (paper claims ~{PAPER_CLAIM_GAIN_PCT:.0f}%)",
            f"  deadlines met/run: fair {self.deadlines['mean_a']:.1f} -> "
            f"proposed {self.deadlines['mean_b']:.1f}",
            "  Fig.3 per-workload completion-time gain:",
        ]
        for w, cmp in sorted(self.per_workload.items(),
                             key=lambda kv: -kv[1].mean_gain_pct):
            lines.append(f"    {w:16s} {cmp.mean_gain_pct:+6.1f}% "
                         f"[{cmp.ci_lo_pct:+6.1f}%, {cmp.ci_hi_pct:+6.1f}%]")
        lines.append(f"  weakest-gain workload: {self.weakest_workload()} "
                     "(paper: permutation)")
        fails = self.failures()
        lines.append("  claims: " + ("REPRODUCED" if not fails
                                     else "; ".join(fails)))
        return "\n".join(lines)


def paper_spec(seeds: Sequence[int] = FULL_SEEDS) -> ExperimentSpec:
    """The paper evaluation as a sweep spec: paper trace (placement re-rolled
    per seed, because ``TraceRef.seed=None`` couples it to the sim seed) x
    paper cluster x {proposed, fair}."""
    return ExperimentSpec(
        name="paper",
        traces=(TraceRef(preset="paper"),),
        clusters=(paper_cluster(),),
        schedulers=("proposed", "fair"),
        seeds=tuple(seeds),
    )


def run_paper(seeds: Sequence[int] = FULL_SEEDS,
              cache_dir: Optional[Union[str, Path]] = None,
              *, workers: int = 0, n_boot: int = 2000,
              progress=None) -> PaperReport:
    tmp = None
    if cache_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="repro-paper-")
        cache_dir = tmp.name
    try:
        report = run_experiment(paper_spec(seeds), cache_dir,
                                workers=workers, progress=progress)
        by_sched = report.by_scheduler()
        fair, proposed = by_sched["fair"], by_sched["proposed"]
        return PaperReport(
            seeds=tuple(seeds),
            throughput=compare_throughput(fair, proposed, n_boot=n_boot),
            per_workload=compare_completion_by_workload(fair, proposed,
                                                        n_boot=n_boot),
            deadlines=compare_deadlines(fair, proposed),
            simulated=report.simulated,
            cached=report.cached,
        )
    finally:
        if tmp is not None:
            tmp.cleanup()
