"""Declarative sweep runner with on-disk result caching.

An ``ExperimentSpec`` is a grid: traces x cluster shapes x schedulers x sim
seeds.  ``run_experiment`` materializes every cell, serves the ones already
on disk from the cache, fans the missing ones out over a ``multiprocessing``
pool, and returns the merged ``RunRecord`` list plus simulated/cached
counts — re-running a finished sweep performs **zero** new simulations, and
a partially-extended grid only simulates the new cells.

Cache layout (``<cache_dir>/``)::

    <cell_hash>/meta.json      # the cell descriptor that produced the hash
    <cell_hash>/seed<k>.json   # one RunRecord per sim seed

``cell_hash`` is sha256 over the canonical-JSON cell descriptor: trace
identity (file content hash for path traces; config + seed for generated
ones), ``ClusterSpec.to_dict()``, scheduler name, sim parameters and a
cache-format version.  The sim seed stays out of the hash so a sweep that
adds seeds reuses the same cell directory.
"""
from __future__ import annotations

import hashlib
import json
import multiprocessing
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

from repro.core.policies import PolicyError, PolicySpec
from repro.core.types import ClusterSpec
from repro.experiments.metrics import RunRecord, run_record_from_result
from repro.simcluster.sim import ClusterSim
from repro.simcluster.traces import (PRESETS, Trace, TraceConfig, _dumps,
                                     generate_trace, paper_trace,
                                     trace_from_rows)

CACHE_VERSION = 1
# the canonical preset names (kept for compatibility; the scheduler axis
# accepts any registered PolicySpec — see repro.core.policies)
SCHEDULERS = ("proposed", "adaptive", "fair", "fifo")


@dataclass(frozen=True)
class TraceRef:
    """Reference to a trace: a JSONL file, a named preset, an inline
    ``TraceConfig``, or explicit ``rows`` (a hand-built mix, as accepted by
    ``trace_from_rows``).  ``seed`` pins the trace seed; ``None`` couples it
    to each cell's sim seed (fresh placements per replication — the paper
    evaluation re-rolls placement every trial)."""

    path: Optional[str] = None
    preset: Optional[str] = None
    config: Optional[TraceConfig] = None
    rows: Optional[Tuple[Tuple[str, float, float, float], ...]] = None
    name: str = "rows"                  # trace name for the rows kind
    seed: Optional[int] = None

    def __post_init__(self) -> None:
        given = sum(x is not None for x in (self.path, self.preset,
                                            self.config, self.rows))
        if given != 1:
            raise ValueError(
                "exactly one of path / preset / config / rows must be given")
        if self.preset is not None and self.preset != "paper" \
                and self.preset not in PRESETS:
            raise ValueError(f"unknown preset {self.preset!r}; available: "
                             f"paper, {', '.join(sorted(PRESETS))}")

    def resolve(self, sim_seed: int) -> Trace:
        tseed = self.seed if self.seed is not None else sim_seed
        if self.path is not None:
            return Trace.load(self.path)
        if self.preset == "paper":
            return paper_trace(tseed)
        if self.preset is not None:
            return generate_trace(PRESETS[self.preset], tseed)
        if self.rows is not None:
            return trace_from_rows(self.name, self.rows, seed=tseed)
        return generate_trace(self.config, tseed)

    def descriptor(self) -> Dict[str, object]:
        """Content identity for cache hashing (path traces hash the bytes,
        so an edited trace file invalidates its cells)."""
        if self.path is not None:
            digest = hashlib.sha256(Path(self.path).read_bytes()).hexdigest()
            return {"kind": "path", "sha256": digest}
        seed = self.seed if self.seed is not None else "=sim_seed"
        if self.preset is not None:
            return {"kind": "preset", "preset": self.preset, "seed": seed}
        if self.rows is not None:
            return {"kind": "rows", "name": self.name,
                    "rows": [list(r) for r in self.rows], "seed": seed}
        return {"kind": "config", "config": self.config.to_dict(),
                "seed": seed}


@dataclass(frozen=True)
class Cell:
    """One grid point; fully picklable so pool workers can simulate it.

    ``scheduler`` is a ``PolicySpec``.  Its cache descriptor collapses to
    the bare policy name when the spec carries no parameter overrides —
    byte-identical to the pre-policy string descriptors, so existing cache
    cells keep hitting."""

    trace: TraceRef
    cluster: ClusterSpec
    scheduler: PolicySpec
    seed: int
    straggler_prob: float
    straggler_factor: float
    speculative: bool
    speculation_threshold: float

    def descriptor(self) -> Dict[str, object]:
        return {
            "version": CACHE_VERSION,
            "trace": self.trace.descriptor(),
            "cluster": self.cluster.to_dict(),
            "scheduler": self.scheduler.cache_descriptor(),
            "sim": {
                "straggler_prob": self.straggler_prob,
                "straggler_factor": self.straggler_factor,
                "speculative": self.speculative,
                "speculation_threshold": self.speculation_threshold,
            },
        }

    def cache_hash(self) -> str:
        return hashlib.sha256(_dumps(self.descriptor()).encode()).hexdigest()[:16]


@dataclass(frozen=True)
class ExperimentSpec:
    """The declarative sweep: every combination of the four axes is a cell."""

    name: str
    traces: Tuple[TraceRef, ...]
    clusters: Tuple[ClusterSpec, ...]
    # policy values: PolicySpec instances, registered names, or policy dicts
    # (normalized to PolicySpec on construction; unknown names raise)
    schedulers: Tuple[Union[str, PolicySpec], ...] = ("proposed", "fair")
    seeds: Tuple[int, ...] = (0,)
    straggler_prob: float = 0.03
    straggler_factor: float = 3.0
    speculative: bool = True
    speculation_threshold: float = 2.0

    def __post_init__(self) -> None:
        try:
            specs = tuple(PolicySpec.parse(s) for s in self.schedulers)
        except PolicyError as e:
            raise ValueError(f"unknown scheduler: {e}") from e
        object.__setattr__(self, "schedulers", specs)
        labels = [s.label for s in specs]
        if len(set(labels)) != len(labels):
            raise ValueError(f"duplicate scheduler policies: {labels}")
        if not (self.traces and self.clusters and self.schedulers and self.seeds):
            raise ValueError("every grid axis needs at least one value")

    def cells(self) -> Iterator[Cell]:
        for trace in self.traces:
            for cluster in self.clusters:
                for sched in self.schedulers:
                    for seed in self.seeds:
                        yield Cell(
                            trace=trace, cluster=cluster, scheduler=sched,
                            seed=seed,
                            straggler_prob=self.straggler_prob,
                            straggler_factor=self.straggler_factor,
                            speculative=self.speculative,
                            speculation_threshold=self.speculation_threshold)

    def n_cells(self) -> int:
        return (len(self.traces) * len(self.clusters) * len(self.schedulers)
                * len(self.seeds))


@dataclass
class SweepReport:
    spec_name: str
    records: List[RunRecord]
    simulated: int
    cached: int

    def by_scheduler(self) -> Dict[str, List[RunRecord]]:
        out: Dict[str, List[RunRecord]] = {}
        for r in self.records:
            out.setdefault(r.scheduler, []).append(r)
        return out


def simulate_cell(cell: Cell) -> Dict[str, object]:
    """Run one grid cell; module-level so pool workers can pickle it."""
    trace = cell.trace.resolve(cell.seed)
    spec = cell.cluster
    jobs = trace.job_specs(spec)
    sched = cell.scheduler.build(spec)
    sim = ClusterSim(spec, sched, seed=cell.seed,
                     straggler_prob=cell.straggler_prob,
                     straggler_factor=cell.straggler_factor,
                     speculative=cell.speculative,
                     speculation_threshold=cell.speculation_threshold)
    t0 = time.perf_counter()
    result = sim.run(jobs)
    wall = time.perf_counter() - t0
    record = run_record_from_result(
        result, trace=trace, cluster_dict=spec.to_dict(),
        scheduler=cell.scheduler.label, seed=cell.seed, wall_time_s=wall,
        policy=cell.scheduler.to_dict())
    return record.to_dict()


def _cell_paths(cache_dir: Path, cell: Cell) -> Tuple[Path, Path]:
    cell_dir = cache_dir / cell.cache_hash()
    return cell_dir, cell_dir / f"seed{cell.seed}.json"


def run_experiment(spec: ExperimentSpec,
                   cache_dir: Union[str, Path],
                   *, workers: int = 0,
                   progress=None) -> SweepReport:
    """Run (or re-serve from cache) every cell of ``spec``.

    ``workers=0``/``1`` simulates inline; ``workers>1`` fans the missing
    cells out over a ``multiprocessing`` pool.  Cache files are written by
    the parent only, after each result arrives."""
    cache_dir = Path(cache_dir)
    cache_dir.mkdir(parents=True, exist_ok=True)
    records: List[RunRecord] = []
    todo: List[Cell] = []
    for cell in spec.cells():
        _, result_path = _cell_paths(cache_dir, cell)
        if result_path.exists():
            records.append(RunRecord.from_dict(
                json.loads(result_path.read_text())))
        else:
            todo.append(cell)
    if progress:
        progress(f"[{spec.name}] {spec.n_cells()} cells: "
                 f"{len(records)} cached, {len(todo)} to simulate")

    if todo:
        if workers > 1 and len(todo) > 1:
            # spawn, not fork: the parent may hold jax/threading state (e.g.
            # under pytest), and the worker import chain is jax-free and cheap
            ctx = multiprocessing.get_context("spawn")
            with ctx.Pool(processes=min(workers, len(todo))) as pool:
                results = pool.map(simulate_cell, todo)
        else:
            results = [simulate_cell(cell) for cell in todo]
        for cell, rec_dict in zip(todo, results):
            cell_dir, result_path = _cell_paths(cache_dir, cell)
            cell_dir.mkdir(parents=True, exist_ok=True)
            meta_path = cell_dir / "meta.json"
            if not meta_path.exists():
                meta_path.write_text(
                    json.dumps(cell.descriptor(), indent=2, sort_keys=True)
                    + "\n")
            result_path.write_text(_dumps(rec_dict) + "\n")
            records.append(RunRecord.from_dict(rec_dict))
            if progress:
                progress(f"  simulated {cell.scheduler.label} seed={cell.seed} "
                         f"({rec_dict['events_processed']} events, "
                         f"{rec_dict['wall_time_s']:.2f}s)")

    records.sort(key=lambda r: (r.trace_name, r.trace_seed,
                                _dumps(r.cluster),
                                r.scheduler, r.seed))
    return SweepReport(spec_name=spec.name, records=records,
                       simulated=len(todo),
                       cached=spec.n_cells() - len(todo))
