"""Trace-driven experiment harness: declarative sweeps over (trace x cluster
x scheduler x seed) grids with on-disk caching, a metrics warehouse, and
paired-bootstrap statistics — the layer every scheduler variant is judged on.

Quickstart::

    PYTHONPATH=src python -m repro.experiments paper --quick
    PYTHONPATH=src python -m repro.experiments generate --preset bursty \
        --seed 0 --out traces/bursty.jsonl
    PYTHONPATH=src python -m repro.experiments compare --trace traces/bursty.jsonl \
        --a proposed --b fair --seeds 0:5
"""
from repro.experiments.metrics import JobRecord, RunRecord, run_record_from_result
from repro.experiments.regimes import (RegimeCell, RegimeReport, regime_spec,
                                       run_regimes)
from repro.experiments.runner import (ExperimentSpec, SweepReport, TraceRef,
                                      run_experiment)
from repro.experiments.stats import (PairedComparison, bootstrap_mean_ci,
                                     compare_completion_by_workload,
                                     compare_throughput, paired_bootstrap)
from repro.experiments.paperfig import PaperReport, run_paper

__all__ = [
    "ExperimentSpec", "JobRecord", "PairedComparison", "PaperReport",
    "RegimeCell", "RegimeReport", "RunRecord", "SweepReport", "TraceRef",
    "bootstrap_mean_ci", "compare_completion_by_workload",
    "compare_throughput", "paired_bootstrap", "regime_spec",
    "run_experiment", "run_paper", "run_record_from_result", "run_regimes",
]
