"""Regime atlas: where does the reconfiguration mechanism actually win?

The paper's headline (~12% throughput over Fair) is one point: one 20-machine
cluster, one job mix.  This module sweeps the proposed scheduler against the
Fair and FIFO baselines over the synthetic workload regimes (heavy-tailed
sizes, diurnal arrivals, flash-crowd bursts, shuffle-heavy mixes) crossed
with cluster shapes from the paper's 20x2 up to fleet scale, with ≥8 paired
seeds per cell, and emits a machine-readable *regime report*: per-regime
throughput-gain CIs, win rates, and locality/deadline deltas.

Job counts scale with the fleet (num_jobs × machines/20) so a 100-machine
cell faces proportional load, and every (trace seed, placement, jitter) draw
is shared by all three schedulers — the comparisons isolate pure policy.

Everything runs through the cached sweep runner: re-running a finished atlas
performs zero new simulations, and `--quick` is a sub-grid of the full atlas
so a later full run reuses its cells.

CLI::

    PYTHONPATH=src python -m repro.experiments regimes --quick
    PYTHONPATH=src python -m repro.experiments regimes --workers 4 \
        --markdown EXPERIMENTS.md
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Sequence, Tuple, Union

from repro.experiments.runner import ExperimentSpec, TraceRef, run_experiment
from repro.experiments.stats import PairedComparison, compare_throughput
from repro.simcluster.largescale import FLEET_SHAPES, fleet_shape
from repro.simcluster.traces import PRESETS

REGIME_PRESETS: Tuple[str, ...] = ("heavy_tail", "diurnal", "bursty",
                                   "shuffle_heavy")
FULL_SHAPES: Tuple[str, ...] = ("20x2", "50x2", "100x2")
QUICK_SHAPES: Tuple[str, ...] = ("20x2", "50x2")
FULL_SEEDS: Tuple[int, ...] = tuple(range(8))
QUICK_SEEDS: Tuple[int, ...] = (0, 1)
SCHEDULERS: Tuple[str, ...] = ("proposed", "fair", "fifo")
REPORT_VERSION = 1


def scaled_jobs(preset: str, machines: int) -> int:
    """Scale a preset's job count with the fleet (baseline: 20 machines)."""
    base = PRESETS[preset].num_jobs
    return max(base, round(base * machines / 20))


def regime_spec(preset: str, shape: str,
                seeds: Sequence[int] = FULL_SEEDS) -> ExperimentSpec:
    """One atlas cell as a sweep spec: scaled preset trace x shape x all
    three schedulers, trace seed coupled to the sim seed (every replication
    re-rolls arrivals and placements for *all* schedulers alike)."""
    machines, _ = FLEET_SHAPES[shape]
    config = dataclasses.replace(PRESETS[preset],
                                 num_jobs=scaled_jobs(preset, machines))
    return ExperimentSpec(
        name=f"regime-{preset}-{shape}",
        traces=(TraceRef(config=config),),
        clusters=(fleet_shape(shape),),
        schedulers=SCHEDULERS,
        seeds=tuple(seeds),
    )


@dataclass
class RegimeCell:
    """Verdict for one (workload regime, cluster shape) point of the atlas."""

    preset: str
    shape: str
    machines: int
    vms: int
    num_jobs: int
    seeds: Tuple[int, ...]
    vs_fair: PairedComparison            # proposed-vs-fair throughput
    vs_fifo: PairedComparison            # proposed-vs-fifo throughput
    locality: Dict[str, float]           # mean locality rate per scheduler
    deadline_frac: Dict[str, float]      # mean deadlines-met / jobs per run
    mean_makespan: Dict[str, float]

    def verdict(self) -> str:
        """'win' / 'loss' when the proposed-vs-fair 95% CI excludes zero,
        else 'tie'."""
        if self.vs_fair.ci_lo_pct > 0:
            return "win"
        if self.vs_fair.ci_hi_pct < 0:
            return "loss"
        return "tie"

    def locality_delta_pp(self) -> float:
        """Locality-rate gain of proposed over fair, percentage points."""
        return (self.locality["proposed"] - self.locality["fair"]) * 100.0

    def deadline_delta_pp(self) -> float:
        """Deadlines-met-fraction gain of proposed over fair, pp."""
        return (self.deadline_frac["proposed"]
                - self.deadline_frac["fair"]) * 100.0

    def to_dict(self) -> Dict[str, object]:
        return {
            "preset": self.preset,
            "shape": self.shape,
            "machines": self.machines,
            "vms": self.vms,
            "num_jobs": self.num_jobs,
            "seeds": list(self.seeds),
            "verdict": self.verdict(),
            "throughput_vs_fair": self.vs_fair.to_dict(),
            "throughput_vs_fifo": self.vs_fifo.to_dict(),
            "locality": self.locality,
            "locality_delta_pp": self.locality_delta_pp(),
            "deadline_frac": self.deadline_frac,
            "deadline_delta_pp": self.deadline_delta_pp(),
            "mean_makespan": self.mean_makespan,
        }


@dataclass
class RegimeReport:
    presets: Tuple[str, ...]
    shapes: Tuple[str, ...]
    seeds: Tuple[int, ...]
    cells: List[RegimeCell]
    simulated: int
    cached: int
    version: int = REPORT_VERSION

    def to_dict(self) -> Dict[str, object]:
        return {
            "version": self.version,
            "presets": list(self.presets),
            "shapes": list(self.shapes),
            "seeds": list(self.seeds),
            "schedulers": list(SCHEDULERS),
            "simulated": self.simulated,
            "cached": self.cached,
            "cells": [c.to_dict() for c in self.cells],
        }

    def save_json(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True)
                        + "\n")
        return path

    # -- human-readable views -----------------------------------------------
    def format(self) -> str:
        lines = [f"== regime atlas: proposed vs fair/fifo "
                 f"({len(self.seeds)} paired seeds/cell; "
                 f"{self.simulated} simulated, {self.cached} cached) =="]
        for c in self.cells:
            g = c.vs_fair
            lines.append(
                f"  {c.preset:13s} {c.shape:6s} ({c.num_jobs:3d} jobs)  "
                f"vs fair {g.mean_gain_pct:+6.1f}% "
                f"[{g.ci_lo_pct:+6.1f}%, {g.ci_hi_pct:+6.1f}%] "
                f"win {g.win_rate:4.0%}  "
                f"Δlocal {c.locality_delta_pp():+5.1f}pp  "
                f"Δddl {c.deadline_delta_pp():+5.1f}pp  -> {c.verdict()}")
        return "\n".join(lines)

    def to_markdown(self) -> str:
        head = [
            "| regime | cluster | jobs | tput gain vs fair (95% CI) | win "
            "rate | tput gain vs fifo | Δ locality | Δ deadlines | verdict |",
            "| --- | --- | ---: | --- | ---: | --- | ---: | ---: | --- |",
        ]
        rows = []
        for c in self.cells:
            f, o = c.vs_fair, c.vs_fifo
            rows.append(
                f"| {c.preset} | {c.shape} | {c.num_jobs} "
                f"| {f.mean_gain_pct:+.1f}% [{f.ci_lo_pct:+.1f}%, "
                f"{f.ci_hi_pct:+.1f}%] | {f.win_rate:.0%} "
                f"| {o.mean_gain_pct:+.1f}% [{o.ci_lo_pct:+.1f}%, "
                f"{o.ci_hi_pct:+.1f}%] | {c.locality_delta_pp():+.1f} pp "
                f"| {c.deadline_delta_pp():+.1f} pp | {c.verdict()} |")
        return "\n".join(head + rows)


def _mean(vals: Sequence[float]) -> float:
    return sum(vals) / len(vals) if vals else 0.0


def run_regimes(presets: Sequence[str] = REGIME_PRESETS,
                shapes: Sequence[str] = FULL_SHAPES,
                seeds: Sequence[int] = FULL_SEEDS,
                cache_dir: Union[str, Path] = ".exp-cache",
                *, workers: int = 0, n_boot: int = 2000,
                progress=None) -> RegimeReport:
    """Run (or re-serve from cache) the full atlas grid and distill the
    per-regime verdicts."""
    cells: List[RegimeCell] = []
    simulated = cached = 0
    for preset in presets:
        for shape in shapes:
            spec = regime_spec(preset, shape, seeds)
            report = run_experiment(spec, cache_dir, workers=workers,
                                    progress=progress)
            simulated += report.simulated
            cached += report.cached
            by = report.by_scheduler()
            machines, vms = FLEET_SHAPES[shape]
            cells.append(RegimeCell(
                preset=preset,
                shape=shape,
                machines=machines,
                vms=vms,
                num_jobs=scaled_jobs(preset, machines),
                seeds=tuple(seeds),
                vs_fair=compare_throughput(by["fair"], by["proposed"],
                                           n_boot=n_boot),
                vs_fifo=compare_throughput(by["fifo"], by["proposed"],
                                           n_boot=n_boot),
                locality={s: _mean([r.locality_rate for r in rs])
                          for s, rs in by.items()},
                deadline_frac={
                    s: _mean([r.deadlines_met / r.jobs_total for r in rs
                              if r.jobs_total])
                    for s, rs in by.items()},
                mean_makespan={s: _mean([r.makespan for r in rs])
                               for s, rs in by.items()},
            ))
            if progress:
                c = cells[-1]
                progress(f"[{preset}/{shape}] vs fair "
                         f"{c.vs_fair.mean_gain_pct:+.1f}% -> {c.verdict()}")
    return RegimeReport(presets=tuple(presets), shapes=tuple(shapes),
                        seeds=tuple(seeds), cells=cells,
                        simulated=simulated, cached=cached)
