"""Regime atlas: where does the reconfiguration mechanism actually win?

The paper's headline (~12% throughput over Fair) is one point: one 20-machine
cluster, one job mix.  This module sweeps the atlas policy columns —
``proposed``, ``adaptive``, ``adaptive_ra`` (reduce-aware overload latch)
and the ``delay``-scheduling baseline against ``fair`` and ``fifo``, all
registry presets (see ``repro.core.policies``) — over the synthetic
workload regimes (heavy-tailed sizes, diurnal arrivals, flash-crowd bursts,
shuffle-heavy mixes, the saturated closed mix) crossed with cluster shapes
from the paper's 20x2 up to fleet scale, with ≥8 paired seeds per cell,
and emits a machine-readable *regime report*: per-regime throughput-gain
CIs, win rates, and locality/deadline deltas.  Extra axes re-run every
preset on the first shape: network fabrics (``FABRICS``) and HDFS
replication (``replications``).

Job counts scale with the fleet (num_jobs × machines/20) so a 100-machine
cell faces proportional load, and every (trace seed, placement, jitter) draw
is shared by all three schedulers — the comparisons isolate pure policy.

Everything runs through the cached sweep runner: re-running a finished atlas
performs zero new simulations, and `--quick` is a sub-grid of the full atlas
so a later full run reuses its cells.

CLI::

    PYTHONPATH=src python -m repro.experiments regimes --quick
    PYTHONPATH=src python -m repro.experiments regimes --workers 4 \
        --markdown EXPERIMENTS.md
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Sequence, Tuple, Union

from repro.core.types import (FaultConfig, MachineClass, ServeConfig,
                              ServiceSpec)
from repro.experiments.runner import ExperimentSpec, TraceRef, run_experiment
from repro.experiments.stats import (PairedComparison, compare_serve_p99,
                                     compare_throughput)
from repro.simcluster.largescale import FLEET_SHAPES, fleet_shape
from repro.simcluster.traces import PRESETS, Trace

REGIME_PRESETS: Tuple[str, ...] = ("heavy_tail", "diurnal", "bursty",
                                   "shuffle_heavy", "saturated")
FULL_SHAPES: Tuple[str, ...] = ("20x2", "50x2", "100x2")
QUICK_SHAPES: Tuple[str, ...] = ("20x2", "50x2")
FULL_SEEDS: Tuple[int, ...] = tuple(range(8))
QUICK_SEEDS: Tuple[int, ...] = (0, 1)
# atlas policy columns (all default-spec registry presets, so the cell
# descriptors stay plain strings and pre-policy cache cells keep hitting):
# adaptive_ra = the reduce-aware overload latch, delay = delay scheduling
SCHEDULERS: Tuple[str, ...] = ("proposed", "adaptive", "adaptive_ra",
                               "delay", "fair", "fifo")
# remote-penalty calibration of the network fabric: at 1.0 a non-local map
# pays the full 2012-era shared-1GbE remote-read penalty; faster fabrics
# scale it down (~linear in link speed) — the axis answers "at what fabric
# speed does the reconfiguration mechanism stop paying?"
FABRICS: Dict[str, float] = {"1GbE": 1.0, "10GbE": 0.25, "40GbE": 0.0625}
BASE_FABRIC = "1GbE"
FULL_FABRICS: Tuple[str, ...] = ("10GbE", "40GbE")   # extra cells, 20x2 only
QUICK_FABRICS: Tuple[str, ...] = ()
# HDFS replication axis: the calibrated paper setting is replication 1
# (per-VM virtual disks); replication 3 is the HDFS default — three times
# the locality opportunities, so parking should matter *less*
BASE_REPLICATION = 1
FULL_REPLICATIONS: Tuple[int, ...] = (3,)            # extra cells, 20x2 only
QUICK_REPLICATIONS: Tuple[int, ...] = ()
# fault-injection axis: crash-rate x heterogeneity profiles (see
# repro.core.types.FaultConfig).  churn_lo/churn_hi vary the per-machine
# crash MTBF; churn_hetero adds a 3:1 new/old machine mix where the "old"
# quartile is 40% slower, pays a 25% stiffer remote penalty, and crashes
# twice as often.  Fault cells sweep every preset over FAULT_SHAPES —
# the axis answers "which policy column degrades gracefully under churn?"
HETERO_MIX: Tuple[MachineClass, ...] = (
    MachineClass(name="new", weight=3),
    MachineClass(name="old", weight=1, speed=1.4, fabric=1.25,
                 mtbf_scale=0.5),
)
FAULT_PROFILES: Dict[str, FaultConfig] = {
    "none": FaultConfig(),
    "churn_lo": FaultConfig(enabled=True, crash_mtbf=3600.0,
                            crash_mttr=120.0, rereplicate_after=60.0),
    "churn_hi": FaultConfig(enabled=True, crash_mtbf=1200.0,
                            crash_mttr=120.0, rereplicate_after=60.0),
    "churn_hetero": FaultConfig(enabled=True, crash_mtbf=1200.0,
                                crash_mttr=120.0, rereplicate_after=60.0,
                                machine_classes=HETERO_MIX),
}
BASE_FAULTS = "none"
FULL_FAULTS: Tuple[str, ...] = ("churn_lo", "churn_hi", "churn_hetero")
QUICK_FAULTS: Tuple[str, ...] = ()
FAULT_SHAPES: Tuple[str, ...] = ("20x2", "50x2")
# serving axis: co-located latency-SLO services (ServeConfig) crossed with
# the batch atlas — service:batch core mix x SLO tightness x spike
# amplitude, each cell pairing the `harvest` policy against its no-harvest
# `adaptive` twin on identical inputs.  Replica counts scale with the
# fleet (4 per 20 machines); 2-vCPU replicas pin a whole VM, so the
# harvest question is "how much pinned capacity can the batch side
# recover without breaching the p99 SLO?"
_SERVE_BASES: Dict[str, ServiceSpec] = {
    # 1-core replicas: nothing harvestable (a replica keeps its last
    # core) — the control cell where harvest must equal adaptive
    "svc_light_loose": ServiceSpec(name="web", vcpus=1, base_rps=12.0,
                                   diurnal_amplitude=0.3,
                                   slo_p99_ms=500.0),
    # 2-core replicas at low utilization with a loose SLO: the
    # harvest-win cell (idle pinned cores, headroom to lend)
    "svc_heavy_loose": ServiceSpec(name="api", vcpus=2, base_rps=15.0,
                                   diurnal_amplitude=0.3,
                                   slo_p99_ms=600.0),
    # 2-core replicas near the knee with a tight SLO: borrowing pushes
    # p99 toward the bar, so preemptive returns must do the work
    "svc_heavy_tight": ServiceSpec(name="api", vcpus=2, base_rps=35.0,
                                   diurnal_amplitude=0.2,
                                   slo_p99_ms=300.0),
    # flash-crowd riders on a quiet baseline: load spikes arrive faster
    # than the diurnal EWMA drifts — exercises util_spike/p99_pressure
    "svc_spiky": ServiceSpec(name="feed", vcpus=2, base_rps=10.0,
                             diurnal_amplitude=0.2, burst_prob=0.05,
                             burst_size_mean=12.0, slo_p99_ms=500.0),
}
SERVE_PROFILES: Tuple[str, ...] = tuple(_SERVE_BASES)
SERVE_SHAPES: Tuple[str, ...] = ("20x2", "50x2")
FULL_SERVE: Tuple[str, ...] = SERVE_PROFILES
QUICK_SERVE: Tuple[str, ...] = ()
# the serving cells pair the harvest column against its no-harvest twin
SERVE_SCHEDULERS: Tuple[str, ...] = ("adaptive", "harvest")
# batch workload under the services: the saturated closed mix keeps a
# standing map backlog, so harvested cores always have work to absorb
SERVE_PRESET = "saturated"


def serve_profile(name: str, machines: int) -> ServeConfig:
    """The named serving profile scaled to a fleet: replica count grows
    with the machine count (4 per 20 machines, minimum 2)."""
    if name not in _SERVE_BASES:
        raise ValueError(f"unknown serve profile {name!r}; available: "
                         f"{', '.join(_SERVE_BASES)}")
    base = _SERVE_BASES[name]
    replicas = max(2, round(4 * machines / 20))
    return ServeConfig(enabled=True, services=(
        dataclasses.replace(base, replicas=replicas),))
# real-trace columns: imported SWIM/Facebook-format cluster logs committed
# as repro-trace/v1 fixtures (see data/swim_fb_sample.log for the raw log
# and the import provenance).  Path traces hash their file bytes into the
# cell descriptor, so editing a fixture invalidates exactly its cells.
_DATA_DIR = Path(__file__).resolve().parent / "data"
SWIM_TRACES: Dict[str, Path] = {
    "swim_fb": _DATA_DIR / "swim_fb_sample.jsonl",
}
FULL_SWIM: Tuple[str, ...] = ("swim_fb",)
QUICK_SWIM: Tuple[str, ...] = ()
REPORT_VERSION = 4


def scaled_jobs(preset: str, machines: int) -> int:
    """Scale a preset's job count with the fleet (baseline: 20 machines).
    Imported SWIM traces are fixed arrival logs — their job count does not
    scale."""
    if preset in SWIM_TRACES:
        return len(Trace.load(SWIM_TRACES[preset]).jobs)
    base = PRESETS[preset].num_jobs
    return max(base, round(base * machines / 20))


def regime_spec(preset: str, shape: str,
                seeds: Sequence[int] = FULL_SEEDS,
                fabric: str = BASE_FABRIC,
                replication: int = BASE_REPLICATION,
                faults: str = BASE_FAULTS) -> ExperimentSpec:
    """One atlas cell as a sweep spec: scaled preset trace x shape x every
    atlas policy column, trace seed coupled to the sim seed (every
    replication re-rolls arrivals and placements for *all* schedulers
    alike).  ``fabric`` calibrates the remote-read penalty via
    ``ClusterSpec.remote_penalty_scale``; ``replication`` sets the HDFS
    replica count; ``faults`` names a ``FAULT_PROFILES`` entry (crash
    churn / heterogeneity).  ``preset`` may also name a committed SWIM
    trace fixture (``SWIM_TRACES``) — then the trace is the imported log,
    byte-hashed into the cell descriptor."""
    machines, _ = FLEET_SHAPES[shape]
    if preset in SWIM_TRACES:
        trace = TraceRef(path=str(SWIM_TRACES[preset]))
    else:
        config = dataclasses.replace(PRESETS[preset],
                                     num_jobs=scaled_jobs(preset, machines))
        trace = TraceRef(config=config)
    cluster = fleet_shape(shape, replication=replication)
    if fabric != BASE_FABRIC:
        cluster = dataclasses.replace(cluster,
                                      remote_penalty_scale=FABRICS[fabric])
    if faults != BASE_FAULTS:
        cluster = dataclasses.replace(cluster,
                                      faults=FAULT_PROFILES[faults])
    suffix = "" if faults == BASE_FAULTS else f"-{faults}"
    return ExperimentSpec(
        name=f"regime-{preset}-{shape}-{fabric}-r{replication}{suffix}",
        traces=(trace,),
        clusters=(cluster,),
        schedulers=SCHEDULERS,
        seeds=tuple(seeds),
    )


def _verdict_of(cmp: PairedComparison) -> str:
    """'win' / 'loss' when the 95% CI excludes zero, else 'tie'."""
    if cmp.ci_lo_pct > 0:
        return "win"
    if cmp.ci_hi_pct < 0:
        return "loss"
    return "tie"


@dataclass
class RegimeCell:
    """Verdict for one (workload regime, cluster shape, fabric, replication)
    point of the atlas."""

    preset: str
    shape: str
    machines: int
    vms: int
    num_jobs: int
    seeds: Tuple[int, ...]
    vs_fair: PairedComparison            # proposed-vs-fair throughput
    vs_fifo: PairedComparison            # proposed-vs-fifo throughput
    adaptive_vs_fair: PairedComparison   # adaptive-vs-fair throughput
    adaptive_vs_proposed: PairedComparison
    ra_vs_fair: PairedComparison         # adaptive_ra (reduce-aware latch)
    ra_vs_adaptive: PairedComparison     # ... and its gain over plain latch
    delay_vs_fair: PairedComparison      # delay-scheduling baseline
    locality: Dict[str, float]           # mean locality rate per scheduler
    deadline_frac: Dict[str, float]      # mean deadlines-met / jobs per run
    mean_makespan: Dict[str, float]
    fabric: str = BASE_FABRIC
    replication: int = BASE_REPLICATION
    faults: str = BASE_FAULTS

    def verdict(self) -> str:
        """Proposed-vs-fair verdict (the legacy fixed-policy column)."""
        return _verdict_of(self.vs_fair)

    def adaptive_verdict(self) -> str:
        """Adaptive-vs-fair verdict (the pressure-adaptive column)."""
        return _verdict_of(self.adaptive_vs_fair)

    def ra_verdict(self) -> str:
        """adaptive_ra-vs-fair verdict (reduce-aware overload latch)."""
        return _verdict_of(self.ra_vs_fair)

    def delay_verdict(self) -> str:
        """delay-vs-fair verdict (delay-scheduling baseline)."""
        return _verdict_of(self.delay_vs_fair)

    def locality_delta_pp(self, scheduler: str = "proposed") -> float:
        """Locality-rate gain of ``scheduler`` over fair, percentage pts."""
        return (self.locality[scheduler] - self.locality["fair"]) * 100.0

    def deadline_delta_pp(self, scheduler: str = "proposed") -> float:
        """Deadlines-met-fraction gain of ``scheduler`` over fair, pp."""
        return (self.deadline_frac[scheduler]
                - self.deadline_frac["fair"]) * 100.0

    def to_dict(self) -> Dict[str, object]:
        return {
            "preset": self.preset,
            "shape": self.shape,
            "fabric": self.fabric,
            "replication": self.replication,
            "faults": self.faults,
            "machines": self.machines,
            "vms": self.vms,
            "num_jobs": self.num_jobs,
            "seeds": list(self.seeds),
            "verdict": self.verdict(),
            "adaptive_verdict": self.adaptive_verdict(),
            "ra_verdict": self.ra_verdict(),
            "delay_verdict": self.delay_verdict(),
            "throughput_vs_fair": self.vs_fair.to_dict(),
            "throughput_vs_fifo": self.vs_fifo.to_dict(),
            "adaptive_vs_fair": self.adaptive_vs_fair.to_dict(),
            "adaptive_vs_proposed": self.adaptive_vs_proposed.to_dict(),
            "adaptive_ra_vs_fair": self.ra_vs_fair.to_dict(),
            "adaptive_ra_vs_adaptive": self.ra_vs_adaptive.to_dict(),
            "delay_vs_fair": self.delay_vs_fair.to_dict(),
            "locality": self.locality,
            "locality_delta_pp": self.locality_delta_pp(),
            "adaptive_locality_delta_pp": self.locality_delta_pp("adaptive"),
            "ra_locality_delta_pp": self.locality_delta_pp("adaptive_ra"),
            "delay_locality_delta_pp": self.locality_delta_pp("delay"),
            "deadline_frac": self.deadline_frac,
            "deadline_delta_pp": self.deadline_delta_pp(),
            "adaptive_deadline_delta_pp": self.deadline_delta_pp("adaptive"),
            "ra_deadline_delta_pp": self.deadline_delta_pp("adaptive_ra"),
            "mean_makespan": self.mean_makespan,
        }


@dataclass
class RegimeReport:
    presets: Tuple[str, ...]
    shapes: Tuple[str, ...]
    seeds: Tuple[int, ...]
    cells: List[RegimeCell]
    simulated: int
    cached: int
    fabrics: Tuple[str, ...] = (BASE_FABRIC,)
    replications: Tuple[int, ...] = (BASE_REPLICATION,)
    fault_profiles: Tuple[str, ...] = (BASE_FAULTS,)
    swim: Tuple[str, ...] = ()
    version: int = REPORT_VERSION

    def cell(self, preset: str, shape: str,
             fabric: str = BASE_FABRIC,
             replication: int = BASE_REPLICATION,
             faults: str = BASE_FAULTS) -> RegimeCell:
        for c in self.cells:
            if (c.preset, c.shape, c.fabric, c.replication, c.faults) \
                    == (preset, shape, fabric, replication, faults):
                return c
        raise KeyError((preset, shape, fabric, replication, faults))

    def to_dict(self) -> Dict[str, object]:
        return {
            "version": self.version,
            "presets": list(self.presets),
            "shapes": list(self.shapes),
            "seeds": list(self.seeds),
            "fabrics": list(self.fabrics),
            "replications": list(self.replications),
            "fault_profiles": list(self.fault_profiles),
            "swim": list(self.swim),
            "schedulers": list(SCHEDULERS),
            "simulated": self.simulated,
            "cached": self.cached,
            "cells": [c.to_dict() for c in self.cells],
        }

    def save_json(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True)
                        + "\n")
        return path

    # -- human-readable views -----------------------------------------------
    def format(self) -> str:
        lines = [f"== regime atlas: proposed/adaptive/adaptive_ra/delay vs "
                 f"fair (+fifo) ({len(self.seeds)} paired seeds/cell; "
                 f"{self.simulated} simulated, {self.cached} cached) =="]
        for c in self.cells:
            g, a, r = c.vs_fair, c.adaptive_vs_fair, c.ra_vs_fair
            lines.append(
                f"  {c.preset:13s} {c.shape:6s} {c.fabric:5s} "
                f"r{c.replication} {c.faults:12s} ({c.num_jobs:3d} jobs)  "
                f"prop {g.mean_gain_pct:+6.1f}% "
                f"[{g.ci_lo_pct:+6.1f}%, {g.ci_hi_pct:+6.1f}%] "
                f"-> {c.verdict():4s}  "
                f"adapt {a.mean_gain_pct:+6.1f}% "
                f"[{a.ci_lo_pct:+6.1f}%, {a.ci_hi_pct:+6.1f}%] "
                f"-> {c.adaptive_verdict():4s}  "
                f"ra {r.mean_gain_pct:+6.1f}% -> {c.ra_verdict():4s}  "
                f"delay {c.delay_vs_fair.mean_gain_pct:+6.1f}% "
                f"-> {c.delay_verdict():4s}  "
                f"Δlocal {c.locality_delta_pp():+5.1f}pp  "
                f"Δddl {c.deadline_delta_pp():+5.1f}pp")
        return "\n".join(lines)

    def to_markdown(self) -> str:
        head = [
            "| regime | cluster | fabric | repl | faults | jobs "
            "| proposed vs fair (95% CI) | verdict "
            "| adaptive vs fair (95% CI) | verdict "
            "| adaptive_ra vs fair (95% CI) | verdict "
            "| delay vs fair | verdict | adaptive vs proposed "
            "| Δ locality (prop/adapt/ra/delay) "
            "| Δ deadlines (prop/adapt/ra) |",
            "| --- | --- | --- | ---: | --- | ---: | --- | --- | --- | --- "
            "| --- | --- | --- | --- | --- | --- | --- |",
        ]
        rows = []
        for c in self.cells:
            f, a = c.vs_fair, c.adaptive_vs_fair
            r, d, ap = c.ra_vs_fair, c.delay_vs_fair, c.adaptive_vs_proposed
            rows.append(
                f"| {c.preset} | {c.shape} | {c.fabric} | {c.replication} "
                f"| {c.faults} | {c.num_jobs} "
                f"| {f.mean_gain_pct:+.1f}% [{f.ci_lo_pct:+.1f}%, "
                f"{f.ci_hi_pct:+.1f}%] | {c.verdict()} "
                f"| {a.mean_gain_pct:+.1f}% [{a.ci_lo_pct:+.1f}%, "
                f"{a.ci_hi_pct:+.1f}%] | {c.adaptive_verdict()} "
                f"| {r.mean_gain_pct:+.1f}% [{r.ci_lo_pct:+.1f}%, "
                f"{r.ci_hi_pct:+.1f}%] | {c.ra_verdict()} "
                f"| {d.mean_gain_pct:+.1f}% | {c.delay_verdict()} "
                f"| {ap.mean_gain_pct:+.1f}% "
                f"| {c.locality_delta_pp():+.1f} / "
                f"{c.locality_delta_pp('adaptive'):+.1f} / "
                f"{c.locality_delta_pp('adaptive_ra'):+.1f} / "
                f"{c.locality_delta_pp('delay'):+.1f} pp "
                f"| {c.deadline_delta_pp():+.1f} / "
                f"{c.deadline_delta_pp('adaptive'):+.1f} / "
                f"{c.deadline_delta_pp('adaptive_ra'):+.1f} pp |")
        return "\n".join(head + rows)


def _mean(vals: Sequence[float]) -> float:
    return sum(vals) / len(vals) if vals else 0.0


def run_regimes(presets: Sequence[str] = REGIME_PRESETS,
                shapes: Sequence[str] = FULL_SHAPES,
                seeds: Sequence[int] = FULL_SEEDS,
                cache_dir: Union[str, Path] = ".exp-cache",
                *, fabrics: Sequence[str] = (),
                replications: Sequence[int] = (),
                faults: Sequence[str] = (),
                swim: Sequence[str] = (),
                workers: int = 0, n_boot: int = 2000,
                progress=None) -> RegimeReport:
    """Run (or re-serve from cache) the full atlas grid and distill the
    per-regime verdicts.  ``fabrics`` adds a remote-penalty sweep and
    ``replications`` an HDFS-replica sweep: each extra fabric/replication
    re-runs every preset on the *first* shape (the paper's 20x2 unless
    overridden) with the scaled remote-read penalty / replica count.
    ``faults`` names ``FAULT_PROFILES`` entries: each profile re-runs every
    preset over the ``FAULT_SHAPES`` present in ``shapes`` (falling back to
    the first shape) with the profile's crash churn / heterogeneity.
    ``swim`` names committed SWIM trace fixtures (``SWIM_TRACES``) run as
    extra regime columns on the first shape."""
    for f in fabrics:
        if f not in FABRICS:
            raise ValueError(f"unknown fabric {f!r}; available: "
                             f"{', '.join(FABRICS)}")
    for r in replications:
        if not isinstance(r, int) or r < 1:
            raise ValueError(f"replication must be a positive int, got {r!r}")
    for fp in faults:
        if fp not in FAULT_PROFILES:
            raise ValueError(f"unknown fault profile {fp!r}; available: "
                             f"{', '.join(FAULT_PROFILES)}")
    for sw in swim:
        if sw not in SWIM_TRACES:
            raise ValueError(f"unknown SWIM trace {sw!r}; available: "
                             f"{', '.join(SWIM_TRACES)}")
    cells: List[RegimeCell] = []
    simulated = cached = 0
    fault_shapes = tuple(s for s in FAULT_SHAPES if s in shapes) \
        or (shapes[0],)
    points = [(preset, shape, BASE_FABRIC, BASE_REPLICATION, BASE_FAULTS)
              for preset in presets for shape in shapes]
    points += [(sw, shapes[0], BASE_FABRIC, BASE_REPLICATION, BASE_FAULTS)
               for sw in swim]
    points += [(preset, shapes[0], fabric, BASE_REPLICATION, BASE_FAULTS)
               for fabric in fabrics for preset in presets
               if fabric != BASE_FABRIC]
    points += [(preset, shapes[0], BASE_FABRIC, repl, BASE_FAULTS)
               for repl in replications for preset in presets
               if repl != BASE_REPLICATION]
    points += [(preset, shape, BASE_FABRIC, BASE_REPLICATION, fp)
               for fp in faults for shape in fault_shapes
               for preset in presets if fp != BASE_FAULTS]
    for preset, shape, fabric, repl, fprofile in points:
        spec = regime_spec(preset, shape, seeds, fabric=fabric,
                           replication=repl, faults=fprofile)
        report = run_experiment(spec, cache_dir, workers=workers,
                                progress=progress)
        simulated += report.simulated
        cached += report.cached
        by = report.by_scheduler()
        machines, vms = FLEET_SHAPES[shape]
        cells.append(RegimeCell(
            preset=preset,
            shape=shape,
            fabric=fabric,
            replication=repl,
            faults=fprofile,
            machines=machines,
            vms=vms,
            num_jobs=scaled_jobs(preset, machines),
            seeds=tuple(seeds),
            vs_fair=compare_throughput(by["fair"], by["proposed"],
                                       n_boot=n_boot),
            vs_fifo=compare_throughput(by["fifo"], by["proposed"],
                                       n_boot=n_boot),
            adaptive_vs_fair=compare_throughput(by["fair"], by["adaptive"],
                                                n_boot=n_boot),
            adaptive_vs_proposed=compare_throughput(
                by["proposed"], by["adaptive"], n_boot=n_boot),
            ra_vs_fair=compare_throughput(by["fair"], by["adaptive_ra"],
                                          n_boot=n_boot),
            ra_vs_adaptive=compare_throughput(
                by["adaptive"], by["adaptive_ra"], n_boot=n_boot),
            delay_vs_fair=compare_throughput(by["fair"], by["delay"],
                                             n_boot=n_boot),
            locality={s: _mean([r.locality_rate for r in rs])
                      for s, rs in by.items()},
            deadline_frac={
                s: _mean([r.deadlines_met / r.jobs_total for r in rs
                          if r.jobs_total])
                for s, rs in by.items()},
            mean_makespan={s: _mean([r.makespan for r in rs])
                           for s, rs in by.items()},
        ))
        if progress:
            c = cells[-1]
            progress(f"[{preset}/{shape}/{fabric}/r{repl}/{fprofile}] "
                     f"proposed "
                     f"{c.vs_fair.mean_gain_pct:+.1f}% -> {c.verdict()}, "
                     f"adaptive {c.adaptive_vs_fair.mean_gain_pct:+.1f}% "
                     f"-> {c.adaptive_verdict()}, "
                     f"ra {c.ra_vs_fair.mean_gain_pct:+.1f}% "
                     f"-> {c.ra_verdict()}")
    return RegimeReport(presets=tuple(presets), shapes=tuple(shapes),
                        seeds=tuple(seeds), cells=cells,
                        simulated=simulated, cached=cached,
                        fabrics=(BASE_FABRIC,) + tuple(
                            f for f in fabrics if f != BASE_FABRIC),
                        replications=(BASE_REPLICATION,) + tuple(
                            r for r in replications
                            if r != BASE_REPLICATION),
                        fault_profiles=(BASE_FAULTS,) + tuple(
                            fp for fp in faults if fp != BASE_FAULTS),
                        swim=tuple(swim))


# -- serving axis -------------------------------------------------------------

def serve_spec(profile: str, shape: str,
               seeds: Sequence[int] = FULL_SEEDS,
               preset: str = SERVE_PRESET) -> ExperimentSpec:
    """One serving cell as a sweep spec: the scaled batch trace plus the
    scaled service fleet, run under both ``SERVE_SCHEDULERS`` on identical
    inputs.  The serve config enters the cluster descriptor (and so the
    cache hash) — serving cells never collide with batch-only cells."""
    machines, _ = FLEET_SHAPES[shape]
    config = dataclasses.replace(PRESETS[preset],
                                 num_jobs=scaled_jobs(preset, machines))
    cluster = dataclasses.replace(fleet_shape(shape),
                                  serve=serve_profile(profile, machines))
    return ExperimentSpec(
        name=f"serve-{preset}-{shape}-{profile}",
        traces=(TraceRef(config=config),),
        clusters=(cluster,),
        schedulers=SERVE_SCHEDULERS,
        seeds=tuple(seeds),
    )


@dataclass
class ServeCell:
    """Verdict for one (serving profile, cluster shape) point: how much
    batch throughput does harvesting recover, and what does it cost the
    services' tail latency / SLO budget?"""

    profile: str
    shape: str
    machines: int
    vms: int
    num_jobs: int
    seeds: Tuple[int, ...]
    slo_bound: float                     # ServeConfig.slo_violation_bound
    throughput: PairedComparison         # harvest-vs-adaptive batch jph
    p99: PairedComparison                # serving p99 delta (lower better)
    violation_rate: Dict[str, float]     # mean SLO-violation rate per sched
    mean_p99_ms: Dict[str, float]
    mean_makespan: Dict[str, float]
    harvest_borrows: float               # mean per harvest run
    harvest_returns: float

    def verdict(self) -> str:
        return _verdict_of(self.throughput)

    def slo_ok(self) -> bool:
        """Every scheduler held the whole-run SLO-violation bound."""
        return all(v <= self.slo_bound + 1e-12
                   for v in self.violation_rate.values())

    def to_dict(self) -> Dict[str, object]:
        return {
            "profile": self.profile,
            "shape": self.shape,
            "machines": self.machines,
            "vms": self.vms,
            "num_jobs": self.num_jobs,
            "seeds": list(self.seeds),
            "slo_bound": self.slo_bound,
            "verdict": self.verdict(),
            "slo_ok": self.slo_ok(),
            "throughput_harvest_vs_adaptive": self.throughput.to_dict(),
            "serve_p99_harvest_vs_adaptive": self.p99.to_dict(),
            "violation_rate": self.violation_rate,
            "mean_p99_ms": self.mean_p99_ms,
            "mean_makespan": self.mean_makespan,
            "harvest_borrows": self.harvest_borrows,
            "harvest_returns": self.harvest_returns,
        }


@dataclass
class ServeReport:
    preset: str
    profiles: Tuple[str, ...]
    shapes: Tuple[str, ...]
    seeds: Tuple[int, ...]
    cells: List[ServeCell]
    simulated: int
    cached: int
    version: int = REPORT_VERSION

    def cell(self, profile: str, shape: str) -> ServeCell:
        for c in self.cells:
            if (c.profile, c.shape) == (profile, shape):
                return c
        raise KeyError((profile, shape))

    def to_dict(self) -> Dict[str, object]:
        return {
            "version": self.version,
            "preset": self.preset,
            "profiles": list(self.profiles),
            "shapes": list(self.shapes),
            "seeds": list(self.seeds),
            "schedulers": list(SERVE_SCHEDULERS),
            "simulated": self.simulated,
            "cached": self.cached,
            "cells": [c.to_dict() for c in self.cells],
        }

    def save_json(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True)
                        + "\n")
        return path

    def format(self) -> str:
        lines = [f"== serving atlas: harvest vs adaptive on co-located "
                 f"service fleets ({self.preset} batch mix, "
                 f"{len(self.seeds)} paired seeds/cell; "
                 f"{self.simulated} simulated, {self.cached} cached) =="]
        for c in self.cells:
            t, p = c.throughput, c.p99
            lines.append(
                f"  {c.profile:16s} {c.shape:6s} ({c.num_jobs:3d} jobs)  "
                f"batch {t.mean_gain_pct:+6.1f}% "
                f"[{t.ci_lo_pct:+6.1f}%, {t.ci_hi_pct:+6.1f}%] "
                f"-> {c.verdict():4s}  "
                f"p99 {p.mean_gain_pct:+6.1f}%  "
                f"viol {c.violation_rate.get('adaptive', 0.0):.4f}/"
                f"{c.violation_rate.get('harvest', 0.0):.4f} "
                f"(bound {c.slo_bound:.2f}) "
                f"{'ok' if c.slo_ok() else 'BREACH'}  "
                f"borrows {c.harvest_borrows:.1f}")
        return "\n".join(lines)

    def to_markdown(self) -> str:
        head = [
            "| profile | cluster | jobs | harvest vs adaptive batch "
            "(95% CI) | verdict | serve p99 Δ | violation rate "
            "(adaptive / harvest, bound) | SLO | borrows / returns |",
            "| --- | --- | ---: | --- | --- | --- | --- | --- | --- |",
        ]
        rows = []
        for c in self.cells:
            t, p = c.throughput, c.p99
            rows.append(
                f"| {c.profile} | {c.shape} | {c.num_jobs} "
                f"| {t.mean_gain_pct:+.1f}% [{t.ci_lo_pct:+.1f}%, "
                f"{t.ci_hi_pct:+.1f}%] | {c.verdict()} "
                f"| {p.mean_gain_pct:+.1f}% "
                f"| {c.violation_rate.get('adaptive', 0.0):.4f} / "
                f"{c.violation_rate.get('harvest', 0.0):.4f} "
                f"(≤ {c.slo_bound:.2f}) "
                f"| {'ok' if c.slo_ok() else '**breach**'} "
                f"| {c.harvest_borrows:.1f} / {c.harvest_returns:.1f} |")
        return "\n".join(head + rows)


def run_serve_regimes(profiles: Sequence[str] = SERVE_PROFILES,
                      shapes: Sequence[str] = SERVE_SHAPES,
                      seeds: Sequence[int] = FULL_SEEDS,
                      cache_dir: Union[str, Path] = ".exp-cache",
                      *, preset: str = SERVE_PRESET,
                      workers: int = 0, n_boot: int = 2000,
                      progress=None) -> ServeReport:
    """Run (or re-serve from cache) the serving axis: every profile x
    shape cell pairs ``harvest`` against ``adaptive`` on identical
    (trace, placement, jitter, request-stream) draws, so the throughput
    and p99 comparisons isolate the harvest component."""
    for p in profiles:
        if p not in _SERVE_BASES:
            raise ValueError(f"unknown serve profile {p!r}; available: "
                             f"{', '.join(_SERVE_BASES)}")
    cells: List[ServeCell] = []
    simulated = cached = 0
    for profile in profiles:
        for shape in shapes:
            spec = serve_spec(profile, shape, seeds, preset=preset)
            report = run_experiment(spec, cache_dir, workers=workers,
                                    progress=progress)
            simulated += report.simulated
            cached += report.cached
            by = report.by_scheduler()
            machines, vms = FLEET_SHAPES[shape]
            cells.append(ServeCell(
                profile=profile,
                shape=shape,
                machines=machines,
                vms=vms,
                num_jobs=scaled_jobs(preset, machines),
                seeds=tuple(seeds),
                slo_bound=serve_profile(profile,
                                        machines).slo_violation_bound,
                throughput=compare_throughput(by["adaptive"], by["harvest"],
                                              n_boot=n_boot),
                p99=compare_serve_p99(by["adaptive"], by["harvest"],
                                      n_boot=n_boot),
                violation_rate={
                    s: _mean([r.serve.get("violation_rate", 0.0)
                              for r in rs])
                    for s, rs in by.items()},
                mean_p99_ms={
                    s: _mean([r.serve.get("p99_ms", 0.0) for r in rs])
                    for s, rs in by.items()},
                mean_makespan={s: _mean([r.makespan for r in rs])
                               for s, rs in by.items()},
                harvest_borrows=_mean(
                    [r.serve.get("harvest_borrows", 0)
                     for r in by["harvest"]]),
                harvest_returns=_mean(
                    [r.serve.get("harvest_returns", 0)
                     for r in by["harvest"]]),
            ))
            if progress:
                c = cells[-1]
                progress(f"[serve {profile}/{shape}] batch "
                         f"{c.throughput.mean_gain_pct:+.1f}% "
                         f"-> {c.verdict()}, p99 "
                         f"{c.p99.mean_gain_pct:+.1f}%, "
                         f"viol {c.violation_rate.get('harvest', 0.0):.4f} "
                         f"({'ok' if c.slo_ok() else 'BREACH'})")
    return ServeReport(preset=preset, profiles=tuple(profiles),
                       shapes=tuple(shapes), seeds=tuple(seeds),
                       cells=cells, simulated=simulated, cached=cached)
