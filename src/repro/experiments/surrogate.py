"""Surrogate sweeps behind the experiments cache + the calibration gate.

This is the experiments-layer face of ``repro.simcluster.surrogate``: the
same declarative ``ExperimentSpec`` grids the event runner consumes, but
every cell integrates through the batched fluid engine — thousands of
(trace × policy × seed) cells per ``vmap`` batch instead of one Python
event loop per cell.

**Cache namespace.**  Surrogate results reuse the event runner's
content-hash cache layout (``<cell_hash>/meta.json`` + ``seed<k>.json``)
but the descriptor carries an extra ``"engine": SURROGATE_ENGINE_ID`` key
the event engine's descriptors never have, so the two engines' hashes are
disjoint by construction: a surrogate sweep can never serve — or pollute —
an event-engine cell (pinned by ``tests/test_experiments.py``).

**Calibration gate.**  The fluid model is only trusted where the
differential wall (``tests/test_surrogate.py``) has shown its policy-vs-
fair throughput gain inside the event oracle's paired-bootstrap CI on
identical (trace, seed) cells.  ``CALIBRATED`` pins exactly that set;
``calibrate`` recomputes the comparison on demand (the ``surrogate`` CLI
verb prints it next to every sweep).  Pairs outside the allowlist stay
oracle-only: at 20×2, fifo-under-heavy-tail (a sub-resolution head-of-line
cost), proposed/delay under ``bursty`` and ``saturated`` (deep-backlog
locality the constant-draws model does not reach), and proposed under
``shuffle_heavy``; the 50×2 shape compresses every gain to ±1–3% and is
entirely oracle-only for now.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.policies import PolicySpec
from repro.experiments.metrics import JobRecord, RunRecord
from repro.experiments.regimes import regime_spec
from repro.experiments.runner import (Cell, ExperimentSpec, SweepReport,
                                      run_experiment)
from repro.experiments.stats import PairedComparison, compare_throughput
from repro.simcluster.surrogate import (SURROGATE_ENGINE_ID,
                                        SurrogateResult,
                                        SurrogateUnsupported, build_cell,
                                        lower_policy, run_batch)
from repro.simcluster.traces import _dumps

#: the differential wall's verdict, pinned: (preset, fleet shape) → the
#: policy labels whose policy-vs-fair gain the surrogate reproduces inside
#: the event oracle's 95% paired-bootstrap CI (4 paired seeds).  The wall
#: in tests/test_surrogate.py re-derives this table from live runs and
#: fails loudly on any drift — growing it requires re-calibration, not an
#: edit here.
CALIBRATED: Dict[Tuple[str, str], Tuple[str, ...]] = {
    ("heavy_tail", "20x2"): ("proposed", "delay", "edf_nopark"),
    ("diurnal", "20x2"): ("proposed", "delay", "fifo", "edf_nopark"),
    ("bursty", "20x2"): ("fifo", "edf_nopark"),
    ("shuffle_heavy", "20x2"): ("delay", "fifo", "edf_nopark"),
    ("saturated", "20x2"): ("fifo", "edf_nopark"),
}
#: seeds the wall calibrates over (paired across engines per cell)
CALIBRATION_SEEDS: Tuple[int, ...] = (0, 1, 2, 3)


def surrogate_descriptor(cell: Cell) -> Dict[str, object]:
    """The event cell descriptor plus the engine-id key — the *only*
    difference, so one grid maps to two parallel hash families."""
    d = cell.descriptor()
    d["engine"] = SURROGATE_ENGINE_ID
    return d


def surrogate_hash(cell: Cell) -> str:
    return hashlib.sha256(
        _dumps(surrogate_descriptor(cell)).encode()).hexdigest()[:16]


def _cell_paths(cache_dir: Path, cell: Cell) -> Tuple[Path, Path]:
    cell_dir = cache_dir / surrogate_hash(cell)
    return cell_dir, cell_dir / f"seed{cell.seed}.json"


def _record(cell: Cell, res: SurrogateResult, trace_name: str,
            trace_seed: int, wall_time_s: float) -> RunRecord:
    jobs = [JobRecord(
        job_id=j.job_id, workload=j.workload, input_gb=j.input_gb,
        submit_time=j.submit_time, deadline=j.deadline,
        finish_time=j.finish_time, completion_time=j.completion_time,
        deadline_met=j.deadline_met,
        local_map_launches=j.local_map_launches,
        remote_map_launches=j.remote_map_launches,
        # the fluid model folds park wins into the local flow; it does
        # not attribute them separately per job
        reconfig_map_launches=0.0) for j in res.jobs]
    return RunRecord(
        trace_name=trace_name, trace_seed=trace_seed,
        cluster=cell.cluster.to_dict(), scheduler=cell.scheduler.label,
        seed=cell.seed, makespan=res.makespan,
        throughput_jph=res.throughput_jobs_per_hour(),
        jobs_total=res.jobs_total, jobs_finished=res.jobs_finished,
        deadlines_met=res.deadlines_met, locality_rate=res.locality_rate,
        speculative_launches=0, events_processed=0,
        wall_time_s=wall_time_s,
        reconfig_stats={"latched_steps": res.latched_steps},
        jobs=jobs, policy=cell.scheduler.to_dict())


def run_surrogate(spec: ExperimentSpec, cache_dir: Union[str, Path],
                  *, progress=None) -> SweepReport:
    """Run (or re-serve from cache) every cell of ``spec`` through the
    batched fluid engine.

    Mirrors ``run_experiment``'s contract — same cache layout, same
    ``SweepReport`` — but all cache-missing cells integrate in one
    ``run_batch`` call (grouped by padded shape into a handful of XLA
    computations).  Every policy in the grid must lower;
    :class:`SurrogateUnsupported` propagates *before* any cell runs, so a
    grid with an unmodelable policy never half-completes.
    """
    for sched in spec.schedulers:
        lower_policy(sched)          # raises SurrogateUnsupported
    cache_dir = Path(cache_dir)
    cache_dir.mkdir(parents=True, exist_ok=True)
    records: List[RunRecord] = []
    todo: List[Cell] = []
    for cell in spec.cells():
        _, result_path = _cell_paths(cache_dir, cell)
        if result_path.exists():
            records.append(RunRecord.from_dict(
                json.loads(result_path.read_text())))
        else:
            todo.append(cell)
    if progress:
        progress(f"[{spec.name}] {spec.n_cells()} surrogate cells: "
                 f"{len(records)} cached, {len(todo)} to integrate")
    if todo:
        t0 = time.perf_counter()
        resolved: Dict[Tuple[int, int], object] = {}
        for cell in todo:
            key = (id(cell.trace), cell.seed)
            if key not in resolved:
                resolved[key] = cell.trace.resolve(cell.seed)
        traces = [resolved[(id(cell.trace), cell.seed)] for cell in todo]
        # the expensive per-job compilation (block placements, jitter) is
        # policy-independent: build once per (trace, seed, cluster) and
        # swap only the lowered policy across the grid's policy columns
        base: Dict[Tuple[int, int, int], object] = {}
        inputs = []
        for cell, trace in zip(todo, traces):
            key = (id(trace), id(cell.cluster), cell.seed)
            if key not in base:
                base[key] = build_cell(trace, cell.cluster,
                                       cell.scheduler, cell.seed)
                inputs.append(base[key])
            else:
                inputs.append(dataclasses.replace(
                    base[key], policy=lower_policy(cell.scheduler)))
        results = run_batch(inputs)
        per_cell = (time.perf_counter() - t0) / len(todo)
        for cell, trace, res in zip(todo, traces, results):
            rec = _record(cell, res, trace.name, trace.seed, per_cell)
            cell_dir, result_path = _cell_paths(cache_dir, cell)
            cell_dir.mkdir(parents=True, exist_ok=True)
            meta_path = cell_dir / "meta.json"
            if not meta_path.exists():
                meta_path.write_text(json.dumps(
                    surrogate_descriptor(cell), indent=2, sort_keys=True)
                    + "\n")
            result_path.write_text(_dumps(rec.to_dict()) + "\n")
            records.append(rec)
        if progress:
            progress(f"  integrated {len(todo)} cells in "
                     f"{per_cell * len(todo):.2f}s "
                     f"({1.0 / per_cell:.0f} cells/s)")
    records.sort(key=lambda r: (r.trace_name, r.trace_seed,
                                _dumps(r.cluster), r.scheduler, r.seed))
    return SweepReport(spec_name=spec.name, records=records,
                       simulated=len(todo),
                       cached=spec.n_cells() - len(todo))


# ---------------------------------------------------------------------------
# differential calibration
# ---------------------------------------------------------------------------

@dataclass
class PolicyCalibration:
    """One (policy vs fair) differential: oracle CI vs surrogate mean."""

    policy: str
    oracle: PairedComparison
    surrogate_gain_pct: float
    allowlisted: bool

    @property
    def inside(self) -> bool:
        return (self.oracle.ci_lo_pct <= self.surrogate_gain_pct
                <= self.oracle.ci_hi_pct)


@dataclass
class CalibrationReport:
    preset: str
    shape: str
    seeds: Tuple[int, ...]
    policies: List[PolicyCalibration] = field(default_factory=list)

    @property
    def wall_green(self) -> bool:
        """Every allowlisted policy's surrogate gain inside the oracle CI."""
        return all(p.inside for p in self.policies if p.allowlisted)


def calibrate(preset: str, shape: str, cache_dir: Union[str, Path],
              *, seeds: Sequence[int] = CALIBRATION_SEEDS,
              policies: Optional[Sequence[str]] = None,
              workers: int = 0, progress=None) -> CalibrationReport:
    """Run surrogate and event engine on identical (trace, seed) cells and
    compare each policy's throughput-vs-fair gain against the oracle's
    paired-bootstrap CI.

    ``policies`` defaults to every surrogate-lowerable policy under test
    (the allowlisted set plus any extra being evaluated for promotion);
    ``fair`` is always added as the shared baseline.  Both engines read
    and write ``cache_dir`` — their cells hash into disjoint namespaces.
    """
    allow = CALIBRATED.get((preset, shape), ())
    pols = tuple(policies) if policies is not None else allow
    pols = tuple(p for p in pols if p != "fair")
    base = regime_spec(preset, shape, seeds=tuple(seeds))
    spec = ExperimentSpec(name=f"surrogate-cal-{preset}-{shape}",
                          traces=base.traces, clusters=base.clusters,
                          schedulers=pols + ("fair",),
                          seeds=tuple(seeds))
    oracle = run_experiment(spec, cache_dir, workers=workers,
                            progress=progress)
    sur = run_surrogate(spec, cache_dir, progress=progress)
    o_by = oracle.by_scheduler()
    s_by = sur.by_scheduler()
    report = CalibrationReport(preset=preset, shape=shape,
                               seeds=tuple(seeds))
    for pol in pols:
        oc = compare_throughput(o_by["fair"], o_by[pol])
        sc = compare_throughput(s_by["fair"], s_by[pol])
        report.policies.append(PolicyCalibration(
            policy=pol, oracle=oc, surrogate_gain_pct=sc.mean_gain_pct,
            allowlisted=pol in allow))
    return report
