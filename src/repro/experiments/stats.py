"""Paired-bootstrap statistics for scheduler-vs-scheduler comparison.

Every comparison pairs runs on the *same* (trace, trace seed, cluster, sim
seed) cell — the two schedulers saw identical arrivals, placements and
jitter draws, so the per-pair gain isolates the policy.  Confidence
intervals are percentile bootstrap over the pairs (resampling seeds, the
replication unit), which makes no normality assumption — gains here are
ratios of makespan-derived throughputs and visibly skewed.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

from repro.experiments.metrics import RunRecord

DEFAULT_N_BOOT = 2000


def percentile(values: Sequence[float], q: float) -> float:
    """Exact nearest-rank percentile of ``values`` (q in [0, 100]).

    The nearest-rank method (``sorted[ceil(q/100 * n) - 1]``) returns an
    actual sample — no interpolation — so p50/p99 over request-latency
    samples are exact order statistics and byte-stable across runs.
    Raises on an empty sample."""
    if not values:
        raise ValueError("percentile over empty sample")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    ordered = sorted(values)
    if q == 0.0:
        return ordered[0]
    rank = math.ceil(q / 100.0 * len(ordered))
    return ordered[rank - 1]


def latency_summary(samples: Sequence[float]) -> Dict[str, float]:
    """Exact p50/p99/mean over request sojourn samples (the serving
    fold's summary unit).  An empty sample folds to zeros — a service
    that received no requests has no latency, not an error."""
    if not samples:
        return {"n": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0}
    return {
        "n": len(samples),
        "mean": sum(samples) / len(samples),
        "p50": percentile(samples, 50.0),
        "p99": percentile(samples, 99.0),
    }


def bootstrap_mean_ci(values: Sequence[float], *, n_boot: int = DEFAULT_N_BOOT,
                      alpha: float = 0.05, seed: int = 0
                      ) -> Tuple[float, float, float]:
    """(mean, ci_lo, ci_hi) — percentile bootstrap over ``values``."""
    vals = list(values)
    if not vals:
        raise ValueError("bootstrap over empty sample")
    mean = sum(vals) / len(vals)
    if len(vals) == 1:
        return mean, mean, mean
    rng = random.Random(seed)
    n = len(vals)
    means = sorted(
        sum(vals[rng.randrange(n)] for _ in range(n)) / n
        for _ in range(n_boot))
    lo = means[int(math.floor((alpha / 2) * (n_boot - 1)))]
    hi = means[int(math.ceil((1 - alpha / 2) * (n_boot - 1)))]
    return mean, lo, hi


@dataclass
class PairedComparison:
    """B-vs-A paired comparison of one metric ("gain" = how much B beats A)."""

    metric: str
    n_pairs: int
    mean_a: float
    mean_b: float
    mean_gain_pct: float
    ci_lo_pct: float
    ci_hi_pct: float
    win_rate: float                     # fraction of pairs where B beats A

    def to_dict(self) -> Dict[str, object]:
        return dict(self.__dict__)

    def format(self, label_a: str = "A", label_b: str = "B") -> str:
        return (f"{self.metric}: {label_a} {self.mean_a:.1f} vs {label_b} "
                f"{self.mean_b:.1f}  gain {self.mean_gain_pct:+.1f}% "
                f"[{self.ci_lo_pct:+.1f}%, {self.ci_hi_pct:+.1f}%] "
                f"(95% CI, n={self.n_pairs}, win rate {self.win_rate:.0%})")


def paired_bootstrap(a: Sequence[float], b: Sequence[float], *,
                     metric: str = "metric", higher_is_better: bool = True,
                     n_boot: int = DEFAULT_N_BOOT, alpha: float = 0.05,
                     seed: int = 0) -> PairedComparison:
    """Paired gain of B over A with a percentile-bootstrap CI.

    Per-pair gain: ``b/a - 1`` when higher is better (throughput), ``1 -
    b/a`` when lower is better (completion time) — positive always means
    "B wins"."""
    if len(a) != len(b):
        raise ValueError(f"paired samples differ in length: {len(a)} vs {len(b)}")
    if not a:
        raise ValueError("paired bootstrap over empty sample")
    gains = []
    wins = 0
    for x, y in zip(a, b):
        ok_x = math.isfinite(x) and x > 0
        ok_y = math.isfinite(y) and y > 0
        if ok_x and ok_y:
            g = (y / x - 1.0) if higher_is_better else (1.0 - y / x)
        elif ok_x == ok_y:
            g = 0.0       # both degenerate (e.g. neither run finished): a tie
        else:
            # exactly one side degenerate (zero throughput / unfinished run =
            # inf completion): a capped win or loss for B, whichever side
            # still produced a valid measurement
            g = 1.0 if ok_y else -1.0
        gains.append(g)
        if g > 0:
            wins += 1
    mean, lo, hi = bootstrap_mean_ci(gains, n_boot=n_boot, alpha=alpha,
                                     seed=seed)
    return PairedComparison(
        metric=metric,
        n_pairs=len(gains),
        mean_a=sum(a) / len(a),
        mean_b=sum(b) / len(b),
        mean_gain_pct=mean * 100.0,
        ci_lo_pct=lo * 100.0,
        ci_hi_pct=hi * 100.0,
        win_rate=wins / len(gains),
    )


def _pair_records(records_a: Sequence[RunRecord],
                  records_b: Sequence[RunRecord]
                  ) -> List[Tuple[RunRecord, RunRecord]]:
    by_key_a = {r.pair_key(): r for r in records_a}
    by_key_b = {r.pair_key(): r for r in records_b}
    common = sorted(set(by_key_a) & set(by_key_b))
    if not common:
        raise ValueError("no common (trace, cluster, seed) cells to pair on")
    return [(by_key_a[k], by_key_b[k]) for k in common]


def compare_throughput(records_a: Sequence[RunRecord],
                       records_b: Sequence[RunRecord], *,
                       n_boot: int = DEFAULT_N_BOOT,
                       seed: int = 0) -> PairedComparison:
    """Job-throughput gain of B over A, paired per (trace, cluster, seed)."""
    pairs = _pair_records(records_a, records_b)
    return paired_bootstrap(
        [pa.throughput_jph for pa, _ in pairs],
        [pb.throughput_jph for _, pb in pairs],
        metric="throughput_jobs_per_hour", higher_is_better=True,
        n_boot=n_boot, seed=seed)


def compare_completion_by_workload(records_a: Sequence[RunRecord],
                                   records_b: Sequence[RunRecord], *,
                                   n_boot: int = DEFAULT_N_BOOT,
                                   seed: int = 0
                                   ) -> Dict[str, PairedComparison]:
    """Per-workload completion-time gain (B faster than A) — the Fig.-3 view."""
    pairs = _pair_records(records_a, records_b)
    per_a: Dict[str, List[float]] = {}
    per_b: Dict[str, List[float]] = {}
    for pa, pb in pairs:
        ca, cb = (pa.mean_completion_by_workload(),
                  pb.mean_completion_by_workload())
        for w in set(ca) & set(cb):
            per_a.setdefault(w, []).append(ca[w])
            per_b.setdefault(w, []).append(cb[w])
    return {w: paired_bootstrap(per_a[w], per_b[w],
                                metric=f"completion_time[{w}]",
                                higher_is_better=False, n_boot=n_boot,
                                seed=seed)
            for w in sorted(per_a)}


def compare_deadlines(records_a: Sequence[RunRecord],
                      records_b: Sequence[RunRecord]) -> Dict[str, float]:
    """Mean deadlines-met per run for each side (no CI — small integers)."""
    pairs = _pair_records(records_a, records_b)
    return {
        "mean_a": sum(pa.deadlines_met for pa, _ in pairs) / len(pairs),
        "mean_b": sum(pb.deadlines_met for _, pb in pairs) / len(pairs),
        "n_pairs": len(pairs),
    }


def compare_serve_p99(records_a: Sequence[RunRecord],
                      records_b: Sequence[RunRecord], *,
                      n_boot: int = DEFAULT_N_BOOT,
                      seed: int = 0) -> PairedComparison:
    """Whole-run serving p99-latency delta of B vs A (lower is better),
    paired per (trace, cluster, seed).  Both sides must carry serving
    metrics (``RunRecord.serve``) — e.g. a harvest policy vs its
    no-harvest baseline on an identical service fleet."""
    pairs = _pair_records(records_a, records_b)
    missing = [r.scheduler for r, _ in pairs if not r.serve] + \
              [r.scheduler for _, r in pairs if not r.serve]
    if missing:
        raise ValueError(
            f"runs without serving metrics cannot compare p99: {missing}")
    return paired_bootstrap(
        [pa.serve["p99_ms"] for pa, _ in pairs],
        [pb.serve["p99_ms"] for _, pb in pairs],
        metric="serve_p99_ms", higher_is_better=False,
        n_boot=n_boot, seed=seed)
