"""Trace telemetry: fold a decision-trace bus into warehouse metrics,
export it (canonical JSONL + Chrome ``trace_event`` JSON for Perfetto),
and power the ``explain`` CLI verb.

The sink side of ``repro.core.tracing``: the engine emits raw records;
this module turns them into the quantities the atlas narrative argues
with — locality split, park win/loss by cause, park-denial attribution by
Algorithm-1 gate, overload-latch residency, remote-transfer cost — and
stores the folded summary next to the cell's ``RunRecord`` in the sweep
warehouse (``<cache>/<cell_hash>/seed<k>.trace.json``).  Tracing never
enters the cell descriptor (``ClusterSpec.to_dict`` drops it), so a traced
replay hashes onto the same cache cell it explains.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple, Union

from repro.core.tracing import TraceBus, dumps_canonical
from repro.core.types import TraceConfig
from repro.experiments.metrics import RunRecord, run_record_from_result
from repro.experiments.runner import Cell, _cell_paths
from repro.simcluster.sim import ClusterSim

# park-wait histogram bucket upper bounds (seconds); the last bucket is
# open-ended
WAIT_BUCKETS: Tuple[float, ...] = (0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 60.0)


@dataclass
class LatchEpisode:
    """One overload-latch residency interval.  ``released_at`` is None when
    the latch never released (it held to the end of the run)."""

    tripped_at: float
    released_at: Optional[float]
    release_cause: Optional[str]
    trip_signals: Dict[str, object] = field(default_factory=dict)

    def residency(self, makespan: float) -> float:
        end = self.released_at if self.released_at is not None else makespan
        return max(0.0, end - self.tripped_at)


@dataclass
class TraceSummary:
    """A ``TraceBus`` folded into per-run decision metrics."""

    makespan: float
    counts: Dict[str, int]               # records emitted, by kind
    dropped: int                         # past TraceConfig.max_events
    # -- locality / launches ------------------------------------------------
    maps_local: int = 0                  # non-speculative map launches
    maps_remote: int = 0
    maps_via_reconfig: int = 0           # unplugged-core launches (subset)
    reduces: int = 0
    speculative: int = 0
    kills: Dict[str, int] = field(default_factory=dict)      # by cause
    # -- remote-transfer cost ----------------------------------------------
    local_map_seconds: float = 0.0       # finished non-spec map runtimes
    remote_map_seconds: float = 0.0
    # -- park funnel --------------------------------------------------------
    park_admits: int = 0
    park_denies: Dict[str, int] = field(default_factory=dict)  # by gate
    park_wins: Dict[str, int] = field(default_factory=dict)    # by cause
    park_losses: int = 0
    park_expired: int = 0
    park_crashed: int = 0
    # histogram of realized park waits (donor matches + expiries), bucketed
    # by WAIT_BUCKETS; the final bucket is > the last bound
    park_wait_hist: List[int] = field(
        default_factory=lambda: [0] * (len(WAIT_BUCKETS) + 1))
    # -- overload latch -----------------------------------------------------
    latch_episodes: List[LatchEpisode] = field(default_factory=list)
    # -- per-machine / per-job timelines ------------------------------------
    machine_launches: Dict[int, int] = field(default_factory=dict)
    machine_crashes: Dict[int, int] = field(default_factory=dict)
    job_maps: Dict[str, Dict[str, int]] = field(default_factory=dict)
    # -- serving / harvest --------------------------------------------------
    serve_ticks: int = 0
    harvest_borrows: Dict[str, int] = field(default_factory=dict)   # by signal
    harvest_returns: Dict[str, int] = field(default_factory=dict)   # by signal
    # per-service latency timeline [t, p99_ms] (one point per replica tick)
    service_timeline: Dict[str, List[List[float]]] = field(
        default_factory=dict)
    # per-service SLO residency: fraction of replica ticks whose p99 held
    # under the service's SLO ({"ticks", "ok_ticks", "residency"})
    service_slo: Dict[str, Dict[str, float]] = field(default_factory=dict)

    # -- derived ------------------------------------------------------------
    def locality_rate(self) -> float:
        tot = self.maps_local + self.maps_remote
        return self.maps_local / tot if tot else 0.0

    def latch_residency(self) -> float:
        return sum(e.residency(self.makespan) for e in self.latch_episodes)

    def latch_residency_frac(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.latch_residency() / self.makespan

    def total_park_wins(self) -> int:
        return sum(self.park_wins.values())

    def total_harvest_borrows(self) -> int:
        return sum(self.harvest_borrows.values())

    def total_harvest_returns(self) -> int:
        return sum(self.harvest_returns.values())

    def to_dict(self) -> Dict[str, object]:
        d = dict(self.__dict__)
        d["latch_episodes"] = [
            {"tripped_at": e.tripped_at, "released_at": e.released_at,
             "release_cause": e.release_cause,
             "trip_signals": e.trip_signals}
            for e in self.latch_episodes]
        # JSON object keys are strings; keep machine maps sortable
        d["machine_launches"] = {str(k): v
                                 for k, v in self.machine_launches.items()}
        d["machine_crashes"] = {str(k): v
                                for k, v in self.machine_crashes.items()}
        d["locality_rate"] = self.locality_rate()
        d["latch_residency"] = self.latch_residency()
        d["latch_residency_frac"] = self.latch_residency_frac()
        return d


def _bucket(hist: List[int], wait: float) -> None:
    for i, bound in enumerate(WAIT_BUCKETS):
        if wait <= bound:
            hist[i] += 1
            return
    hist[-1] += 1


def fold_trace(bus: TraceBus, makespan: float) -> TraceSummary:
    """Fold retained bus records into a :class:`TraceSummary`.

    Works from the retained event list, so a capped bus (``dropped > 0``)
    folds what survived — the per-kind ``counts`` still cover everything."""
    s = TraceSummary(makespan=makespan, counts=dict(bus.counts),
                     dropped=bus.dropped)
    open_latch: Optional[LatchEpisode] = None
    for t, kind, data in bus.events:
        if kind == "launch":
            if data.get("spec"):
                s.speculative += 1
            elif data["tkind"] == "map":
                if data["local"]:
                    s.maps_local += 1
                else:
                    s.maps_remote += 1
                if data.get("via_reconfig"):
                    s.maps_via_reconfig += 1
                jm = s.job_maps.setdefault(
                    data["job"], {"local": 0, "remote": 0})
                jm["local" if data["local"] else "remote"] += 1
            else:
                s.reduces += 1
            m = data.get("machine")
            if m is not None:
                s.machine_launches[m] = s.machine_launches.get(m, 0) + 1
        elif kind == "finish":
            if data["tkind"] == "map" and not data.get("spec"):
                if data["local"]:
                    s.local_map_seconds += data["duration"]
                else:
                    s.remote_map_seconds += data["duration"]
        elif kind == "kill":
            cause = data.get("cause", "unknown")
            s.kills[cause] = s.kills.get(cause, 0) + 1
        elif kind == "park_admit":
            s.park_admits += 1
        elif kind == "park_deny":
            gate = data.get("gate", "unknown")
            s.park_denies[gate] = s.park_denies.get(gate, 0) + 1
        elif kind == "park_outcome":
            if data["won"]:
                cause = data.get("cause", "unknown")
                s.park_wins[cause] = s.park_wins.get(cause, 0) + 1
            else:
                s.park_losses += 1
        elif kind == "reconfig_match":
            _bucket(s.park_wait_hist, data["wait"])
        elif kind == "park_expired":
            s.park_expired += 1
            _bucket(s.park_wait_hist, data["waited"])
        elif kind == "park_crashed":
            s.park_crashed += 1
        elif kind == "latch_trip":
            if open_latch is None:
                open_latch = LatchEpisode(t, None, None, dict(data))
                s.latch_episodes.append(open_latch)
        elif kind == "latch_release":
            if open_latch is not None:
                open_latch.released_at = t
                open_latch.release_cause = data.get("cause")
                open_latch = None
        elif kind == "crash":
            m = data["machine"]
            s.machine_crashes[m] = s.machine_crashes.get(m, 0) + 1
        elif kind == "serve_tick":
            s.serve_ticks += 1
            svc = data["service"]
            s.service_timeline.setdefault(svc, []).append(
                [t, data["p99_ms"]])
            slo = s.service_slo.setdefault(
                svc, {"ticks": 0, "ok_ticks": 0})
            slo["ticks"] += 1
            if data["p99_ms"] <= data["slo_p99_ms"]:
                slo["ok_ticks"] += 1
        elif kind == "harvest_borrow":
            sig = data.get("signal", "unknown")
            s.harvest_borrows[sig] = s.harvest_borrows.get(sig, 0) + 1
        elif kind == "harvest_return":
            sig = data.get("signal", "unknown")
            s.harvest_returns[sig] = s.harvest_returns.get(sig, 0) + 1
    for slo in s.service_slo.values():
        slo["residency"] = (slo["ok_ticks"] / slo["ticks"]
                            if slo["ticks"] else 1.0)
    return s


# -- exporters ---------------------------------------------------------------

def write_jsonl(bus: TraceBus, path: Union[str, Path]) -> Path:
    """Canonical JSONL: one sorted-key record per line, byte-stable per
    (config, seed) — the diffable/hashable artifact."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(bus.to_jsonl())
    return path


def chrome_trace_events(bus: TraceBus) -> List[Dict[str, object]]:
    """Chrome ``trace_event`` view of the bus (open the written file in
    Perfetto / chrome://tracing): task executions are complete ``X`` slices
    (pid = physical machine, tid = VM/node), park and latch decisions are
    instant events, and pressure snapshots are ``C`` counter tracks."""
    out: List[Dict[str, object]] = []
    us = 1e6                             # trace_event timestamps are µs
    # open launches by (task, speculative); finish/kill events close them
    open_runs: Dict[Tuple[str, bool], Dict[str, object]] = {}
    for t, kind, data in bus.events:
        if kind == "launch":
            open_runs[(data["task"], bool(data.get("spec")))] = {
                "t": t, "node": data["node"],
                "machine": data.get("machine", 0),
                "tkind": data["tkind"], "local": data["local"]}
        elif kind in ("finish", "kill"):
            key = (data["task"], bool(data.get("spec")))
            start = open_runs.pop(key, None)
            begin = start["t"] if start is not None else data.get("start", t)
            node = data["node"]
            machine = (start["machine"] if start is not None
                       else data.get("machine", 0))
            out.append({
                "name": str(data["task"]), "ph": "X",
                "cat": data["tkind"] + ("-killed" if kind == "kill" else ""),
                "pid": machine, "tid": node,
                "ts": begin * us, "dur": max(0.0, (t - begin)) * us,
                "args": {k: v for k, v in data.items()
                         if k not in ("task", "tkind", "node")},
            })
        elif kind == "serve_tick":
            out.append({
                "name": f"serve:{data['service']}", "ph": "C",
                "pid": data.get("machine", 0), "ts": t * us,
                "args": {"p99_ms": data["p99_ms"], "util": data["util"],
                         "cores": data["cores"]}})
        elif kind in ("park_admit", "park_deny", "unpark", "park_expired",
                      "park_crashed", "park_outcome", "reconfig_match",
                      "harvest_borrow", "harvest_return",
                      "crash", "restart", "burst", "rereplicate"):
            out.append({
                "name": (f"{kind}:{data['gate']}" if kind == "park_deny"
                         else kind),
                "ph": "i", "s": "p", "cat": "decision",
                "pid": data.get("machine", 0),
                "tid": data.get("node", data.get("target_vm", 0)),
                "ts": t * us, "args": dict(data),
            })
        elif kind in ("latch_trip", "latch_release"):
            out.append({"name": kind, "ph": "i", "s": "g", "cat": "overload",
                        "pid": 0, "tid": 0, "ts": t * us,
                        "args": dict(data)})
        elif kind == "pressure":
            out.append({"name": "pressure", "ph": "C", "pid": 0,
                        "ts": t * us,
                        "args": {"pending_maps": data["pending_maps"],
                                 "active_jobs": data["active_jobs"],
                                 "ready_reduces": data["ready_reduces"],
                                 "parked": data.get("parked", 0),
                                 "down_nodes": data["down_nodes"]}})
    return out


def write_chrome_trace(bus: TraceBus, path: Union[str, Path]) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    # args dicts carry raw TaskId objects off the bus; render them as the
    # same canonical strings the JSONL exporter uses
    path.write_text(json.dumps(
        {"traceEvents": chrome_trace_events(bus),
         "displayTimeUnit": "ms"}, default=str) + "\n")
    return path


# -- warehouse integration ---------------------------------------------------

def simulate_cell_traced(cell: Cell,
                         tracing: Optional[TraceConfig] = None
                         ) -> Tuple[RunRecord, TraceBus]:
    """Replay one sweep cell with the decision-trace bus attached.

    Identical inputs to ``runner.simulate_cell`` — same trace, placements,
    jitter draws — so the traced replay reproduces the cached run
    bit-exactly (tracing draws from no RNG); it just also returns the bus."""
    tracing = tracing or TraceConfig(enabled=True)
    spec = dataclasses.replace(cell.cluster, tracing=tracing)
    trace = cell.trace.resolve(cell.seed)
    jobs = trace.job_specs(spec)
    sched = cell.scheduler.build(spec)
    sim = ClusterSim(spec, sched, seed=cell.seed,
                     straggler_prob=cell.straggler_prob,
                     straggler_factor=cell.straggler_factor,
                     speculative=cell.speculative,
                     speculation_threshold=cell.speculation_threshold)
    t0 = time.perf_counter()
    result = sim.run(jobs)
    wall = time.perf_counter() - t0
    record = run_record_from_result(
        result, trace=trace, cluster_dict=cell.cluster.to_dict(),
        scheduler=cell.scheduler.label, seed=cell.seed, wall_time_s=wall,
        policy=cell.scheduler.to_dict())
    return record, result.trace


def store_trace_summary(cache_dir: Union[str, Path], cell: Cell,
                        summary: TraceSummary) -> Path:
    """Write the folded summary next to the cell's ``RunRecord``:
    ``<cache>/<cell_hash>/seed<k>.trace.json``.  The cell hash is the
    *untraced* hash (tracing never enters the descriptor), so the summary
    sits beside the record it explains."""
    cell_dir, result_path = _cell_paths(Path(cache_dir), cell)
    cell_dir.mkdir(parents=True, exist_ok=True)
    path = cell_dir / (result_path.stem + ".trace.json")
    path.write_text(dumps_canonical(summary.to_dict()) + "\n")
    return path


# -- the `explain` verb ------------------------------------------------------

def _pct(part: int, whole: int) -> str:
    return f"{100.0 * part / whole:.0f}%" if whole else "n/a"


def format_summary(label: str, record: RunRecord,
                   summary: TraceSummary) -> str:
    """Human-readable decision-attribution block for one traced run."""
    lines = [f"{label}: makespan {record.makespan:.1f}s, "
             f"throughput {record.throughput_jph:.1f} jobs/h, "
             f"locality {summary.locality_rate() * 100:.1f}%, "
             f"deadlines {record.deadlines_met}/{record.jobs_total}"]
    # latch story
    eps = summary.latch_episodes
    if eps:
        e = eps[0]
        sig = e.trip_signals
        trip = (f"  latch: tripped at t={e.tripped_at:.1f} "
                f"(pending={sig.get('pending_maps')} >= "
                f"{sig.get('pending_bar', 0.0):.0f}, "
                f"crowd={sig.get('crowd')} >= "
                f"{sig.get('crowd_bar', 0.0):.0f})")
        if e.released_at is None:
            trip += ", released never"
        else:
            trip += (f", released at t={e.released_at:.1f} "
                     f"({e.release_cause})")
        if len(eps) > 1:
            trip += f" (+{len(eps) - 1} more episode(s))"
        trip += (f"; latched "
                 f"{summary.latch_residency_frac() * 100:.1f}% of the run")
        lines.append(trip)
    else:
        lines.append("  latch: never tripped")
    # park funnel
    denies = sum(summary.park_denies.values())
    lines.append(f"  parks: {summary.park_admits} admitted, "
                 f"{denies} denied, {summary.total_park_wins()} won "
                 f"({summary.park_losses} lost, "
                 f"{summary.park_expired} expired, "
                 f"{summary.park_crashed} crashed)")
    if summary.park_denies:
        top = sorted(summary.park_denies.items(),
                     key=lambda kv: (-kv[1], kv[0]))
        lines.append("  denied by gate: " + ", ".join(
            f"{g} {n} ({_pct(n, denies)})" for g, n in top))
    maps = summary.maps_local + summary.maps_remote
    lines.append(f"  maps: {summary.maps_local}/{maps} local "
                 f"({summary.maps_via_reconfig} via reconfig); "
                 f"remote map runtime {summary.remote_map_seconds:.0f}s "
                 f"vs local {summary.local_map_seconds:.0f}s")
    if summary.machine_crashes:
        lines.append(f"  faults: {sum(summary.machine_crashes.values())} "
                     f"crashes over {len(summary.machine_crashes)} machines")
    if summary.serve_ticks:
        res = ", ".join(
            f"{svc} {d['residency'] * 100:.1f}%"
            for svc, d in sorted(summary.service_slo.items()))
        line = (f"  serve: {summary.serve_ticks} replica ticks; "
                f"SLO residency {res}; harvest "
                f"{summary.total_harvest_borrows()} borrows / "
                f"{summary.total_harvest_returns()} returns")
        if summary.harvest_borrows or summary.harvest_returns:
            sigs = sorted(
                list(summary.harvest_borrows.items())
                + list(summary.harvest_returns.items()),
                key=lambda kv: (-kv[1], kv[0]))
            line += " (" + ", ".join(f"{k} {n}" for k, n in sigs) + ")"
        lines.append(line)
    return "\n".join(lines)


def explain_cell(preset: str, shape: str, *, policy: str = "adaptive",
                 baseline: str = "proposed", seed: int = 0,
                 fabric: str = "1GbE", replication: int = 1,
                 faults: str = "none",
                 cache_dir: Union[str, Path] = ".exp-cache",
                 store: bool = True,
                 export_dir: Optional[Union[str, Path]] = None
                 ) -> Tuple[str, TraceSummary, TraceSummary]:
    """Replay one atlas cell with tracing on and attribute its decisions.

    Runs ``policy`` and ``baseline`` on identical inputs (same trace seed,
    placements and jitter draws), folds both buses, stores the ``policy``
    summary next to the cell's warehouse record, and returns the formatted
    attribution text plus both summaries.  ``export_dir`` additionally
    writes the raw JSONL trace and the Chrome/Perfetto JSON there."""
    from repro.experiments.regimes import regime_spec

    spec = regime_spec(preset, shape, (seed,), fabric=fabric,
                       replication=replication, faults=faults)
    cells = {c.scheduler.label: c for c in spec.cells()}
    if policy not in cells:
        # not an atlas column: build the cell from any registered policy
        base = next(iter(cells.values()))
        from repro.core.policies import PolicySpec
        cells[policy] = dataclasses.replace(
            base, scheduler=PolicySpec.parse(policy))
    out_lines = [f"explain {preset}/{shape} fabric={fabric} "
                 f"r={replication} faults={faults} seed={seed}"]
    summaries: Dict[str, Tuple[RunRecord, TraceSummary]] = {}
    for label in (policy, baseline):
        record, bus = simulate_cell_traced(cells[label])
        summary = fold_trace(bus, record.makespan)
        summaries[label] = (record, summary)
        out_lines.append(format_summary(label, record, summary))
        if store:
            store_trace_summary(cache_dir, cells[label], summary)
        if export_dir is not None:
            stem = Path(export_dir) / f"{preset}-{shape}-{label}-s{seed}"
            write_jsonl(bus, stem.with_suffix(".trace.jsonl"))
            write_chrome_trace(bus, stem.with_suffix(".chrome.json"))
            out_lines.append(f"  exported {stem}.trace.jsonl + .chrome.json"
                             " (open the .chrome.json in Perfetto)")
    pol_sum = summaries[policy][1]
    base_sum = summaries[baseline][1]
    # attribution delta: what happened to the parks the baseline admitted?
    if base_sum.park_admits and pol_sum.park_denies:
        gate, n = max(pol_sum.park_denies.items(),
                      key=lambda kv: (kv[1], kv[0]))
        denies = sum(pol_sum.park_denies.values())
        out_lines.append(
            f"attribution: {baseline} admitted {base_sum.park_admits} parks "
            f"on these inputs; {policy} admitted {pol_sum.park_admits} and "
            f"denied {denies} — {_pct(n, denies)} of denials by the "
            f"`{gate}` gate")
    return "\n".join(out_lines), pol_sum, base_sum
