"""AdamW with cosine schedule, pure JAX, sharding-friendly.

Optimizer state mirrors the param tree (same sharding specs apply), with
fp32 moments regardless of param dtype — the standard mixed-precision recipe
(bf16 params / fp32 m,v).  Global-norm clipping runs in fp32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state) -> Tuple[Any, Dict]:
    """Returns (new_params, new_state).  Grads may be bf16; math is fp32."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1t
        vh = v / b2t
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
