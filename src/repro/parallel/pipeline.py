"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For depth-dominated models at pod scale, the layer stack is split into
``n_stages`` contiguous groups placed on a ``pipe`` mesh axis; microbatches
stream through with the classic GPipe schedule (fill + steady + drain =
n_stages + n_micro - 1 ticks).  Activations hop stages with
``jax.lax.ppermute`` — on TPU that is a neighbour ICI transfer.

This is the DP×PP building block referenced in DESIGN.md §3; the dry-run
meshes use DP×TP (better for the assigned shapes), but the fleet scheduler
can launch depth-heavy jobs with a ("data","pipe") mesh using this module.
Numerics are validated against the unpipelined reference in
tests/test_pipeline.py (1-device mesh, multi-stage semantics still exact).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    layer_fn: Callable,      # (params_for_one_layer, x) -> x
    stacked_params,          # pytree with leading [n_layers, ...]
    x: jax.Array,            # [n_micro, mb, ...] microbatched input
    *,
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run ``n_layers`` (= n_stages × layers_per_stage) over microbatches.

    Layers are split contiguously across the ``axis`` ranks.  Returns the
    final activations [n_micro, mb, ...].
    """
    n_stages = mesh.shape[axis]
    n_layers = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    assert n_layers % n_stages == 0, (n_layers, n_stages)
    n_micro = x.shape[0]

    def body(params_stage, x_all):
        # params_stage: [layers_per_stage, ...] (this rank's layers)
        # x_all: [n_micro, mb, ...] (replicated input; stage 0 consumes it)
        stage = jax.lax.axis_index(axis)

        def run_stage(h):
            def one(h, lp):
                return layer_fn(lp, h), None
            h, _ = jax.lax.scan(one, h, params_stage)
            return h

        mb_shape = x_all.shape[1:]
        buf = jnp.zeros(mb_shape, x_all.dtype)       # in-flight microbatch
        outs = jnp.zeros_like(x_all)                 # collected at last stage
        total = n_stages + n_micro - 1

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any left)
            take = jnp.clip(t, 0, n_micro - 1)
            incoming = jax.lax.dynamic_index_in_dim(x_all, take, 0, False)
            buf = jnp.where(jnp.logical_and(stage == 0, t < n_micro),
                            incoming, buf)
            # every stage processes its current microbatch (validity handled
            # by the schedule: garbage results are never collected)
            h = run_stage(buf)
            # last stage collects microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            collect = jnp.logical_and(stage == n_stages - 1,
                                      t >= n_stages - 1)
            outs = jax.lax.cond(
                collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, h, out_idx, 0),
                lambda o: o, outs)
            # shift: stage i's output becomes stage i+1's input
            buf = jax.lax.ppermute(
                h, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(total))
        # only the last stage holds the real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    from jax.experimental.shard_map import shard_map
    spec_params = jax.tree_util.tree_map(lambda _: P(axis), stacked_params)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P()),
                   out_specs=P(),
                   check_rep=False)
    return fn(stacked_params, x)


def reference_apply(layer_fn, stacked_params, x):
    """Unpipelined oracle: scan all layers over each microbatch."""
    def per_micro(h):
        def one(h, lp):
            return layer_fn(lp, h), None
        h, _ = jax.lax.scan(one, h, stacked_params)
        return h
    return jax.vmap(per_micro)(x)
