from repro.parallel.sharding import (
    ShardingPolicy, make_param_specs, make_batch_specs, make_cache_specs,
    make_opt_specs, attach, abstract_with_sharding)
