"""Sharding rules for every model family, with divisibility fallbacks.

The policy maps param-tree leaf *names* to logical roles and assigns mesh
axes per role:

* ``tp``   ("model")          — tensor-parallel dim (heads / ffn / vocab / experts-f)
* ``fsdp`` ("data", optional) — ZeRO-3 style parameter sharding; all-gathered
  per layer inside the scan, gradients reduce-scattered back
* ``dp``   ("data" [+ "pod"]) — batch dim of activations / caches

Every assignment checks divisibility; a dim that does not divide its axis
size falls back to the next candidate (or replication).  This is what lets
one rule-set cover kv_heads ∈ {2..32}, experts ∈ {8, 64}, batch ∈ {1..256}.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


@dataclass(frozen=True)
class ShardingPolicy:
    """Axis assignment for one launch configuration."""
    tp_axis: str = "model"
    fsdp: bool = True
    fsdp_axes: Tuple[str, ...] = ("data",)          # can be ("pod","data")
    dp_axes: Tuple[str, ...] = ("data",)            # ("pod","data") multi-pod

    def fsdp_entry(self):
        if not self.fsdp:
            return None
        return self.fsdp_axes if len(self.fsdp_axes) > 1 else self.fsdp_axes[0]

    def dp_entry(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]


def _axsize(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


def _fit(mesh: Mesh, shape: Tuple[int, ...], wants: Sequence[Any]) -> P:
    """Build a PartitionSpec keeping only divisible assignments, never using
    one mesh axis twice."""
    used = set()
    out = []
    for dim, cand in zip(shape, wants):
        picked = None
        for entry in (cand if isinstance(cand, list) else [cand]):
            if entry is None:
                continue
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            if any(a in used for a in axes):
                continue
            if dim % _axsize(mesh, entry) == 0 and _axsize(mesh, entry) > 1:
                picked = entry
                used.update(axes)
                break
        out.append(picked)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# name -> (expected trailing ndim, wants builder)
def _param_rules(pol: ShardingPolicy):
    tp, fs = pol.tp_axis, pol.fsdp_entry()
    return {
        # [in, out(tp)]
        "wq": (2, [fs, tp]), "wk": (2, [fs, tp]), "wv": (2, [fs, tp]),
        "w_gate": (2, [fs, tp]), "w_up": (2, [fs, tp]),
        "w_z": (2, [fs, tp]), "w_x": (2, [fs, tp]),
        "in_proj": (2, [fs, tp]),
        "lm_head": (2, [fs, tp]),
        # [in(tp), out]
        "wo": (2, [tp, fs]), "w_down": (2, [tp, fs]), "w_out": (2, [tp, fs]),
        # embeddings: vocab on tp (row-parallel gather + AR)
        "tok": (2, [tp, fs]),
        "pos_embed": (2, [None, fs]),
        # small projections
        "w_B": (2, [fs, None]), "w_C": (2, [fs, None]), "w_dt": (2, [fs, None]),
        "w_dkv": (2, [fs, None]),
        "w_uk": (2, [None, tp]), "w_uv": (2, [None, tp]),
        "router": (2, [None, None]),
        # conv kernels [K, channels(tp)]
        "conv_x": (2, [None, tp]), "conv_B": (2, [None, tp]),
        "conv_C": (2, [None, tp]),
        # vectors
        "scale": (1, [None]), "bias": (1, [None]),
        "A_log": (1, [None]), "D": (1, [None]), "dt_bias": (1, [None]),
        # zamba lora [napp, d, r] / [napp, r, f]
        "lora_a": (3, [None, fs, None]), "lora_b": (3, [None, None, tp]),
    }


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return entry.name
    return ""


def make_param_specs(cfg: ModelConfig, params_shapes, mesh: Mesh,
                     pol: ShardingPolicy):
    """params_shapes: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    rules = _param_rules(pol)
    # expert tensors [E, d, f]: detected via 3-D named w_gate/w_up/w_down
    def spec(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name not in rules:
            return P()
        nd, wants = rules[name]
        extra = len(shape) - nd
        if extra < 0:
            return P()
        wants_full = [None] * extra + list(wants)
        return _fit(mesh, shape, wants_full)

    return jax.tree_util.tree_map_with_path(spec, params_shapes)


def make_opt_specs(param_specs):
    """AdamW state mirrors params; step is replicated."""
    return {"m": param_specs, "v": param_specs, "step": P()}


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def make_batch_specs(cfg: ModelConfig, batch_shapes, mesh: Mesh,
                     pol: ShardingPolicy):
    dp = pol.dp_entry()

    def spec(path, leaf):
        shape = leaf.shape
        # batch dim first everywhere; shard it over dp (fall back to nothing)
        wants = [dp] + [None] * (len(shape) - 1)
        return _fit(mesh, shape, wants)

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def make_cache_specs(cfg: ModelConfig, cache_shapes, mesh: Mesh,
                     pol: ShardingPolicy):
    """KV/state caches: [L?, B, heads?, S, ...] — batch over dp, heads over
    tp when divisible, otherwise sequence over tp (flash-decode style); for
    batch=1 long-context cells the sequence dim picks up dp as well."""
    dp, tp = pol.dp_entry(), pol.tp_axis

    def spec(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name == "len" or len(shape) == 0:
            return P()
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            # [L, B, H, S, hd]
            return _fit(mesh, shape, [None, dp, tp, [tp, dp], None])
        if name in ("c_kv", "k_rope"):
            # [L, B, S, r]
            return _fit(mesh, shape, [None, dp, [tp, dp], None])
        if name == "ssm":
            # [L, B, H, P, N]
            return _fit(mesh, shape, [None, dp, tp, None, None])
        if name.startswith("conv_"):
            # [L, B, K-1, channels]
            return _fit(mesh, shape, [None, dp, None, tp])
        wants = [None, dp] + [None] * (len(shape) - 2)
        return _fit(mesh, shape, wants)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def attach(mesh: Mesh, shapes, specs):
    """ShapeDtypeStruct tree + spec tree -> ShapeDtypeStruct tree with
    NamedSharding attached (for .lower())."""
    return jax.tree_util.tree_map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_with_sharding(fn, mesh, pol, cfg, *args):
    shapes = jax.eval_shape(fn, *args)
    specs = make_param_specs(cfg, shapes, mesh, pol)
    return attach(mesh, shapes, specs), specs
