"""Gradient compression for cross-pod reduction (int8 + error feedback).

At 2 pods the "pod" axis all-reduce moves full fp32/bf16 gradients over DCI;
int8 block-quantization with error feedback cuts wire bytes 4x (vs fp32)
while keeping convergence (the residual carries quantization error to the
next step).  ``compressed_psum`` plugs into shard_map train loops on the
"pod" axis; quantization + error feedback are exercised numerically in
tests/test_substrates.py.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _blockify(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...], int]:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), x.shape, pad


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8.  Returns (q [nb, BLOCK] int8, scale [nb])."""
    blocks, _, _ = _blockify(x)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, pad: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad] if pad else flat
    return flat.reshape(shape)


def compressed_psum(x: jax.Array, axis_name: str,
                    residual: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compressed all-reduce over ``axis_name`` (inside
    shard_map/pmap).  Returns (summed value, new residual)."""
    x_c = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(x_c)
    _, shape, pad = _blockify(x_c)
    deq = dequantize_int8(q, scale, shape, pad)
    new_residual = x_c - deq
    summed = jax.lax.psum(deq, axis_name)
    return summed.astype(x.dtype), new_residual


def compress_tree(grads):
    """Tree version of quantize: returns (quantized leaves, scales, meta)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    qs, scales, metas = [], [], []
    for leaf in leaves:
        blocks, shape, pad = _blockify(leaf)
        q, s = quantize_int8(leaf)
        qs.append(q)
        scales.append(s)
        metas.append((shape, pad))
    return qs, scales, metas, treedef


def decompress_tree(qs, scales, metas, treedef):
    leaves = [dequantize_int8(q, s, shape, pad)
              for q, s, (shape, pad) in zip(qs, scales, metas)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def wire_bytes_ratio() -> float:
    """int8 payload + fp32 scale per block vs fp32 baseline."""
    return (BLOCK * 1 + 4) / (BLOCK * 4)
