"""Activation sharding constraints.

GSPMD left alone will happily model-shard the hidden dim of activations and
replicate the batch (observed in the first dry-run probe: local hidden
[32, 4096, 128] instead of [2, 4096, 2048]).  The launcher declares the
intended activation layout here; model code calls ``shard_acts`` at layer
boundaries.  No-op when unset (unit tests, single device).

Layout convention for [B, S, D] activations:
  dim 0 (batch)     -> dp entry ("data" or ("pod","data"))
  dim 1 (sequence)  -> sp entry (sequence parallelism, optional hillclimb)
  dim 2 (hidden)    -> None (materialized fully per shard between matmuls)
Logits [B, S, V] additionally shard V over tp (set by ``shard_logits``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"dp": None, "dp_size": 1, "sp": None, "sp_size": 1,
          "tp": None, "tp_size": 1, "mesh": None, "fsdp": None}


def set_activation_sharding(dp=None, dp_size=1, sp=None, sp_size=1,
                            tp=None, tp_size=1, mesh=None, fsdp=None) -> None:
    _STATE.update(dp=dp, dp_size=dp_size, sp=sp, sp_size=sp_size,
                  tp=tp, tp_size=tp_size, mesh=mesh, fsdp=fsdp)


def clear() -> None:
    set_activation_sharding()


def _entry(name, dim_size):
    e, size = _STATE[name], _STATE[name + "_size"]
    if e is None or size <= 1 or dim_size % size != 0:
        return None
    return e


def shard_embed_out(x: jax.Array) -> jax.Array:
    """Stage the vocab-sharded-gather output towards the activation layout.

    The gather over a tp-sharded table comes out d-sharded; jumping straight
    to batch-sharded triggers GSPMD's "involuntary full rematerialization"
    (replicate-then-slice).  Constraining to (dp, None, tp) first makes the
    transition a local slice, and the following shard_acts an ordinary
    all-gather over tp."""
    if _STATE["dp"] is None or x.ndim != 3:
        return x
    spec = [_entry("dp", x.shape[0]), None, _entry("tp", x.shape[2])]
    if any(s is not None for s in spec):
        x = jax.lax.with_sharding_constraint(x, P(*spec))
    return shard_acts(x)


def shard_acts(x: jax.Array) -> jax.Array:
    """Constrain [B, ...] activations: batch over dp, seq over sp."""
    if _STATE["dp"] is None or x.ndim < 2:
        return x
    spec = [_entry("dp", x.shape[0])]
    if x.ndim >= 3:
        spec.append(_entry("sp", x.shape[1]))
        spec.extend([None] * (x.ndim - 2))
    else:
        spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_attn_qkv(q: jax.Array, k: jax.Array, v: jax.Array):
    """Attention-interior sharding ([B, H, S, D] each).

    GSPMD left alone splits *within* heads when H doesn't divide tp (e.g.
    llama3.2's 24 q-heads on a 16-way model axis -> g=2 partial-softmax
    all-reduces per kv block, ~360 GB/step/chip at 4k).  Rule:
      * both Hq and Hkv divide tp -> shard heads over tp (classic TP);
      * otherwise shard the *sequence* over tp (context parallelism inside
        the layer; boundary reshards are cheap all-to-alls).
    """
    if _STATE["dp"] is None or q.ndim != 4:
        return q, k, v
    tp, tps = _STATE["tp"], _STATE["tp_size"]
    dp = _entry("dp", q.shape[0])
    if tp is None or tps <= 1:
        return q, k, v
    heads_ok = (q.shape[1] % tps == 0) and (k.shape[1] % tps == 0)
    seq_ok = (q.shape[2] % tps == 0) and (k.shape[2] % tps == 0)
    if heads_ok:
        spec_q = P(dp, tp, None, None)
        spec_kv = P(dp, tp, None, None)
    elif seq_ok:
        spec_q = P(dp, None, tp, None)
        spec_kv = P(dp, None, tp, None)
    else:
        return q, k, v
    q = jax.lax.with_sharding_constraint(q, spec_q)
    k = jax.lax.with_sharding_constraint(k, spec_kv)
    v = jax.lax.with_sharding_constraint(v, spec_kv)
    return q, k, v


def bh_flat_entry(b: int, h: int):
    """Joint (batch*heads) sharding over dp×tp for the flattened-attention
    layout; None when the product doesn't divide."""
    if _STATE["dp"] is None:
        return None
    dp, tp = _STATE["dp"], _STATE["tp"]
    total = _STATE["dp_size"] * _STATE["tp_size"]
    if tp is None or total <= 1 or (b * h) % total != 0:
        return None
    axes = (dp if isinstance(dp, tuple) else (dp,)) + (tp,)
    return axes


def shard_bh(x: jax.Array) -> jax.Array:
    """x: [B*H, 1, S, D] — constrain dim0 over dp×tp."""
    entry = bh_flat_entry(x.shape[0], 1)
    if entry is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(entry, *([None] * (x.ndim - 1))))


def shard_logits(x: jax.Array) -> jax.Array:
    """[B, S, V]: batch over dp, vocab over tp."""
    if _STATE["dp"] is None or x.ndim != 3:
        return x
    spec = [_entry("dp", x.shape[0]), _entry("sp", x.shape[1]),
            _entry("tp", x.shape[2])]
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
