from repro.mapreduce.engine import MRJob, run_mapreduce, WORKLOAD_FNS
