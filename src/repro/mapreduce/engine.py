"""A real (executable) MapReduce engine in JAX — the data plane behind the
simulated control plane.

The paper's five workloads are implemented as jitted map/reduce functions
over token blocks.  The engine mirrors Hadoop's phases:

  map:     vmap(map_fn) over input blocks -> per-block partial results,
           hash-partitioned into ``n_reducers`` buckets
  shuffle: transpose [blocks, reducers, ...] -> [reducers, blocks, ...]
           (on a sharded mesh this lowers to an all-to-all; the dry-run of
           the framework exercises that path)
  reduce:  vmap(reduce_fn) over reducer buckets

Each workload returns a verifiable aggregate so tests can assert engine
correctness against a pure-numpy oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

VOCAB = 4096


@dataclass(frozen=True)
class MRJob:
    workload: str
    n_blocks: int
    block_tokens: int
    n_reducers: int
    seed: int = 0


def make_blocks(job: MRJob) -> np.ndarray:
    rng = np.random.RandomState(job.seed)
    return rng.randint(1, VOCAB, size=(job.n_blocks, job.block_tokens),
                       dtype=np.int32)


# ---------------------------------------------------------------------------
# map fns: block tokens [T] -> [n_reducers, payload] partials
# ---------------------------------------------------------------------------


def _bucket(tokens: jax.Array, n_red: int) -> jax.Array:
    return tokens % n_red


def map_wordcount(tokens: jax.Array, n_red: int) -> jax.Array:
    """Per-reducer histogram slice: [n_red, VOCAB//n_red]."""
    counts = jnp.bincount(tokens, length=VOCAB)
    return counts.reshape(n_red, VOCAB // n_red)


def map_grep(tokens: jax.Array, n_red: int, needle: int = 7) -> jax.Array:
    hits = (tokens == needle).sum()
    out = jnp.zeros((n_red, 1), jnp.int32)
    return out.at[needle % n_red, 0].set(hits.astype(jnp.int32))


def map_sort(tokens: jax.Array, n_red: int) -> jax.Array:
    """Range-partition counts: sorted output = prefix sums per bucket."""
    edges = jnp.arange(1, n_red + 1) * (VOCAB // n_red)
    bucket = jnp.searchsorted(edges, tokens, side="right")
    onehot = jax.nn.one_hot(bucket, n_red, dtype=jnp.int32)
    # per-bucket local sorted histogram
    counts = jnp.bincount(tokens, length=VOCAB).reshape(n_red, VOCAB // n_red)
    del onehot
    return counts


def map_permutation(tokens: jax.Array, n_red: int) -> jax.Array:
    """Reduce-input-heavy: emits an [n_red, VOCAB//n_red] dense expansion of
    pairwise shifted tokens (large intermediate, like the paper's
    permutation generator)."""
    shifted = jnp.stack([jnp.roll(tokens, s) for s in range(4)], 0)
    pairs = (tokens[None, :] * 31 + shifted) % VOCAB
    counts = jnp.bincount(pairs.reshape(-1), length=VOCAB)
    return counts.reshape(n_red, VOCAB // n_red)


def map_inverted_index(tokens: jax.Array, n_red: int) -> jax.Array:
    present = (jnp.bincount(tokens, length=VOCAB) > 0).astype(jnp.int32)
    return present.reshape(n_red, VOCAB // n_red)


# reduce fns: [n_blocks, payload] -> [payload]
def reduce_sum(parts: jax.Array) -> jax.Array:
    return parts.sum(axis=0)


WORKLOAD_FNS: Dict[str, Tuple[Callable, Callable]] = {
    "wordcount": (map_wordcount, reduce_sum),
    "grep": (map_grep, reduce_sum),
    "sort": (map_sort, reduce_sum),             # counting-sort histogram
    "permutation": (map_permutation, reduce_sum),
    "inverted_index": (map_inverted_index, reduce_sum),  # posting counts
}


@partial(jax.jit, static_argnames=("workload", "n_red"))
def _run(blocks: jax.Array, workload: str, n_red: int):
    map_fn, red_fn = WORKLOAD_FNS[workload]
    partials = jax.vmap(lambda b: map_fn(b, n_red))(blocks)   # [B, R, P]
    shuffled = jnp.swapaxes(partials, 0, 1)                   # [R, B, P] (all-to-all)
    return jax.vmap(red_fn)(shuffled)                         # [R, P]


def run_mapreduce(job: MRJob, blocks: np.ndarray | None = None) -> np.ndarray:
    if blocks is None:
        blocks = make_blocks(job)
    return np.asarray(_run(jnp.asarray(blocks), job.workload, job.n_reducers))
