"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

27L, d_model=2048, 16H MLA (kv_lora=512, rope 64, nope 128, v 128),
64 routed experts top-6 + 2 shared (d_ff_expert=1408), first layer dense
(d_ff=10944), vocab=102400.  (The assignment line's "160 routed" is
DeepSeek-V2-full; Lite is 64 routed — see DESIGN.md.)
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400,
    rope_theta=10000.0,
    n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
    n_dense_layers=1, d_ff_dense=10944,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    capacity_factor=1.25,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512,
        n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=64,
        n_dense_layers=1, d_ff_dense=256, moe_dispatch_groups=8,
        kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
        param_dtype="float32", compute_dtype="float32", remat="none")
