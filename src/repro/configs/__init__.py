"""Architecture config registry.

``get_config(arch_id)`` returns the full assigned configuration;
``get_smoke_config(arch_id)`` returns the reduced same-family config used by
CPU smoke tests (full configs are only ever lowered abstractly).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

ALL_ARCHS: List[str] = [
    "mamba2-1.3b",
    "zamba2-1.2b",
    "nemotron-4-15b",
    "llama3.2-3b",
    "tinyllama-1.1b",
    "stablelm-3b",
    "mixtral-8x22b",
    "deepseek-v2-lite-16b",
    "whisper-large-v3",
    "qwen2-vl-2b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "p") for a in ALL_ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; have {ALL_ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke()
