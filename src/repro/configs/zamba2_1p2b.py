"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

38 mamba2 layers, d_model=2048; one SHARED attn(32H, kv=32)+MLP(d_ff=8192)
block applied every 6 layers (7 applications) with per-application LoRA;
vocab=32000, ssm_state=64.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    ssm_conv_width=4, ssm_chunk=256,
    shared_attn_period=6,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, ssm_state=16, ssm_headdim=16, ssm_chunk=32,
        shared_attn_period=2,
        param_dtype="float32", compute_dtype="float32", remat="none")
