"""llama3.2-3b — small llama3 [hf:meta-llama/Llama-3.2-3B].

28L, d_model=3072, 24H (kv=8), d_ff=8192, vocab=128256, rope theta 500k,
tied embeddings.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    tie_embeddings=True,
    attn_row_parallel=True,
    remat="comm",   # §Perf: save collective outputs, skip recompute-comm
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat="none")
