"""nemotron-4-15b — dense GQA, squared-ReLU FFN, LayerNorm [arXiv:2402.16819].

32L, d_model=6144, 48H (kv=8), d_ff=24576, vocab=256000.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab_size=256000,
    act="relu2", norm="ln",
    rope_theta=10000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat="none")
