"""mamba2-1.3b — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L, d_model=2048, d_ff=0 (no MLP — mamba2 blocks only), vocab=50280,
ssm_state=128; expand=2 -> d_inner=4096, headdim=64 -> 64 SSM heads.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    n_heads=1, n_kv_heads=1, d_ff=0,          # attention-free
    vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    ssm_conv_width=4, ssm_chunk=256,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, vocab_size=512,
        ssm_state=16, ssm_headdim=16, ssm_chunk=32,
        param_dtype="float32", compute_dtype="float32", remat="none")
