"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385].

22L, d_model=2048, 32H (kv=4), d_ff=5632, vocab=32000.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=5632,
    vocab_size=32000,
    rope_theta=10000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat="none")
