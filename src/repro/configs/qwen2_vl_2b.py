"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191].

28L, d_model=1536, 12H (kv=2), d_ff=8960, vocab=151936, head_dim=128,
M-RoPE sections (16, 24, 24).  The vision frontend is a stub: precomputed
patch embeddings + 3-D position ids come in through the batch.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, head_dim=32, mrope_sections=(4, 6, 6),
        param_dtype="float32", compute_dtype="float32", remat="none")
