"""stablelm-3b — [hf:stabilityai/stablelm-2 family].

32L, d_model=2560, 32H (kv=32 = MHA), d_ff=6912, vocab=50304, LayerNorm,
partial rotary (25% of head_dim).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab_size=50304,
    norm="ln",
    rope_theta=10000.0, rope_fraction=0.25,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat="none")
