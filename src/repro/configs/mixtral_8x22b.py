"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].

56L, d_model=6144, 48H (kv=8), expert d_ff=16384, vocab=32768, SWA window
4096.  ~141B total / ~39B active parameters.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768,
    rope_theta=1000000.0,
    window=4096,
    n_experts=8, top_k=2, d_ff_expert=16384,
    capacity_factor=1.25,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, window=64,
        n_experts=4, top_k=2, d_ff_expert=128, moe_dispatch_groups=2,
        param_dtype="float32", compute_dtype="float32", remat="none")
