"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model=1280, 20H (MHA), d_ff=5120,
vocab=51866, GELU, LayerNorm, absolute positions (no rope).  The conv/mel
frontend is a stub: inputs are precomputed frame embeddings.  Assigned
``seq_len`` = encoder frames; decoder length = seq_len // 4 (DESIGN.md).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-large-v3",
    family="encdec",
    num_layers=32,
    enc_layers=32, dec_layers=32,
    d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866,
    act="gelu", norm="ln",
    rope_fraction=0.0,            # absolute positions
    max_target_positions=16384,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, enc_layers=2, dec_layers=2,
        d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, max_target_positions=256,
        param_dtype="float32", compute_dtype="float32", remat="none")
