"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): one grid step
processes one (batch, head-block, chunk) tile entirely in VMEM —

  1. intra-chunk dual form:   Y_diag = (C B^T ∘ L) · (dt x)      (MXU)
  2. inter-chunk state carry: h held in a VMEM scratch across the chunk
     grid dimension (sequential on TPU), updated as
       Y_off = C · h · exp(cumsum dA);  h = h · exp(total dA) + states

The chunk length is the VMEM tile: Q=128 rows align the MXU; state [P, N]
per head stays resident.  Grid = (B, H, n_chunks) with chunks minor so the
scratch carry is legal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int, seq_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # [Q]
    a = a_ref[0]                                     # scalar A_h
    b = b_ref[0, :, 0].astype(jnp.float32)          # [Q, N]
    c = c_ref[0, :, 0].astype(jnp.float32)          # [Q, N]

    # mask padded tail rows (dt=0 -> no state contribution)
    pos = ci * chunk + jax.lax.iota(jnp.int32, chunk)
    dt = jnp.where(pos < seq_len, dt, 0.0)

    dA = dt * a                                      # [Q]
    cum = jnp.cumsum(dA)                             # [Q]
    # L[i, j] = exp(cum_i - cum_j) for i >= j else 0
    li = cum[:, None] - cum[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tril, jnp.exp(li), 0.0)

    xdt = x * dt[:, None]                            # [Q, P]
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    y_diag = jax.lax.dot_general(cb * L, xdt, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q, P]

    h = h_ref[...]                                    # [P, N]
    y_off = jax.lax.dot_general(c, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [Q, P]
    y_off = y_off * jnp.exp(cum)[:, None]
    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # chunk state: sum_j exp(cum_last - cum_j) * dt_j * B_j (x) x_j
    decay_to_end = jnp.exp(cum[-1] - cum)             # [Q]
    bw = b * decay_to_end[:, None]                    # [Q, N]
    states = jax.lax.dot_general(xdt, bw, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [P, N]
    h_ref[...] = h * jnp.exp(cum[-1]) + states


def ssd_scan(
    x: jax.Array,      # [B, S, H, P]
    dt: jax.Array,     # [B, S, H]
    A: jax.Array,      # [H]
    B_: jax.Array,     # [B, S, G, N]
    C: jax.Array,      # [B, S, G, N]
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    """Returns y [B, S, H, P].  Groups are pre-broadcast to heads."""
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    if G != H:
        B_ = jnp.repeat(B_, H // G, axis=2)
        C = jnp.repeat(C, H // G, axis=2)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    grid = (Bsz, H, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, seq_len=S)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B_, C)
    return y[:, :S]
