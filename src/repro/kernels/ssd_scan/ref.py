"""Pure-jnp oracle for the Mamba2 SSD chunked-scan kernel.

Sequential recurrence — O(S) scan, numerically exact ground truth:
    h_t = h_{t-1} * exp(dt_t * A) + dt_t * B_t (x) x_t
    y_t = C_t . h_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,      # [B, S, H, P]
    dt: jax.Array,     # [B, S, H]      (> 0, post-softplus)
    A: jax.Array,      # [H]            (negative)
    B_: jax.Array,     # [B, S, G, N]
    C: jax.Array,      # [B, S, G, N]
    D: jax.Array | None = None,        # [H]
):
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(B_.astype(f32), rep, axis=2)     # [B,S,H,N]
    Ch = jnp.repeat(C.astype(f32), rep, axis=2)

    def step(h, inp):
        xt, dtt, bt, ct = inp                         # [B,H,P],[B,H],[B,H,N]x2
        decay = jnp.exp(dtt * A.astype(f32)[None])    # [B,H]
        h = h * decay[..., None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", xt, bt, dtt)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), f32)
    xs = (x.astype(f32).transpose(1, 0, 2, 3), dt.astype(f32).transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)                      # [B,S,H,P]
    if D is not None:
        y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), hT
