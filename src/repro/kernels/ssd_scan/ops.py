"""Jit'd public wrapper for the SSD-scan kernel (adds the D skip-term)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B_, C, D=None, *, chunk: int = 128, interpret: bool = False):
    y = ssd_scan(x, dt, A, B_, C, chunk=chunk, interpret=interpret)
    if D is not None:
        y = y + (x.astype(jnp.float32)
                 * D.astype(jnp.float32)[None, None, :, None]).astype(y.dtype)
    return y
