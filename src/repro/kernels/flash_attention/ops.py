"""Jit'd public wrapper for the flash-attention kernel (GQA-aware)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "kv_block",
                                   "interpret"))
def flash_attention(
    q: jax.Array,            # [B, Hq, Sq, D]
    k: jax.Array,            # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """GQA flash attention: broadcasts KV heads to query heads, then runs
    the Pallas kernel.  On CPU use interpret=True (validation mode)."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, interpret=interpret)
