"""Flash attention forward as a Pallas TPU kernel.

TPU adaptation (vs. the CUDA original): the online-softmax tiling is mapped
to MXU-friendly (q_block × kv_block) tiles resident in VMEM; the kv loop is
the innermost grid dimension so K/V tiles stream HBM->VMEM while the
accumulator stays pinned in a VMEM scratch across iterations (grid order
(b, h, q, kv) with kv minor = sequential on TPU, enabling carry).

Block shapes default to (128, 128): MXU-aligned (multiples of 128 on both
matmul dims) and small enough that q/k/v/acc tiles fit VMEM for head_dim
up to 256.

Causal skipping is handled by masking inside the tile; whole-tile skipping
uses `when` on the tile index so fully-masked tiles do no MXU work.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_Q_BLOCK = 128
DEFAULT_KV_BLOCK = 128
_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               causal: bool, window: Optional[int], q_block: int,
               kv_block: int, n_kv: int, sq: int, skv: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * q_block
    k_start = ki * kv_block
    # whole-tile skip: tile contributes only if some (q, k) pair can be
    # unmasked — fully-masked tiles do no MXU work
    run = jnp.bool_(True)
    if causal:
        run &= (q_start + q_block - 1) >= k_start
    if window is not None:
        run &= q_start < k_start + kv_block + window - 1

    @pl.when(run)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)           # [qb, D]
        k = k_ref[0, 0].astype(jnp.float32)           # [kb, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [qb, kb]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = (qpos < sq) & (kpos < skv)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,            # [B, H, Sq, D]  (GQA pre-broadcast to H = Hq)
    k: jax.Array,            # [B, H, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_block: int = DEFAULT_Q_BLOCK,
    kv_block: int = DEFAULT_KV_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    qb, kb = min(q_block, max(Sq, 8)), min(kv_block, max(Skv, 8))
    pad_q = (-Sq) % qb
    pad_k = (-Skv) % kb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = q.shape[2] // qb
    n_kv = k.shape[2] // kb
    grid = (B, H, n_q, n_kv)

    kernel = functools.partial(
        _fa_kernel, causal=causal, window=window, q_block=qb, kv_block=kb,
        n_kv=n_kv, sq=Sq, skv=Skv, scale=1.0 / math.sqrt(D))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kb, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, kb, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, n_q * qb, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, D), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
