"""Pure-jnp oracle for the flash-attention Pallas kernel.

Naive full-materialization attention — the ground truth every kernel shape
sweep asserts against (tests/test_kernels.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,            # [B, Hq, Sq, D]
    k: jax.Array,            # [B, Hkv, Skv, D]
    v: jax.Array,            # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    logits *= 1.0 / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)
