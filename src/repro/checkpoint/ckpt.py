"""Sharded checkpoint save/restore with resharding-on-restore.

Layout: one .npz per (checkpoint, shard-group) + a JSON manifest.  Restore
accepts a *different* mesh/sharding than save — the elastic runtime
(repro.elastic) uses this to grow/shrink a job's chip allocation at step
boundaries (the TPU analogue of the paper's vCPU hot-plug, DESIGN.md §2).

Fault tolerance: writes go to a temp dir, fsync'd, then atomically renamed;
`latest_step` ignores incomplete checkpoints, so a crash mid-save restores
the previous complete one.  `AsyncCheckpointer` overlaps serialization with
the next training step (double-buffered thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path)
        arr = flat[key]
        leaves.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: Any) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {"step": step,
                "keys": {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()}}
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                 # atomic publish
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, step: int, template: Any,
                       shardings=None) -> Any:
    """Restore into ``template``'s structure; if ``shardings`` (a pytree of
    NamedSharding) is given, device_put each leaf with it — this is the
    resharding path used on elastic mesh resize."""
    path = Path(ckpt_dir) / f"step_{step}" / "arrays.npz"
    flat = dict(np.load(path))
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class AsyncCheckpointer:
    """Double-buffered async save: serialize + write on a worker thread."""

    def __init__(self, ckpt_dir: str | Path):
        self.ckpt_dir = Path(ckpt_dir)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # device->host now

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree)
            except BaseException as e:   # noqa: BLE001 — surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
