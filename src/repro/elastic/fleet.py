"""Fleet scheduler: the paper's algorithms running a multi-job TPU pod.

Mapping (DESIGN.md §2):
  map task        -> one microbatch train step
  map slot        -> one chip in a job's data-parallel mesh
  t_m             -> measured per-step time (per chip-normalized)
  Eq. 10          -> minimum chips for the job to hit its deadline
  Algorithm 1     -> chip Assign/Release queues per *host* (4 chips/host);
                     a job wanting a chip on the host that stores its data
                     shards parks a grow-request; jobs past their demand
                     release chips; matches move a chip between jobs
  vCPU hot-plug   -> checkpoint -> re-jit on resized mesh -> resharded
                     restore (jitted SPMD binds devices at compile time, so
                     "hot-plug" happens at step boundaries)
  heartbeat       -> per-step completion callbacks

Fault tolerance: a failed host's chips are dropped from the pool; affected
jobs resize-restore from their last checkpoint.  Straggling hosts are
drained the same way (straggler mitigation = elastic shrink away from the
slow host).

This module is hardware-agnostic: it runs the real thing on however many
jax devices exist (tests/examples use CPU fake devices).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.estimator import min_slots
from repro.checkpoint import restore_checkpoint, save_checkpoint, latest_step


@dataclass
class FleetJob:
    job_id: str
    deadline: float                     # seconds from submission
    total_steps: int
    make_step: Callable                 # (mesh) -> (step_fn, state, shardings)
    preferred_hosts: Tuple[int, ...] = ()   # where its data shards live
    min_chips: int = 1
    # runtime state
    chips: List[int] = field(default_factory=list)     # device ids
    step: int = 0
    submitted_at: float = 0.0
    finished_at: Optional[float] = None
    step_times: List[float] = field(default_factory=list)
    resizes: int = 0
    state: object = None
    step_fn: Optional[Callable] = None

    @property
    def done(self) -> bool:
        return self.step >= self.total_steps

    def t_step(self) -> Optional[float]:
        if not self.step_times:
            return None
        recent = self.step_times[-8:]
        return sum(recent) / len(recent)

    def demanded_chips(self, now: float, total_chips: int) -> int:
        """Eq. 10 with u_m = remaining steps, work ∝ chips·time."""
        t = self.t_step()
        if t is None:
            return max(self.min_chips, len(self.chips) or 1)
        remaining = self.total_steps - self.step
        if remaining <= 0:
            return 0
        time_left = max(self.deadline - (now - self.submitted_at), 1e-3)
        # one "map task" = one step at current width; normalize to chip-steps
        chip_seconds = remaining * t * max(len(self.chips), 1)
        d = min_slots(u_m=remaining, v_r=1,
                      t_m=chip_seconds / remaining, t_r=0.0, t_s=0.0,
                      deadline=time_left, max_map_slots=total_chips)
        want = max(self.min_chips, min(d.n_m, total_chips))
        # snap UP to a power of two: allocations are mesh slices
        snapped = 1
        while snapped < want:
            snapped *= 2
        return min(snapped, total_chips)


class ChipPool:
    """Host-grouped chip inventory with AQ/RQ per host (Algorithm 1)."""

    def __init__(self, devices: Sequence, chips_per_host: int = 4):
        self.devices = list(devices)
        self.chips_per_host = chips_per_host
        self.num_hosts = (len(self.devices) + chips_per_host - 1) // chips_per_host
        self.owner: Dict[int, Optional[str]] = {i: None for i in range(len(self.devices))}
        self.dead_hosts: set = set()
        self.aq: List[Deque[str]] = [deque() for _ in range(self.num_hosts)]
        self.rq: List[Deque[int]] = [deque() for _ in range(self.num_hosts)]
        self.reconfigurations = 0

    def host_of(self, chip: int) -> int:
        return chip // self.chips_per_host

    def free_chips(self, host: Optional[int] = None) -> List[int]:
        return [c for c, o in self.owner.items()
                if o is None and self.host_of(c) not in self.dead_hosts
                and (host is None or self.host_of(c) == host)]

    def allocate(self, job_id: str, n: int,
                 preferred_hosts: Sequence[int] = ()) -> List[int]:
        got = []
        for h in preferred_hosts:
            for c in self.free_chips(h):
                if len(got) >= n:
                    break
                self.owner[c] = job_id
                got.append(c)
        for c in self.free_chips():
            if len(got) >= n:
                break
            self.owner[c] = job_id
            got.append(c)
        return got

    def release(self, chips: Sequence[int]) -> None:
        for c in chips:
            self.owner[c] = None
            self.rq[self.host_of(c)].append(c)

    def park_grow(self, job_id: str, host: int) -> None:
        self.aq[host].append(job_id)

    def match(self) -> List[Tuple[str, int]]:
        """AQ/RQ pairing per host -> (job, chip) grants."""
        grants = []
        for h in range(self.num_hosts):
            while self.aq[h] and self.rq[h]:
                job = self.aq[h].popleft()
                chip = self.rq[h].popleft()
                if self.owner.get(chip) is not None:
                    continue            # stale offer
                self.owner[chip] = job
                grants.append((job, chip))
                self.reconfigurations += 1
        return grants

    def fail_host(self, host: int) -> List[str]:
        """Kill a host; returns affected job ids."""
        self.dead_hosts.add(host)
        affected = set()
        for c in range(host * self.chips_per_host,
                       min((host + 1) * self.chips_per_host, len(self.devices))):
            if self.owner[c] is not None:
                affected.add(self.owner[c])
            self.owner[c] = None
        return sorted(affected)


class EstimatorBridge:
    """Keeps the paper symbols visible for tests: A=u_m·t_m etc."""

    @staticmethod
    def demand(remaining_steps: int, t_step: float, width: int,
               time_left: float, total_chips: int) -> int:
        chip_seconds = remaining_steps * t_step * max(width, 1)
        d = min_slots(u_m=remaining_steps, v_r=1,
                      t_m=chip_seconds / remaining_steps, t_r=0.0, t_s=0.0,
                      deadline=max(time_left, 1e-3),
                      max_map_slots=total_chips)
        return d.n_m


class FleetScheduler:
    """EDF + Eq.-10 demands + AQ/RQ chip movement, at step granularity.

    ``run`` drives all jobs cooperatively (round-robin one step per tick) —
    a stand-in for per-job processes on a real fleet.  Resizes happen at
    step boundaries via checkpoint -> re-jit -> resharded restore.
    """

    def __init__(self, pool: ChipPool, ckpt_root: str,
                 clock: Callable[[], float] = time.monotonic):
        self.pool = pool
        self.ckpt_root = ckpt_root
        self.clock = clock
        self.jobs: Dict[str, FleetJob] = {}
        self.events: List[str] = []

    # -- lifecycle -----------------------------------------------------------
    def submit(self, job: FleetJob) -> None:
        job.submitted_at = self.clock()
        self.jobs[job.job_id] = job
        want = max(job.min_chips, 1)
        chips = self.pool.allocate(job.job_id, want, job.preferred_hosts)
        job.chips = chips
        self._build(job)
        self.events.append(f"submit {job.job_id} chips={chips}")

    def _mesh(self, job: FleetJob) -> Mesh:
        devs = np.array([self.pool.devices[c] for c in job.chips])
        return Mesh(devs.reshape(-1), ("data",))

    def _build(self, job: FleetJob, restore: bool = True) -> None:
        mesh = self._mesh(job)
        step_fn, state, shardings = job.make_step(mesh)
        ck = f"{self.ckpt_root}/{job.job_id}"
        last = latest_step(ck) if restore else None
        if last is not None:
            state = restore_checkpoint(ck, last, state, shardings)
            job.step = last
        job.step_fn, job.state = step_fn, state

    # -- elastic resize ---------------------------------------------------------
    def _resize(self, job: FleetJob, new_chips: List[int]) -> None:
        ck = f"{self.ckpt_root}/{job.job_id}"
        save_checkpoint(ck, job.step, jax.tree_util.tree_map(np.asarray, job.state))
        self.pool.release([c for c in job.chips if c not in new_chips])
        job.chips = new_chips
        job.resizes += 1
        self._build(job)
        self.events.append(f"resize {job.job_id} -> {len(new_chips)} chips")

    # -- scheduling tick -----------------------------------------------------
    def rebalance(self) -> None:
        now = self.clock()
        total = len([c for c in self.pool.owner
                     if self.pool.host_of(c) not in self.pool.dead_hosts])
        active = [j for j in self.jobs.values() if not j.done]
        # EDF order for grants
        active.sort(key=lambda j: j.submitted_at + j.deadline)
        for job in active:
            demand = job.demanded_chips(now, total)
            have = len(job.chips)
            if demand > have:
                # grow: prefer hosts holding the job's data (locality);
                # park on AQ, and claim any free chips right away
                free = self.pool.allocate(job.job_id, demand - have,
                                          job.preferred_hosts)
                if free:
                    self._resize(job, job.chips + free)
                for h in (job.preferred_hosts or range(self.pool.num_hosts)):
                    if len(job.chips) >= demand:
                        break
                    self.pool.park_grow(job.job_id, h)
            elif demand < have and have > job.min_chips:
                # release surplus (Algorithm 1's RQ registration)
                surplus = min(have - max(demand, job.min_chips), have - 1)
                if surplus > 0:
                    keep = job.chips[:have - surplus]
                    self._resize(job, keep)
        # AQ/RQ matching -> grants
        grants: Dict[str, List[int]] = {}
        for job_id, chip in self.pool.match():
            grants.setdefault(job_id, []).append(chip)
        for job_id, chips in grants.items():
            job = self.jobs[job_id]
            if job.done:
                self.pool.release(chips)
                continue
            self._resize(job, job.chips + chips)

    def handle_host_failure(self, host: int) -> None:
        affected = self.pool.fail_host(host)
        self.events.append(f"host {host} FAILED; affected={affected}")
        for job_id in affected:
            job = self.jobs[job_id]
            survivors = [c for c in job.chips
                         if self.pool.host_of(c) not in self.pool.dead_hosts]
            for c in survivors:
                self.pool.owner[c] = job.job_id
            if not survivors:
                survivors = self.pool.allocate(job.job_id, 1,
                                               job.preferred_hosts)
            job.chips = survivors
            self._build(job)        # restore from last checkpoint
            self.events.append(
                f"recovered {job_id} on {len(survivors)} chips @step {job.step}")

    # -- driver -----------------------------------------------------------------
    def run(self, *, rebalance_every: int = 4, ckpt_every: int = 8,
            max_ticks: int = 10_000) -> None:
        tick = 0
        while any(not j.done for j in self.jobs.values()) and tick < max_ticks:
            tick += 1
            for job in list(self.jobs.values()):
                if job.done or job.step_fn is None:
                    continue
                t0 = self.clock()
                job.state = job.step_fn(job.state)
                jax.block_until_ready(jax.tree_util.tree_leaves(job.state)[0])
                job.step_times.append(self.clock() - t0)
                job.step += 1
                if job.step % ckpt_every == 0:
                    save_checkpoint(f"{self.ckpt_root}/{job.job_id}", job.step,
                                    jax.tree_util.tree_map(np.asarray, job.state))
                if job.done:
                    job.finished_at = self.clock()
                    self.pool.release(job.chips)
                    job.chips = []
                    self.events.append(f"done {job.job_id} step={job.step}")
            if tick % rebalance_every == 0:
                self.rebalance()
