from repro.elastic.fleet import (FleetJob, FleetScheduler, ChipPool,
                                 EstimatorBridge)
