"""Resource estimation model — paper §2.2, Eqs. (1)–(10).

Given a job with ``u_m`` map tasks of mean duration ``t_m``, ``v_r`` reduce
tasks of duration ``t_r``, per mapper→reducer copy time ``t_s`` and deadline
``D``, the completion-time model (Eq. 7) is

    u_m·t_m / n_m  +  v_r·t_r / n_r  +  u_m·v_r·t_s  <=  D

and the *minimum total* slot allocation meeting it is the Lagrange-multiplier
solution (Eq. 10) of  min (n_m + n_r)  s.t.  A/n_m + B/n_r = C:

    A = u_m·t_m ;  B = v_r·t_r ;  C = D − u_m·v_r·t_s
    n_m = √A(√A+√B)/C ;  n_r = √B(√A+√B)/C

Task durations are estimated online from the completed-task sample mean
(Eq. 1) and re-estimated on every task completion (Algorithm 2 lines 17–20).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence

from .types import JobRuntime, SlotDemand, ceil_at_least_one


def mean_task_length(durations: Sequence[float]) -> Optional[float]:
    """Eq. (1): mean completed task length; None when no sample exists."""
    if not durations:
        return None
    return sum(durations) / len(durations)


def min_slots(
    u_m: int,
    v_r: int,
    t_m: float,
    t_r: float,
    t_s: float,
    deadline: float,
    *,
    max_map_slots: Optional[int] = None,
    max_reduce_slots: Optional[int] = None,
) -> SlotDemand:
    """Closed-form Eq. (10).

    When the shuffle term alone exceeds the deadline (C <= 0) the job is
    infeasible under the model: no finite slot count meets D.  We then demand
    the cluster caps (or a large sentinel) and flag ``feasible=False`` — the
    scheduler treats such jobs as "give it everything EDF allows".
    """
    if u_m <= 0 or v_r <= 0:
        raise ValueError("u_m and v_r must be positive")
    if t_m < 0 or t_r < 0 or t_s < 0:
        raise ValueError("task durations must be non-negative")

    a = u_m * t_m
    b = v_r * t_r
    c = deadline - (u_m * v_r) * t_s

    if c <= 0 or (a == 0 and b == 0):
        n_m = max_map_slots if max_map_slots is not None else u_m
        n_r = max_reduce_slots if max_reduce_slots is not None else v_r
        feasible = a == 0 and b == 0 and c >= 0
        return SlotDemand(
            n_m=max(1, n_m),
            n_r=max(1, n_r),
            feasible=feasible,
            n_m_cont=float("inf") if not feasible else 0.0,
            n_r_cont=float("inf") if not feasible else 0.0,
        )

    sa, sb = math.sqrt(a), math.sqrt(b)
    n_m_cont = sa * (sa + sb) / c
    n_r_cont = sb * (sa + sb) / c

    n_m = ceil_at_least_one(n_m_cont)
    n_r = ceil_at_least_one(n_r_cont)

    # A job never benefits from more slots than it has tasks.
    n_m = min(n_m, u_m)
    n_r = min(n_r, v_r)

    feasible = True
    if max_map_slots is not None and n_m > max_map_slots:
        n_m, feasible = max_map_slots, False
    if max_reduce_slots is not None and n_r > max_reduce_slots:
        n_r, feasible = max_reduce_slots, False
    return SlotDemand(
        n_m=n_m, n_r=n_r, feasible=feasible, n_m_cont=n_m_cont, n_r_cont=n_r_cont
    )


def completion_time(
    u_m: int, v_r: int, t_m: float, t_r: float, t_s: float, n_m: int, n_r: int
) -> float:
    """Eq. (7) left-hand side: modeled completion time for an allocation."""
    return (u_m * t_m) / n_m + (v_r * t_r) / n_r + (u_m * v_r) * t_s


@dataclass
class EstimatorConfig:
    """Knobs for the online estimator.

    ``assume_tr_equals_tm`` is paper Eq. (3) (homogeneous cluster).  When
    False we refine t_r with the reduce-task sample mean once one exists —
    the paper notes the scheduler "cannot make assumptions about the Reduce
    phase before seeing some Reduce tasks completing", so the bootstrap is
    always Eq. (3).
    """

    assume_tr_equals_tm: bool = True
    default_shuffle_time: float = 0.01   # t_s prior before any shuffle sample


class OnlineEstimator:
    """Per-job online resource estimator (Algorithm 2 lines 17–20).

    Re-computes Eq. (10) with the *remaining* work and *remaining* time:
    as the deadline gets nearer the demanded slot counts rise — this is the
    paper's "as time progresses and the job deadline gets nearer, the
    introduced mechanism re-computes the number of resources required".
    """

    def __init__(self, config: EstimatorConfig | None = None):
        self.config = config or EstimatorConfig()

    # -- duration estimates ------------------------------------------------
    def t_m(self, job: JobRuntime) -> Optional[float]:
        return mean_task_length(job.map_durations)

    def t_r(self, job: JobRuntime) -> Optional[float]:
        if not self.config.assume_tr_equals_tm and job.reduce_durations:
            return mean_task_length(job.reduce_durations)
        return self.t_m(job)   # Eq. (3)

    def t_s(self, job: JobRuntime) -> float:
        return job.spec.profile.shuffle_time_per_pair if job.spec.profile else (
            self.config.default_shuffle_time
        )

    # -- demand -------------------------------------------------------------
    def demand(
        self,
        job: JobRuntime,
        now: float,
        *,
        max_map_slots: Optional[int] = None,
        max_reduce_slots: Optional[int] = None,
        remaining_work: bool = True,
    ) -> Optional[SlotDemand]:
        """Eq. (10) demand; None while no map sample exists (bootstrap phase).

        With ``remaining_work`` (the scheduler's mode) the counts are the
        not-yet-completed tasks and the deadline is the time left; with
        ``remaining_work=False`` it is the submission-time estimate used for
        Table 2.
        """
        t_m = self.t_m(job)
        if t_m is None:
            return None
        t_r = self.t_r(job)
        assert t_r is not None
        t_s = self.t_s(job)
        spec = job.spec

        if remaining_work:
            u_m = spec.u_m - len(job.completed_map)
            v_r = spec.v_r - len(job.completed_reduce)
            # Shuffle copies still owed: completed maps have already pushed
            # their v_r copies.
            pairs_left = u_m * spec.v_r
            time_left = job.absolute_deadline - now
            if u_m == 0 and v_r == 0:
                return SlotDemand(n_m=0, n_r=0, feasible=True)
            u_m = max(u_m, 1)
            v_r = max(v_r, 1)
            if time_left <= 0:
                return SlotDemand(
                    n_m=min(u_m, max_map_slots or u_m),
                    n_r=min(v_r, max_reduce_slots or v_r),
                    feasible=False,
                    n_m_cont=float("inf"),
                    n_r_cont=float("inf"),
                )
            deadline = time_left + (u_m * v_r) * t_s - pairs_left * t_s
            # (equivalently: C = time_left − pairs_left·t_s)
        else:
            u_m, v_r, deadline = spec.u_m, spec.v_r, spec.deadline

        return min_slots(
            u_m, v_r, t_m, t_r, t_s, deadline,
            max_map_slots=max_map_slots, max_reduce_slots=max_reduce_slots,
        )
