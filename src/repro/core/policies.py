"""First-class policy API: composable, serializable scheduler specs.

The paper's contribution is a *policy* (Resource Predictor + Reconfigurator)
evaluated against baselines.  This module makes policies first-class values
instead of hardcoded strings threaded through four modules:

* :class:`PolicySpec` — a named policy plus typed parameter overrides, with
  a canonical serialized form (``to_dict``/``from_dict`` round-trip to
  identity) and a **stable cache key** the experiment warehouse hashes;
* :func:`register_policy` — the registry.  A policy registration declares
  its parameter schema (names, types and defaults), its *components* along
  the proposed scheduler's seams — job **ordering** (``edf`` /
  ``fair_deficit`` / ``fifo``), **park admission** (``off`` / ``fixed`` /
  ``adaptive``) and **overload** policy (``none`` / ``latch`` /
  ``reduce_aware``) — and a builder that constructs the scheduler;
* canonical presets: ``proposed``, ``adaptive``, ``fair``, ``fifo`` are
  registry entries whose built schedulers are **bit-identical** to the old
  string-keyed factory (pinned by ``tests/test_policies.py`` and re-fuzzed
  through this construction path by ``tests/test_parity_fuzz.py``).

Adding a policy is one registration.  The shipped non-preset entries show
the seams composing:

* ``adaptive_ra`` — the adaptive policy with the **reduce-aware** overload
  latch (does not trip on long reduce backlogs; the shuffle_heavy/20x2 fix);
* ``delay`` — delay scheduling [Zaharia, EuroSys'10]: fair deficit order,
  no reconfiguration, a job waits up to ``locality_delay`` scheduling
  offers for a data-local slot before launching remotely;
* ``edf_nopark`` — ablation: the proposed EDF/demand scheduler with parking
  disabled entirely (isolates Algorithm 2 from Algorithm 1).

Cache compatibility: for a spec with all-default parameters the cache
descriptor is the bare policy *name* — exactly the string the pre-policy
cell descriptors carried — so every existing sweep-cache cell still hits.
Parameter overrides switch the descriptor to the canonical dict form.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.types import ClusterSpec


class PolicyError(ValueError):
    """Unknown policy, unknown parameter, or ill-typed parameter value."""


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: the component axes every registration must declare, and their vocabulary.
#: Axes whose vocabulary includes "off" may be omitted from a registration
#: and default to "off" — adding a new axis must not break existing
#: registrations (the ``harvest`` axis arrived after the presets).
COMPONENT_AXES: Dict[str, Tuple[str, ...]] = {
    "ordering": ("edf", "fair_deficit", "fifo"),
    "park": ("off", "fixed", "adaptive"),
    "overload": ("none", "latch", "reduce_aware"),
    # Borg-style service-core harvesting (repro.simcluster.serving): off,
    # or utilization-EWMA borrowing against ServeConfig's headroom bar
    "harvest": ("off", "ewma"),
}


@dataclass(frozen=True)
class Policy:
    """One registry entry: schema + builder(s) for a named policy."""

    name: str
    description: str
    components: Mapping[str, str]          # axis -> value (COMPONENT_AXES)
    defaults: Mapping[str, object]         # param name -> default value
    builder: Callable[[ClusterSpec, Dict[str, object]], object]
    legacy_builder: Optional[Callable[[ClusterSpec, Dict[str, object]],
                                      object]] = None

    def validate_params(self, params: Mapping[str, object]) -> Dict[str, object]:
        """Type-check ``params`` against the schema and return only the
        entries that differ from the defaults (the canonical form: adding
        a new parameter with a default never changes existing specs'
        serialized form or cache keys)."""
        out: Dict[str, object] = {}
        for key in sorted(params):
            if key not in self.defaults:
                raise PolicyError(
                    f"policy {self.name!r} has no parameter {key!r}; "
                    f"available: {', '.join(sorted(self.defaults))}")
            default = self.defaults[key]
            value = params[key]
            if isinstance(default, bool):
                if not isinstance(value, bool):
                    raise PolicyError(
                        f"{self.name}.{key} must be a bool, got {value!r}")
            elif isinstance(default, float):
                if isinstance(value, bool) or not isinstance(value, (int, float)):
                    raise PolicyError(
                        f"{self.name}.{key} must be a number, got {value!r}")
                value = float(value)
            elif isinstance(default, int):
                if isinstance(value, bool) or not isinstance(value, int):
                    raise PolicyError(
                        f"{self.name}.{key} must be an int, got {value!r}")
            elif isinstance(default, str):
                if not isinstance(value, str):
                    raise PolicyError(
                        f"{self.name}.{key} must be a string, got {value!r}")
            if value != default:
                out[key] = value
        return out


_REGISTRY: Dict[str, Policy] = {}

#: the four names the pre-policy string factory understood; their default
#: specs must stay bit-identical to it and keep its cache descriptors
PRESET_NAMES: Tuple[str, ...] = ("proposed", "adaptive", "fair", "fifo")


def register_policy(name: str, *, description: str,
                    components: Mapping[str, str],
                    defaults: Optional[Mapping[str, object]] = None,
                    legacy_builder: Optional[Callable] = None):
    """Decorator registering ``fn(cluster, params) -> scheduler`` under
    ``name``.  ``components`` must cover every axis in ``COMPONENT_AXES``
    (axes with an "off" value may be omitted and default to it)."""
    components = dict(components)
    for axis, vocab in COMPONENT_AXES.items():
        if axis not in components and "off" in vocab:
            components[axis] = "off"
        if components.get(axis) not in vocab:
            raise PolicyError(
                f"policy {name!r}: component {axis!r} must be one of "
                f"{vocab}, got {components.get(axis)!r}")

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise PolicyError(f"policy {name!r} already registered")
        _REGISTRY[name] = Policy(
            name=name, description=description,
            components=dict(components), defaults=dict(defaults or {}),
            builder=fn, legacy_builder=legacy_builder)
        return fn
    return deco


def get_policy(name: str) -> Policy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise PolicyError(
            f"unknown policy {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def registered_policies() -> Dict[str, Policy]:
    """Name -> registration, in registration order."""
    return dict(_REGISTRY)


def partition_policies(predicate) -> Tuple[List[str], List[str]]:
    """Split registered policy names by a predicate over their default
    ``PolicySpec``: ``(accepted, rejected)``, each in registration order.

    The canonical consumer is engine-capability gating — e.g. the fluid
    surrogate partitions the registry into policies it can lower and
    policies that stay oracle-only (``repro.simcluster.surrogate
    .surrogate_supported``), and its fuzz wall iterates the rejected side
    asserting every one raises rather than silently approximating."""
    accepted: List[str] = []
    rejected: List[str] = []
    for name in _REGISTRY:
        (accepted if predicate(PolicySpec.parse(name)) else
         rejected).append(name)
    return accepted, rejected


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

@dataclass
class PolicySpec:
    """A scheduler policy as a value: registry name + parameter overrides.

    ``params`` is canonicalized on construction: unknown names and ill-typed
    values raise :class:`PolicyError`, and entries equal to the registered
    defaults are dropped — so two specs describing the same policy compare
    equal, serialize identically and share one cache key."""

    name: str
    params: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self) -> None:
        policy = get_policy(self.name)
        self.params = policy.validate_params(self.params)

    # -- construction --------------------------------------------------------
    @classmethod
    def parse(cls, value) -> "PolicySpec":
        """Coerce a policy-shaped value: a ``PolicySpec`` (returned as is),
        a bare name, a JSON object string (the CLI's ``--policy``), or a
        ``{"name": ..., "params": {...}}`` mapping."""
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            text = value.strip()
            if text.startswith("{"):
                try:
                    value = json.loads(text)
                except json.JSONDecodeError as e:
                    raise PolicyError(f"bad policy JSON: {e}") from None
            else:
                return cls(name=text)
        if isinstance(value, Mapping):
            return cls.from_dict(value)
        raise PolicyError(f"cannot parse a policy from {value!r}")

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "PolicySpec":
        extra = set(d) - {"name", "params"}
        if extra or "name" not in d:
            raise PolicyError(
                "policy dict must be {'name': ..., 'params': {...}}, got "
                f"keys {sorted(d)}")
        if not isinstance(d["name"], str):
            raise PolicyError(f"policy name must be a string, "
                              f"got {d['name']!r}")
        params = d.get("params", {})
        if not isinstance(params, Mapping):
            raise PolicyError(f"policy params must be a mapping, got {params!r}")
        return cls(name=d["name"], params=dict(params))

    # -- canonical forms -----------------------------------------------------
    def to_dict(self) -> Dict[str, object]:
        """Canonical serialized form; ``from_dict(to_dict(s)) == s``."""
        return {"name": self.name,
                "params": {k: self.params[k] for k in sorted(self.params)}}

    def cache_descriptor(self):
        """Value embedded in experiment-cache cell descriptors.  A spec with
        all-default parameters collapses to the bare name — byte-identical
        to the descriptors the old string-keyed factory produced, so
        pre-policy cache cells keep hitting."""
        return self.name if not self.params else self.to_dict()

    def cache_key(self) -> str:
        """Stable 16-hex content key of the canonical form (pinned by
        ``tests/test_policies.py`` — changing it orphans sweep caches)."""
        blob = json.dumps(self.cache_descriptor(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    @property
    def label(self) -> str:
        """Short human/warehouse identifier: the name, plus any non-default
        parameters in canonical order."""
        if not self.params:
            return self.name
        inner = ",".join(f"{k}={self.params[k]}" for k in sorted(self.params))
        return f"{self.name}[{inner}]"

    # -- schema views --------------------------------------------------------
    @property
    def policy(self) -> Policy:
        return get_policy(self.name)

    @property
    def components(self) -> Dict[str, str]:
        return dict(self.policy.components)

    def effective_params(self) -> Dict[str, object]:
        """Defaults overlaid with this spec's overrides."""
        out = dict(self.policy.defaults)
        out.update(self.params)
        return out

    # -- building ------------------------------------------------------------
    def build(self, cluster: ClusterSpec, *, legacy: bool = False):
        """Construct the scheduler this spec describes on ``cluster``.

        ``legacy=True`` builds the frozen seed engine's counterpart (parity
        oracle); policies with no legacy counterpart raise PolicyError."""
        policy = self.policy
        params = self.effective_params()
        if legacy:
            if policy.legacy_builder is None:
                raise PolicyError(
                    f"policy {self.name!r} has no legacy (seed-engine) "
                    "counterpart")
            sched = policy.legacy_builder(cluster, params)
        else:
            sched = policy.builder(cluster, params)
            sched.policy = self
        sched.name = self.label
        return sched


def build_policy(spec, cluster: ClusterSpec, *, legacy: bool = False):
    """Functional spelling of ``PolicySpec.parse(spec).build(cluster)``."""
    return PolicySpec.parse(spec).build(cluster, legacy=legacy)


# ---------------------------------------------------------------------------
# registrations: the canonical presets + the composed extras
# ---------------------------------------------------------------------------

#: AdaptiveConfig knobs the adaptive presets expose as PolicySpec params
#: (searchable dimensions; ROADMAP direction 2).  Values mirror the
#: AdaptiveConfig field defaults, so a default-built spec leaves the
#: cluster's config untouched and keeps the bare-name cache descriptor.
_ADAPTIVE_PARAM_KNOBS: Dict[str, object] = {
    "surge_width": 16.0,
    "crash_discount": True,
    "ewma_gap_cap": 4.0,
}


def _adaptive_cluster(cluster: ClusterSpec,
                      p: Optional[Mapping[str, object]] = None) -> ClusterSpec:
    """The cluster with its AdaptiveConfig switched on (the adaptive knobs
    themselves live on ``ClusterSpec`` and are part of the *cluster* cache
    identity, exactly as before).  ``p`` (the policy's effective params)
    may override the ``_ADAPTIVE_PARAM_KNOBS`` fields — e.g. the
    ``surge_width=0`` ablation recovers the pre-PR-8 latch."""
    overrides = {}
    if p is not None:
        overrides = {k: p[k] for k in _ADAPTIVE_PARAM_KNOBS
                     if k in p and p[k] != getattr(cluster.adaptive, k)}
    if cluster.adaptive.enabled and not overrides:
        return cluster
    return dataclasses.replace(
        cluster,
        adaptive=dataclasses.replace(cluster.adaptive, enabled=True,
                                     **overrides))


def _legacy_proposed(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.simcluster import _legacy as L
    sched = L.LegacyCompletionTimeScheduler(
        cluster, L.LegacyReconfigurator(cluster, max_wait=p["max_wait"]))
    sched.park_depth = p["park_depth"]
    return sched


def _legacy_fair(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.simcluster import _legacy as L
    return L.LegacyFairScheduler(cluster,
                                 locality_delay=p["locality_delay"])


def _legacy_fifo(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.simcluster import _legacy as L
    return L.LegacyFIFOScheduler(cluster)


@register_policy(
    "proposed",
    description="The paper's completion-time scheduler (Algorithm 2) with "
                "fixed-patience VM-reconfiguration parking (Algorithm 1).",
    components={"ordering": "edf", "park": "fixed", "overload": "none"},
    defaults={"max_wait": 30.0, "park_depth": 2},
    legacy_builder=_legacy_proposed)
def _build_proposed(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.core.reconfigurator import Reconfigurator
    from repro.core.scheduler import CompletionTimeScheduler
    # NB: the ctor's overload default ("latch") is deliberately left in
    # place rather than pinned to the declared "none" component: on the
    # preset's own terms the overload machinery is inert (it requires
    # ``cluster.adaptive.enabled``, which `proposed` does not set), and a
    # caller who hands in a cluster that *does* enable it must get the
    # pre-policy factory's behaviour bit-exactly — that construction used
    # the ctor default, and the cache descriptor for this preset is still
    # the bare string "proposed".
    return CompletionTimeScheduler(
        cluster, Reconfigurator(cluster, max_wait=p["max_wait"]),
        park_depth=p["park_depth"])


@register_policy(
    "adaptive",
    description="Proposed scheduler with the pressure-adaptive "
                "reconfiguration policy (AdaptiveConfig) and the latching "
                "overload detector switched on.",
    components={"ordering": "edf", "park": "adaptive", "overload": "latch"},
    defaults={"max_wait": 30.0, "park_depth": 2, **_ADAPTIVE_PARAM_KNOBS})
def _build_adaptive(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.core.reconfigurator import Reconfigurator
    from repro.core.scheduler import CompletionTimeScheduler
    cluster = _adaptive_cluster(cluster, p)
    return CompletionTimeScheduler(
        cluster, Reconfigurator(cluster, max_wait=p["max_wait"]),
        park_depth=p["park_depth"], overload="latch")


@register_policy(
    "adaptive_ra",
    description="Adaptive policy with the reduce-aware overload latch: the "
                "crowd bar counts only map-open jobs and the latch releases "
                "when the map backlog drains, so long reduce backlogs "
                "neither trip nor hold it.",
    components={"ordering": "edf", "park": "adaptive",
                "overload": "reduce_aware"},
    defaults={"max_wait": 30.0, "park_depth": 2, **_ADAPTIVE_PARAM_KNOBS})
def _build_adaptive_ra(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.core.reconfigurator import Reconfigurator
    from repro.core.scheduler import CompletionTimeScheduler
    cluster = _adaptive_cluster(cluster, p)
    return CompletionTimeScheduler(
        cluster, Reconfigurator(cluster, max_wait=p["max_wait"]),
        park_depth=p["park_depth"], overload="reduce_aware")


@register_policy(
    "harvest",
    description="Adaptive policy plus Borg-style service-core harvesting: "
                "with ServeConfig active, idle service cores (utilization "
                "EWMA under the headroom bar) are lent to the batch side "
                "to plug parked maps and returned preemptively on load "
                "spikes before the p99 SLO is breached.  Identical to "
                "`adaptive` when serving is off.",
    components={"ordering": "edf", "park": "adaptive", "overload": "latch",
                "harvest": "ewma"},
    defaults={"max_wait": 30.0, "park_depth": 2, **_ADAPTIVE_PARAM_KNOBS})
def _build_harvest(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.core.reconfigurator import Reconfigurator
    from repro.core.scheduler import CompletionTimeScheduler
    cluster = _adaptive_cluster(cluster, p)
    sched = CompletionTimeScheduler(
        cluster, Reconfigurator(cluster, max_wait=p["max_wait"]),
        park_depth=p["park_depth"], overload="latch")
    sched.harvest = True
    return sched


@register_policy(
    "fair",
    description="Hadoop Fair Scheduler: equal instantaneous share, deficit "
                "round-robin; no deadlines, estimator or reconfiguration.",
    components={"ordering": "fair_deficit", "park": "off", "overload": "none"},
    defaults={"locality_delay": 0},
    legacy_builder=_legacy_fair)
def _build_fair(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.core.baselines import FairScheduler
    return FairScheduler(cluster, locality_delay=p["locality_delay"])


@register_policy(
    "fifo",
    description="Hadoop default FIFO scheduler: submission order.",
    components={"ordering": "fifo", "park": "off", "overload": "none"},
    legacy_builder=_legacy_fifo)
def _build_fifo(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.core.baselines import FIFOScheduler
    return FIFOScheduler(cluster)


@register_policy(
    "delay",
    description="Delay scheduling [Zaharia, EuroSys'10]: fair deficit order; "
                "a job skips up to locality_delay scheduling offers while it "
                "has no data-local task on the offered node, then launches "
                "remotely.",
    components={"ordering": "fair_deficit", "park": "off", "overload": "none"},
    defaults={"locality_delay": 8},
    legacy_builder=_legacy_fair)
def _build_delay(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.core.baselines import FairScheduler
    return FairScheduler(cluster, locality_delay=p["locality_delay"])


@register_policy(
    "edf_nopark",
    description="Ablation: the proposed EDF/demand scheduler with parking "
                "disabled — every non-local map launches remotely at once "
                "(Algorithm 2 without Algorithm 1).",
    components={"ordering": "edf", "park": "off", "overload": "none"},
    defaults={"max_wait": 30.0, "park_depth": 2})
def _build_edf_nopark(cluster: ClusterSpec, p: Dict[str, object]):
    from repro.core.reconfigurator import Reconfigurator
    from repro.core.scheduler import CompletionTimeScheduler
    return CompletionTimeScheduler(
        cluster, Reconfigurator(cluster, max_wait=p["max_wait"]),
        park_depth=p["park_depth"], parking=False, overload="none")


# ---------------------------------------------------------------------------
# smoke check (CI: `python -m repro.experiments policies --smoke`)
# ---------------------------------------------------------------------------

def smoke_test_policies(*, num_machines: int = 2,
                        seed: int = 0) -> List[str]:
    """Instantiate every registered policy on a tiny cluster, drive a short
    scenario to completion and flag stranded work.  Returns failure strings
    (empty = all policies healthy)."""
    import random

    from repro.simcluster.sim import ClusterSim
    from repro.simcluster.workloads import default_deadline, make_job

    failures: List[str] = []
    for name in registered_policies():
        spec = PolicySpec(name)
        cluster = ClusterSpec(num_machines=num_machines, vms_per_machine=2,
                              replication=1)
        rng = random.Random(seed)
        jobs = [make_job(f"{w}-{i}", w, 0.25,
                         default_deadline(w, 0.25), cluster, rng,
                         submit_time=float(i))
                for i, w in enumerate(("wordcount", "grep"))]
        try:
            sched = spec.build(cluster)
            result = ClusterSim(cluster, sched, seed=seed).run(jobs)
        except Exception as e:           # noqa: BLE001 - smoke surface
            failures.append(f"{name}: {type(e).__name__}: {e}")
            continue
        for jid, rt in result.jobs.items():
            if rt.finish_time is None:
                failures.append(f"{name}: job {jid} never finished")
            elif rt.pending_map or rt.pending_reduce:
                failures.append(f"{name}: job {jid} left stranded tasks")
        if result.scheduler != spec.label:
            failures.append(f"{name}: result labelled {result.scheduler!r}")
    return failures
