"""Decision-trace bus: attributed scheduler telemetry.

The simulator's only introspection used to be a bare ``fault_log`` list of
``(time, kind, machine)`` tuples.  This module adds a structured,
default-off event bus that the sim, the scheduler and the reconfigurator
all share, so a single run can answer *why* questions: why was this map
launched remote, which Algorithm-1 gate denied this park, what tripped
the overload latch and what (if anything) released it.

Design contracts (enforced by tests/test_tracing.py and the parity fuzz):

* **Observer only.**  A ``TraceBus`` draws from no RNG and mutates no
  simulation state; every emission site is guarded by a single
  ``trace is not None`` check, so tracing-off is bit-exact against the
  frozen ``_legacy`` engine and tracing-on changes nothing but the bus.
* **Bounded.**  ``TraceConfig.max_events`` caps retained records; the
  per-kind counters keep counting past the cap and the overflow is
  visible in :attr:`TraceBus.dropped`.
* **One schema for faults and decisions.**  ``fault_log`` entries are
  :class:`FaultEvent` named tuples now — they serialize (via
  ``json.dumps``) byte-identically to the old bare tuples, compare equal
  to them, and unpack the same way, so the byte-reproducibility pins in
  tests/test_faults.py hold while the same events also appear on the bus
  with full context.
"""

from __future__ import annotations

import json
from typing import Dict, Iterator, List, NamedTuple, Tuple

from repro.core.types import TaskId, TraceConfig


class FaultEvent(NamedTuple):
    """A ``fault_log`` entry: the typed twin of the legacy tuple.

    NamedTuple keeps byte-compatibility: ``json.dumps`` renders it as the
    same ``[time, "kind", machine]`` array, ``==`` against old tuples
    holds, and ``for t, kind, m in sim.fault_log`` still unpacks.
    """

    time: float
    kind: str      # "crash" | "restart" | "burst" | "rereplicate"
    machine: int


# Algorithm-1 park gates, in the order the scheduler evaluates them.
# ``park_deny`` records carry exactly one of these in their ``gate`` field.
PARK_GATES: Tuple[str, ...] = (
    "parking_off",        # scheduler built with parking disabled
    "no_park",            # task already expired out of a queue once
    "deadline_critical",  # slack under 3x the parking wait bound
    "remote_fill",        # phase-3 backfill: parking not offered at all
    "overload_latch",     # latched overload mode: parking suspended
    "crowd_bar",          # adaptive crowd bar (unlatched; wide batches exempt)
    "replicas_down",      # every replica holder is crashed
    "aq_saturated",       # anticipation queue at park_depth on the target
    "width_gate",         # pending maps too narrow vs open map jobs
    "fail_streak",        # reconfigurator: consecutive-loss circuit breaker
    "predicted_wait",     # reconfigurator: EWMA wait forecast > breakeven
    "win_floor",          # reconfigurator: park win-rate EWMA under floor
)

# Causes a latch_release record can carry: the adaptive overload latch's
# exit vocabulary (see CompletionTimeScheduler._overload_check).
LATCH_RELEASE_CAUSES: Tuple[str, ...] = (
    "empty_cluster",      # a new job found a fully-drained cluster
    "cluster_drained",    # no active job left
    "maps_drained",       # reduce_aware: map backlog fully drained
    "churn_drain",        # faults: empty backlog mid-churn ends the epoch
    "churn_relief",       # faults: fleet degraded / crash-lost maps still
                          # re-pending — churn, not overload; park
                          # admission reverts to the fixed policy's gates
    "win_release",        # win-aware: backlog became a wide batch — parking
                          # wins there, exact-Fair would surrender them
)

# Causes a park_outcome record can carry (reconfigurator feedback loop).
PARK_OUTCOME_CAUSES: Tuple[str, ...] = (
    "reservation",        # won: launched data-locally via its AQ reservation
    "donor_match",        # won: launched through a donor-core hot-plug
    "remote",             # lost: burned its patience, launched remotely
    "crash_discount",     # discounted: remote launch forced by a crash
                          # (every live replica down) — gates not charged
)

# Signals a harvest_borrow / harvest_return record can carry (serving
# layer decision loop; see repro.simcluster.serving).
HARVEST_SIGNALS: Tuple[str, ...] = (
    "parked_demand",      # borrow: parked maps wait on this machine's AQ
    "map_backlog",        # borrow: cluster-wide pending maps, util is low
    "util_spike",         # return: utilization EWMA over the return bar
    "p99_pressure",       # return: tick p99 reached the SLO — preempt
    "churn_relief",       # return: harvesting stands down under churn
    "machine_down",       # return: the host machine crashed
)

# Every record kind the bus can carry, grouped by TraceConfig switch.
EVENT_KINDS: Dict[str, Tuple[str, ...]] = {
    "launches": ("job_submit", "job_finish", "launch", "finish", "kill"),
    "parks": ("park_admit", "park_deny", "park_outcome", "reconfig_match",
              "unpark", "park_expired", "park_crashed"),
    "overload": ("latch_trip", "latch_release"),
    "faults": ("crash", "restart", "burst", "rereplicate"),
    "serve": ("serve_tick", "harvest_borrow", "harvest_return"),
    "pressure": ("pressure",),
}


def dumps_canonical(obj: object) -> str:
    """Canonical JSON: sorted keys, no whitespace — byte-stable across
    runs so traces can be diffed and hashed."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


class TraceBus:
    """Append-only event sink shared by sim, scheduler and reconfigurator.

    ``emit`` is deliberately tiny (a dict increment plus a bounded list
    append of a plain tuple) because it sits on the task launch/finish
    hot path when tracing is enabled; the ≤10% events/sec overhead gate
    in scripts/check.sh holds it to that.
    """

    __slots__ = ("config", "launches", "parks", "overload", "faults",
                 "serve", "pressure_every", "max_events", "events", "counts",
                 "dropped")

    def __init__(self, config: TraceConfig) -> None:
        self.config = config
        # per-category booleans are precomputed so emission sites test a
        # plain attribute, not a dataclass field chain
        self.launches = config.launches
        self.parks = config.parks
        self.overload = config.overload
        self.faults = config.faults
        self.serve = config.serve
        self.pressure_every = config.pressure_every
        self.max_events = config.max_events
        self.events: List[Tuple[float, str, Dict[str, object]]] = []
        self.counts: Dict[str, int] = {}
        self.dropped = 0

    def emit(self, t: float, kind: str, data: Dict[str, object]) -> None:
        counts = self.counts
        counts[kind] = counts.get(kind, 0) + 1
        if len(self.events) < self.max_events:
            self.events.append((t, kind, data))
        else:
            self.dropped += 1

    def count(self, kind: str) -> int:
        return self.counts.get(kind, 0)

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def records(self) -> Iterator[Dict[str, object]]:
        """Flattened dict view of every retained event, in emission
        order.  ``t`` and ``kind`` are reserved keys; payload fields must
        not collide with them (enforced here, not trusted).  Emission
        sites store raw ``TaskId`` objects (stringifying ~10^4 ids would
        sit on the launch hot path); they render canonically here."""
        for t, kind, data in self.events:
            rec: Dict[str, object] = {"t": t, "kind": kind}
            for k, v in data.items():
                if k not in ("t", "kind"):
                    rec[k] = str(v) if isinstance(v, TaskId) else v
            yield rec

    def to_jsonl(self) -> str:
        """Canonical JSONL: one sorted-key record per line."""
        return "".join(dumps_canonical(r) + "\n" for r in self.records())
