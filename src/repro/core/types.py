"""Core datatypes for the deadline/locality scheduler.

These types model the paper's world (MapReduce jobs, map/reduce tasks, slots,
HDFS-style block placement) in a backend-agnostic way: the same types drive

* the faithful discrete-event reproduction (`repro.simcluster`),
* the real JAX MapReduce engine (`repro.mapreduce`), and
* the fleet-level elastic TPU scheduler (`repro.elastic`), where a "map task"
  is a data-parallel microbatch and a "slot" is a chip.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import heapq
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple


class TaskKind(enum.Enum):
    MAP = "map"
    REDUCE = "reduce"


class TaskState(enum.Enum):
    UNSTARTED = "unstarted"   # U^j in the paper
    RUNNING = "running"       # R^j
    COMPLETED = "completed"   # C^j


@dataclass(frozen=True)
class TaskId:
    job_id: str
    kind: TaskKind
    index: int

    def __str__(self) -> str:  # pragma: no cover - debug aid
        return f"{self.job_id}/{self.kind.value}{self.index}"


@dataclass
class WorkloadProfile:
    """Nominal execution characteristics of one MapReduce workload.

    The scheduler never reads these directly -- it estimates durations online
    from completed tasks (paper Eq. 1).  The *simulator* uses them as ground
    truth, optionally perturbed per-task.

    Attributes:
      name: workload name (wordcount, sort, grep, permutation, inverted_index).
      map_time: nominal seconds for one map task on a *data-local* node.
      reduce_time: nominal seconds for one reduce task (compute portion).
      shuffle_time_per_pair: ``t_s`` -- seconds for one mapper->reducer copy.
      remote_penalty: fractional slowdown of a map task reading its input
        block from a remote node (e.g. 0.45 => 45% slower).
      intermediate_ratio: bytes(intermediate)/bytes(input); drives the
        "reduce-input heavy" behaviour of Permutation Generator.
      time_cv: coefficient of variation for per-task duration jitter.
    """

    name: str
    map_time: float
    reduce_time: float
    shuffle_time_per_pair: float
    remote_penalty: float = 0.45
    intermediate_ratio: float = 1.0
    time_cv: float = 0.08

    def to_dict(self) -> Dict[str, object]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "WorkloadProfile":
        return cls(**d)


@dataclass
class JobSpec:
    """A MapReduce job with a completion-time goal.

    ``u_m`` / ``v_r`` follow the paper's symbols (number of map / reduce
    tasks).  ``block_placement[i]`` lists the node ids that hold a replica of
    map task *i*'s input block.
    """

    job_id: str
    profile: WorkloadProfile
    u_m: int
    v_r: int
    deadline: float                      # D, seconds from submission
    submit_time: float = 0.0
    input_size_gb: float = 0.0
    block_placement: List[Tuple[int, ...]] = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.u_m <= 0 or self.v_r <= 0:
            raise ValueError("jobs need at least one map and one reduce task")
        if self.deadline <= 0:
            raise ValueError("deadline must be positive")

    def to_dict(self) -> Dict[str, object]:
        # asdict introspects fields, so a future field cannot silently be
        # left out of the serialized form
        d = asdict(self)
        d["block_placement"] = [list(p) for p in d["block_placement"]]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "JobSpec":
        d = dict(d)
        d["profile"] = WorkloadProfile.from_dict(d["profile"])
        d["block_placement"] = [tuple(p) for p in d["block_placement"]]
        return cls(**d)


@dataclass
class SlotDemand:
    """Output of the resource estimator: Eq. (10) of the paper."""

    n_m: int      # minimum map slots
    n_r: int      # minimum reduce slots
    feasible: bool
    # Raw (continuous) Lagrange solution, for analysis / tests.
    n_m_cont: float = float("nan")
    n_r_cont: float = float("nan")


@dataclass
class JobRuntime:
    """Mutable execution state of a job as seen by a scheduler.

    Tracks the paper's sets C^j (completed), R^j (running), U^j (unstarted)
    per phase, plus the observed durations that feed Eq. (1).

    The U^j sets are materialized incrementally: ``pending_map`` /
    ``pending_reduce`` hold the not-yet-started indices, and lazy min-heaps
    plus a per-node inverted index (``node -> pending local map ids``) answer
    "first unstarted task" and "first data-local task on this node" in
    amortized O(1) instead of rescanning ``range(u_m)``.  An index leaves the
    pending sets exactly once (task start); heap entries are discarded lazily
    on peek, so every index is popped from every heap at most once over the
    job's lifetime.
    """

    spec: JobSpec
    seq: int = 0                       # admission order, set by the scheduler
    completed_map: Set[int] = field(default_factory=set)
    running_map: Dict[int, int] = field(default_factory=dict)      # task -> node
    completed_reduce: Set[int] = field(default_factory=set)
    running_reduce: Dict[int, int] = field(default_factory=dict)
    map_durations: List[float] = field(default_factory=list)
    reduce_durations: List[float] = field(default_factory=list)
    map_duration_sum: float = 0.0
    reduce_duration_sum: float = 0.0
    demand: Optional[SlotDemand] = None
    finish_time: Optional[float] = None
    local_map_launches: int = 0
    remote_map_launches: int = 0
    reconfig_map_launches: int = 0     # launched data-local via Algorithm 1
    # flag mirrors of the map_finished / finished / started properties,
    # maintained by SchedulerBase at state transitions so scheduler hot
    # loops read a plain attribute instead of recomputing set sizes
    map_done: bool = field(default=False, repr=False)
    all_done: bool = field(default=False, repr=False)
    has_progress: bool = field(default=False, repr=False)
    pending_map: Set[int] = field(default_factory=set, repr=False)
    pending_reduce: Set[int] = field(default_factory=set, repr=False)
    _pending_map_heap: List[int] = field(default_factory=list, repr=False)
    _pending_reduce_heap: List[int] = field(default_factory=list, repr=False)
    _local_heaps: Dict[int, List[int]] = field(default_factory=dict, repr=False)

    def __post_init__(self) -> None:
        u, v = self.spec.u_m, self.spec.v_r
        self.pending_map = set(range(u))
        self.pending_reduce = set(range(v))
        # ascending ranges are already valid heaps
        self._pending_map_heap = list(range(u))
        self._pending_reduce_heap = list(range(v))
        self._local_heaps = {}
        for i, placement in enumerate(self.spec.block_placement[:u]):
            for node in set(placement):
                self._local_heaps.setdefault(node, []).append(i)

    # -- incremental-index queries (amortized O(1)) ----------------------
    def first_pending_map(self) -> Optional[int]:
        heap, pend = self._pending_map_heap, self.pending_map
        while heap:
            if heap[0] in pend:
                return heap[0]
            heapq.heappop(heap)
        return None

    def first_local_pending_map(self, node: int) -> Optional[int]:
        heap = self._local_heaps.get(node)
        if not heap:
            return None
        pend = self.pending_map
        while heap:
            if heap[0] in pend:
                return heap[0]
            heapq.heappop(heap)
        return None

    def first_pending_reduce(self) -> Optional[int]:
        heap, pend = self._pending_reduce_heap, self.pending_reduce
        while heap:
            if heap[0] in pend:
                return heap[0]
            heapq.heappop(heap)
        return None

    def mean_map_duration(self) -> Optional[float]:
        if not self.map_durations:
            return None
        return self.map_duration_sum / len(self.map_durations)

    # -- paper-set views -------------------------------------------------
    @property
    def unstarted_map(self) -> int:
        return self.spec.u_m - len(self.completed_map) - len(self.running_map)

    @property
    def unstarted_reduce(self) -> int:
        return self.spec.v_r - len(self.completed_reduce) - len(self.running_reduce)

    @property
    def map_finished(self) -> bool:
        return len(self.completed_map) == self.spec.u_m

    @property
    def finished(self) -> bool:
        return self.map_finished and len(self.completed_reduce) == self.spec.v_r

    @property
    def started(self) -> bool:
        """Paper Algorithm 2: jobs with no completed or running tasks get
        precedence so the estimator can bootstrap."""
        return bool(
            self.completed_map
            or self.running_map
            or self.completed_reduce
            or self.running_reduce
        )

    @property
    def absolute_deadline(self) -> float:
        return self.spec.submit_time + self.spec.deadline

    def locality_rate(self) -> float:
        launches = self.local_map_launches + self.remote_map_launches
        return self.local_map_launches / launches if launches else 0.0


@dataclass(frozen=True)
class AdaptiveConfig:
    """Pressure-adaptive reconfiguration policy (paper §4.1 extension).

    The paper's Algorithm 1 parks a non-local map task on the data node's
    machine with a *fixed* patience (``Reconfigurator.max_wait``) — a bet
    that "the target system will soon have a free core".  Under sustained
    saturation every VM keeps its freed cores for its own local work, the
    bet loses, and parked tasks starve (the regime atlas' diurnal/20x2
    loss cell).  When ``enabled``, the reconfigurator tracks per-machine
    core-pressure signals — queued donor-offer depth (valid RQ entries),
    the oldest AQ wait, and an EWMA of donor-offer intervals fed by the
    simulator's release events — and uses them to

    * **gate park admission**: when the predicted core wait exceeds the
      task's remote-launch break-even (``map_time x remote_penalty``,
      fabric-scaled), or the machine's recent parks keep ending in remote
      launches (fail streak), the task launches remotely immediately
      instead of parking;
    * **scale each park's patience**: a machine with no recent failure
      parks at the fixed ``max_wait``; one that lost a park since its last
      win (or a probe under the suspended win-rate floor) only earns
      ``max_wait_floor`` — every bound clamped to
      ``[max_wait_floor, max_wait_ceiling]``;
    * **suspend parking on starved machines**: ``fail_streak_limit``
      remote-ending park outcomes in a row suspend parking there until an
      offer arrives, a park pays off, or ``fail_cooldown`` quiet seconds
      earn a fresh probe;
    * **spread capacity under sustained overload**: when the queued map
      backlog exceeds ``overload_pending_factor x`` cluster map slots and
      active jobs outnumber ``overload_active_factor x`` machines (EDF
      priority then only serializes the drain tail), scheduling
      degenerates to the exact Fair assignment (deficit round-robin at
      task granularity, parking suspended), latched until the cluster
      fully drains.  The scheduler also tracks the set of active jobs
      already past their deadline (``overdue``) as an observable pressure
      signal.

    Defaults to **off** — with ``enabled=False`` the engine is bit-exact
    against the frozen legacy engine (pinned by the parity fuzz suite).
    """

    enabled: bool = False
    max_wait_floor: float = 4.0       # seconds; shortest per-park patience
    max_wait_ceiling: float = 45.0    # seconds; longest per-park patience
    ewma_alpha: float = 0.25          # weight of the newest observed interval
    breakeven_margin: float = 1.0     # park only if predicted <= margin x remote cost
    fail_streak_limit: int = 2        # remote-ending parks that suspend a machine
    fail_cooldown: float = 30.0       # quiet seconds before a suspended machine re-probes
    outcome_alpha: float = 0.12       # weight of the newest park outcome (cluster-wide)
    park_win_floor: float = 0.35      # suspend all parking when win-rate EWMA dips below
    # parking is only admitted while active jobs stay under
    # park_active_factor x machines AND the queued backlog averages at
    # least park_min_width pending maps per active job: narrow jobs (or a
    # crowd) put every parked map on its job's phase-critical path, while
    # wide jobs (the paper's closed mix) park for free — a parked map has
    # plenty of siblings to keep its job's map phase busy
    park_active_factor: float = 0.3
    park_min_width: float = 12.0
    # overload (fair-spread) mode enters when the map backlog reaches
    # pending_factor x cluster map slots AND active jobs reach
    # active_factor x machines, then latches until the cluster fully
    # drains (idle epoch reset)
    overload_pending_factor: float = 0.25
    overload_active_factor: float = 0.5
    # win-aware latch + churn-proof gates.  A backlog averaging at least
    # surge_width pending maps per map-open job is a *healthy wide batch*
    # (the paper's closed-mix regime, or churn re-pending lost work), not
    # the many-small-jobs surge the latch exists for: the latch neither
    # trips on one nor holds through one (release cause "win_release",
    # vetoed while the park win-rate EWMA sits under park_win_floor), and
    # the crowd bar stops suppressing park admission.  0 disables (the
    # pre-PR-8 latch/crowd behavior).
    surge_width: float = 16.0
    # park losses whose remote launch was forced by a crash (every live
    # replica of the task down) are discounted from the fail-streak and
    # win-rate gates — churn must not read as park starvation
    crash_discount: bool = True
    # offer/core-free EWMA samples are clamped to gap_cap x the running
    # mean: an interval spanning a restart gap (or any long disruption)
    # must not inflate the predicted core wait for the whole next epoch.
    # 0 disables the cap.
    ewma_gap_cap: float = 4.0

    def __post_init__(self) -> None:
        if self.max_wait_floor < 0:
            raise ValueError("max_wait_floor must be non-negative")
        if self.max_wait_ceiling < self.max_wait_floor:
            raise ValueError("max_wait_ceiling must be >= max_wait_floor")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.breakeven_margin <= 0:
            raise ValueError("breakeven_margin must be positive")
        if self.fail_streak_limit < 1:
            raise ValueError("fail_streak_limit must be >= 1")
        if self.fail_cooldown < 0:
            raise ValueError("fail_cooldown must be non-negative")
        if not 0.0 < self.outcome_alpha <= 1.0:
            raise ValueError("outcome_alpha must be in (0, 1]")
        if not 0.0 <= self.park_win_floor <= 1.0:
            raise ValueError("park_win_floor must be in [0, 1]")
        if self.park_active_factor <= 0:
            raise ValueError("park_active_factor must be positive")
        if self.park_min_width < 0:
            raise ValueError("park_min_width must be non-negative")
        if self.overload_pending_factor <= 0 or self.overload_active_factor <= 0:
            raise ValueError("overload entry factors must be positive")
        if self.surge_width < 0:
            raise ValueError("surge_width must be non-negative")
        if self.ewma_gap_cap < 0:
            raise ValueError("ewma_gap_cap must be non-negative")

    def to_dict(self) -> Dict[str, object]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "AdaptiveConfig":
        return cls(**d)


#: field defaults looked up by ClusterSpec.to_dict when deciding which
#: adaptive knobs to omit for cache compatibility (kept next to the class
#: so a default change cannot silently diverge from the omission rule)
_ADAPTIVE_FIELD_DEFAULTS: Dict[str, object] = {
    f.name: f.default for f in dataclasses.fields(AdaptiveConfig)}


@dataclass(frozen=True)
class MachineClass:
    """One hardware generation in a heterogeneous fleet.

    Machines are assigned to classes round-robin over the weight-expanded
    pattern (weights 3,1 -> m % 4 in {0,1,2} is class 0), so any fleet size
    gets the requested mix deterministically.

    Attributes:
      name: label for logs/atlas columns.
      weight: relative share of machines in this class (>= 1).
      speed: task-duration multiplier on this class (> 1 = slower
        hardware generation; scales map *and* reduce compute).
      fabric: remote-read-penalty multiplier for map tasks running on this
        class (NIC/uplink generation; composes with
        ``ClusterSpec.remote_penalty_scale``).
      mtbf_scale: crash-rate multiplier — this class's mean time between
        failures is ``FaultConfig.crash_mtbf * mtbf_scale`` (older
        generations fail more often: ``mtbf_scale < 1``).
    """

    name: str = "base"
    weight: int = 1
    speed: float = 1.0
    fabric: float = 1.0
    mtbf_scale: float = 1.0

    def __post_init__(self) -> None:
        if self.weight < 1:
            raise ValueError("machine-class weight must be >= 1")
        if self.speed <= 0:
            raise ValueError("machine-class speed must be positive")
        if self.fabric < 0:
            raise ValueError("machine-class fabric must be non-negative")
        if self.mtbf_scale <= 0:
            raise ValueError("machine-class mtbf_scale must be positive")

    def to_dict(self) -> Dict[str, object]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "MachineClass":
        return cls(**d)


_BASE_CLASS = MachineClass()


@dataclass(frozen=True)
class FaultConfig:
    """Fault-injection + heterogeneity layer for the simulated fleet.

    Default **off** — with ``enabled=False`` every knob is inert: the
    engine is bit-exact against the frozen legacy engine (pinned by the
    parity fuzz suite, which fuzzes *disabled* configs), and the config is
    omitted from ``ClusterSpec.to_dict`` so every pre-fault sweep-cache
    hash and pair key is untouched.

    When enabled, ``ClusterSim`` drives deterministic fault processes from
    per-machine RNG streams seeded by (sim seed, machine) only — the
    crash/restart schedule is a pure function of (config, seed),
    independent of scheduler decisions (pinned by the determinism test):

    * **node churn** — each machine crashes after Exp(mtbf) up-time
      (class-scaled) and restarts after Exp(mttr) down-time; running tasks
      on its VMs are lost and re-enqueued against surviving replicas;
    * **re-replication** — a machine down longer than the grace window
      gets its pending blocks re-replicated (from the durable store) onto
      a surviving node, restoring locality after the window;
    * **straggler bursts** — correlated slowdown episodes per machine
      (every task launched on a bursting machine is slowed), instead of
      the i.i.d. per-task ``straggler_prob``;
    * **heterogeneous machine classes** — per-class duration/fabric
      multipliers threaded through ``task_duration`` and the
      reconfigurator's park break-even bar.
    """

    enabled: bool = False
    # -- node churn (0 = no crashes even when enabled) -------------------
    crash_mtbf: float = 0.0       # mean seconds of up-time per machine
    crash_mttr: float = 90.0      # mean seconds of down-time per crash
    crash_warmup: float = 0.0     # no crashes before this sim time
    # -- re-replication ---------------------------------------------------
    rereplicate_after: float = 60.0   # grace window before blocks re-home
    # -- correlated straggler bursts (0 = off) ----------------------------
    burst_rate: float = 0.0       # mean seconds between episodes per machine
    burst_duration: float = 30.0  # seconds one episode lasts
    burst_slowdown: float = 2.5   # duration multiplier while bursting
    # -- heterogeneity (() = homogeneous fleet) ---------------------------
    machine_classes: Tuple[MachineClass, ...] = ()

    def __post_init__(self) -> None:
        if self.crash_mtbf < 0:
            raise ValueError("crash_mtbf must be non-negative")
        if self.crash_mttr <= 0:
            raise ValueError("crash_mttr must be positive")
        if self.crash_warmup < 0:
            raise ValueError("crash_warmup must be non-negative")
        if self.rereplicate_after < 0:
            raise ValueError("rereplicate_after must be non-negative")
        if self.burst_rate < 0:
            raise ValueError("burst_rate must be non-negative")
        if self.burst_duration <= 0:
            raise ValueError("burst_duration must be positive")
        if self.burst_slowdown < 1.0:
            raise ValueError("burst_slowdown must be >= 1")
        if not isinstance(self.machine_classes, tuple):
            object.__setattr__(self, "machine_classes",
                               tuple(self.machine_classes))

    @property
    def active(self) -> bool:
        """Any fault process actually running (vs. enabled-but-all-off)."""
        return self.enabled and (self.crash_mtbf > 0 or self.burst_rate > 0
                                 or bool(self.machine_classes))

    def machine_class(self, machine: int) -> MachineClass:
        """Class of physical machine ``machine`` (round-robin over the
        weight-expanded class pattern); the base class when disabled or
        homogeneous."""
        if not (self.enabled and self.machine_classes):
            return _BASE_CLASS
        pattern = _class_pattern(self.machine_classes)
        return pattern[machine % len(pattern)]

    def to_dict(self) -> Dict[str, object]:
        d = asdict(self)
        d["machine_classes"] = [asdict(c) for c in self.machine_classes]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "FaultConfig":
        d = dict(d)
        d["machine_classes"] = tuple(
            MachineClass.from_dict(c) if isinstance(c, dict) else c
            for c in d.get("machine_classes", ()))
        return cls(**d)


@functools.lru_cache(maxsize=None)
def _class_pattern(classes: Tuple[MachineClass, ...]
                   ) -> Tuple[MachineClass, ...]:
    pattern: List[MachineClass] = []
    for c in classes:
        pattern.extend([c] * c.weight)
    return tuple(pattern)


@dataclass(frozen=True)
class ServiceSpec:
    """One long-lived latency-sensitive service co-located with the batch
    workload.

    Each replica pins ``vcpus`` cores on one VM (replicas are spread over
    the fleet round-robin) and receives an open-arrival request stream —
    a non-homogeneous Poisson process with the same diurnal/flash-crowd
    shape as ``repro.simcluster.traces.ArrivalConfig``, drawn from a
    dedicated per-replica RNG stream (zero draws from the decision RNG).

    Attributes:
      name: service label (also part of the RNG stream key).
      replicas: service instances; each lives on one VM.
      vcpus: cores pinned per replica (the batch side loses this much map
        capacity on the host VM; harvesting may borrow all but one back).
      base_rps: mean request arrival rate per replica (requests/second).
      diurnal_amplitude/diurnal_period/diurnal_phase: sinusoidal load
        modulation, ``rate(t) = base_rps * (1 + A sin(2 pi (t+phase)/T))``.
      burst_prob: per base arrival, chance of a flash crowd riding on it.
      burst_size_mean: mean extra requests per flash crowd (geometric).
      burst_stagger: mean spacing (s) of flash-crowd arrivals.
      service_time: mean seconds one request occupies one core (exponential).
      slo_p99_ms: per-request latency SLO; a request whose sojourn exceeds
        this counts as an SLO violation.
    """

    name: str = "svc"
    replicas: int = 2
    vcpus: int = 1
    base_rps: float = 10.0
    diurnal_amplitude: float = 0.0
    diurnal_period: float = 3600.0
    diurnal_phase: float = 0.0
    burst_prob: float = 0.0
    burst_size_mean: float = 8.0
    burst_stagger: float = 0.05
    service_time: float = 0.02
    slo_p99_ms: float = 250.0

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("service name must be non-empty")
        if self.replicas < 1:
            raise ValueError("service replicas must be >= 1")
        if self.vcpus < 1:
            raise ValueError("service vcpus must be >= 1")
        if self.base_rps <= 0:
            raise ValueError("base_rps must be positive")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1)")
        if self.diurnal_period <= 0:
            raise ValueError("diurnal_period must be positive")
        if not 0.0 <= self.burst_prob < 1.0:
            raise ValueError("burst_prob must be in [0, 1)")
        if self.burst_size_mean < 1.0:
            raise ValueError("burst_size_mean must be >= 1")
        if self.burst_stagger <= 0:
            raise ValueError("burst_stagger must be positive")
        if self.service_time <= 0:
            raise ValueError("service_time must be positive")
        if self.slo_p99_ms <= 0:
            raise ValueError("slo_p99_ms must be positive")

    def to_dict(self) -> Dict[str, object]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "ServiceSpec":
        return cls(**d)


@dataclass(frozen=True)
class ServeConfig:
    """Multi-tenant serving layer: latency-SLO services co-located with
    the batch MapReduce workload on one reconfigurable fleet.

    Default **off** — with ``enabled=False`` (or no services) the layer is
    never constructed, zero RNG draws happen, the engine stays bit-exact
    against the frozen legacy engine (the parity fuzz suite carries
    disabled-but-wild serving knobs through the sweep), and the config is
    omitted from ``ClusterSpec.to_dict`` so every sweep-cache hash and
    pair key is untouched — exactly like ``FaultConfig``/``TraceConfig``.

    When active, ``ClusterSim`` pins each replica's vcpus on its host VM
    (reducing batch map capacity there), drives per-replica request
    streams from dedicated ``f"{seed}:serve:{service}:{replica}"`` RNG
    streams, and folds per-request queueing into p50/p99 latency and
    SLO-violation counters each serve tick.  The harvest knobs govern the
    Borg-style core-harvesting component (``PolicySpec`` axis
    ``harvest``): a replica whose utilization EWMA sits below
    ``harvest_headroom`` may lend all but one pinned core to the batch
    side; cores are returned preemptively when the EWMA crosses
    ``harvest_return_util`` or the tick's p99 reaches the SLO.
    """

    enabled: bool = False
    services: Tuple[ServiceSpec, ...] = ()
    # -- harvest component knobs (inert unless the policy enables it) -----
    harvest_headroom: float = 0.55     # borrow only below this util EWMA
    harvest_return_util: float = 0.85  # return preemptively above this
    harvest_util_alpha: float = 0.3    # utilization EWMA weight
    # atlas guard: max tolerated fraction of requests over their p99 SLO
    slo_violation_bound: float = 0.02

    def __post_init__(self) -> None:
        if not 0.0 < self.harvest_headroom < 1.0:
            raise ValueError("harvest_headroom must be in (0, 1)")
        if self.harvest_return_util <= self.harvest_headroom:
            raise ValueError("harvest_return_util must be > harvest_headroom")
        if not 0.0 < self.harvest_util_alpha <= 1.0:
            raise ValueError("harvest_util_alpha must be in (0, 1]")
        if not 0.0 <= self.slo_violation_bound <= 1.0:
            raise ValueError("slo_violation_bound must be in [0, 1]")
        if not isinstance(self.services, tuple):
            object.__setattr__(self, "services", tuple(self.services))
        names = [s.name for s in self.services]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate service names: {names}")

    @property
    def active(self) -> bool:
        """Any service actually running (vs. enabled-but-empty)."""
        return self.enabled and bool(self.services)

    def to_dict(self) -> Dict[str, object]:
        d = asdict(self)
        d["services"] = [asdict(s) for s in self.services]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "ServeConfig":
        d = dict(d)
        d["services"] = tuple(
            ServiceSpec.from_dict(s) if isinstance(s, dict) else s
            for s in d.get("services", ()))
        return cls(**d)


@dataclass(frozen=True)
class TraceConfig:
    """Decision-trace bus configuration (``repro.core.tracing``).

    Default **off** — with ``enabled=False`` no bus is created, every
    emission site is a single ``is None`` guard, zero RNG draws happen,
    and the config is omitted from ``ClusterSpec.to_dict`` so every
    sweep-cache hash and pair key is untouched (the fuzz suite carries
    disabled-but-wild trace knobs through the parity sweep, exactly like
    ``AdaptiveConfig``/``FaultConfig`` before it).

    When enabled, ``ClusterSim`` wires one ``TraceBus`` through itself,
    the scheduler and the reconfigurator; the category switches select
    which record families are emitted:

    * ``launches`` — task ``launch``/``finish`` records (local/remote,
      speculative, via-reconfig) plus ``job_submit``/``job_finish`` and
      crash ``kill`` records;
    * ``parks`` — the Algorithm-1 decision trail: ``park_admit``,
      ``park_deny`` (with the failing gate named), ``park_outcome``,
      ``reconfig_match``, ``unpark``, ``park_expired``, ``park_crashed``;
    * ``overload`` — ``latch_trip``/``latch_release`` with the triggering
      counters;
    * ``faults`` — full-context twins of the ``fault_log`` entries
      (crash/restart/burst/re-replication);
    * ``pressure_every`` — seconds between cluster ``pressure`` snapshots
      (EWMAs, fail streaks, rq depth, map_open_jobs); 0 disables them.

    ``max_events`` bounds retained records (the per-kind counters keep
    counting past it; overflow is reported in ``TraceBus.dropped``).
    """

    enabled: bool = False
    launches: bool = True
    parks: bool = True
    overload: bool = True
    faults: bool = True
    # serving/harvest records: ``harvest_borrow``/``harvest_return`` (with
    # the triggering signal named) plus per-tick ``serve_tick`` snapshots
    serve: bool = True
    pressure_every: float = 0.0
    max_events: int = 1_000_000

    def __post_init__(self) -> None:
        if self.pressure_every < 0:
            raise ValueError("pressure_every must be non-negative")
        if self.max_events < 0:
            raise ValueError("max_events must be non-negative")

    def to_dict(self) -> Dict[str, object]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "TraceConfig":
        return cls(**d)


@dataclass(frozen=True)
class ClusterSpec:
    """Static shape of the virtualized cluster (paper §5: 20 machines,
    2 map + 2 reduce slots per node)."""

    num_machines: int = 20
    vms_per_machine: int = 2
    base_map_slots: int = 2        # per VM
    base_reduce_slots: int = 2     # per VM
    max_vcpus_per_vm: int = 6      # hot-plug ceiling
    min_vcpus_per_vm: int = 1      # never unplug below this
    replication: int = 3           # HDFS default
    heartbeat_interval: float = 3.0   # paper: "Usually the heartbeat interval is 3s"
    hotplug_latency: float = 0.5      # seconds for a vCPU assign/release
    # network-fabric calibration: scales every profile's remote-read penalty
    # (1.0 = the paper's 2012 shared 1GbE; ~0.25 = 10GbE; ~0.0625 = 40GbE)
    remote_penalty_scale: float = 1.0
    adaptive: AdaptiveConfig = AdaptiveConfig()
    faults: FaultConfig = FaultConfig()
    serve: ServeConfig = ServeConfig()
    tracing: TraceConfig = TraceConfig()

    @property
    def num_nodes(self) -> int:
        return self.num_machines * self.vms_per_machine

    def machine_of(self, node: int) -> int:
        return node // self.vms_per_machine

    def machine_class(self, machine: int) -> MachineClass:
        """Hardware class of physical machine ``machine`` (heterogeneous
        fleets live on ``FaultConfig``; the base class otherwise)."""
        return self.faults.machine_class(machine)

    def to_dict(self) -> Dict[str, object]:
        # asdict introspects fields: the experiment cache hashes this dict,
        # so a hand-maintained list that went stale would alias genuinely
        # different clusters onto one cache cell
        d = asdict(self)
        if self.faults == FaultConfig():
            # cache compatibility: a default (disabled) fault layer is
            # omitted so pre-fault sweep caches, pair keys and the pinned
            # cell hashes in tests/test_policies.py are byte-identical
            del d["faults"]
        else:
            d["faults"] = self.faults.to_dict()
        if self.serve == ServeConfig():
            # same contract for the serving layer: serving-off is invisible
            del d["serve"]
        else:
            d["serve"] = self.serve.to_dict()
        # tracing is a pure observer: results are bit-identical with it
        # on or off, so it is *always* omitted — a traced replay of a
        # cached cell must hash onto the same cache entry
        del d["tracing"]
        # cache compatibility for the PR-8 bugfix knobs: at their default
        # values they are omitted, so the pinned adaptive cell hashes in
        # tests/test_policies.py (and pre-existing sweep caches) keep
        # their keys — the fixed behavior is the bugfix semantics of
        # those cells, not a new cell identity.  Non-default values (e.g.
        # the surge_width=0 ablation) still hash distinctly.
        for knob in ("surge_width", "crash_discount", "ewma_gap_cap"):
            if getattr(self.adaptive, knob) == _ADAPTIVE_FIELD_DEFAULTS[knob]:
                del d["adaptive"][knob]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "ClusterSpec":
        d = dict(d)
        if isinstance(d.get("adaptive"), dict):
            d["adaptive"] = AdaptiveConfig.from_dict(d["adaptive"])
        if isinstance(d.get("faults"), dict):
            d["faults"] = FaultConfig.from_dict(d["faults"])
        if isinstance(d.get("serve"), dict):
            d["serve"] = ServeConfig.from_dict(d["serve"])
        if isinstance(d.get("tracing"), dict):
            d["tracing"] = TraceConfig.from_dict(d["tracing"])
        return cls(**d)


def ceil_at_least_one(x: float) -> int:
    """Ceil to int, but always demand at least one slot."""
    if not math.isfinite(x) or x <= 0:
        return 1
    return max(1, int(math.ceil(x - 1e-9)))
