"""Completion-time based scheduler — paper §4.2, Algorithm 2 (+ Algorithm 1
for map-task assignment through resource reconfiguration).

Policy, exactly as the paper states it:

* jobs with no completed or running tasks take precedence (oldest first) so
  the online estimator can bootstrap (initial tasks give the Eq.-1 sample);
* remaining jobs are sorted by EDF (ascending deadline);
* a job only receives map slots while ``scheduled_maps < n_m`` and reduce
  slots while ``scheduled_reduces < n_r`` (Eq. 10 demand, recomputed on every
  task completion with remaining work and remaining time);
* reduces launch only after the job's map phase finishes (Algorithm 2 l.10);
* map assignment prefers a data-local task on the heartbeating node; a
  non-local candidate is parked for VM reconfiguration on a node that holds
  its data (Algorithm 1): AQ entry on the data node's machine, RQ entry on
  the heartbeating node's machine.

Implementation note — incremental indices.  Per-heartbeat work is
O(active work at this node), not O(jobs × tasks): the per-job pending sets
and the per-node ``node -> pending local map ids`` inverted index live on
``JobRuntime`` (see ``core/types.py``); this module adds the cross-job
aggregates (per-node local-pending counters, maintained EDF order, global
pending-work counters, per-job parked counts).  Decision order is identical
to the seed implementation — pinned by ``tests/test_parity.py`` against the
frozen engine in ``repro.simcluster._legacy``.
"""
from __future__ import annotations

import bisect
import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from repro.core.estimator import OnlineEstimator
from repro.core.reconfigurator import Reconfigurator
from repro.core.types import (ClusterSpec, JobRuntime, JobSpec, TaskId,
                              TaskKind)


@dataclass
class Launch:
    """Scheduler decision: run task on node (immediately)."""
    task: TaskId
    node: int
    local: bool
    via_reconfig: bool = False


class SchedulerBase:
    """Common bookkeeping shared by all scheduler policies.

    Maintains, incrementally across task lifecycle transitions:

    * ``active`` — unfinished jobs in submission order (dict removal keeps
      ``active_jobs()`` O(active), not O(all jobs ever));
    * ``local_pending_count[node]`` — how many (job, map task) pending pairs
      have a replica on ``node``, so ``has_local_pending`` is O(1);
    * ``total_pending_maps`` / ``ready_pending_reduces`` — global counters
      that let ``select`` return immediately when the offered slots cannot
      possibly be used (idle-heartbeat churn fix).
    """

    name = "base"
    uses_reconfig = False
    # set by PolicySpec.build: the spec this instance was constructed from
    policy = None
    # harvest policy component (repro.core.policies axis "harvest"): when
    # True and ServeConfig is active, the serving layer borrows idle
    # service cores for the batch side (repro.simcluster.serving).  Set by
    # harvest-policy builders; read-only for the engine, so non-harvest
    # policies are untouched.
    harvest = False
    # decision-trace bus (repro.core.tracing.TraceBus); attached by the
    # simulator when ClusterSpec.tracing is enabled, None otherwise.  Every
    # emission site is behind a single `is None` guard and draws from no
    # RNG, so tracing-off is bit-exact and tracing-on changes no decision.
    trace = None

    @classmethod
    def from_policy(cls, policy, spec: ClusterSpec):
        """Construct a scheduler from a policy value (a ``PolicySpec``, a
        registered name, or policy JSON/dict) — see ``repro.core.policies``."""
        from repro.core.policies import build_policy
        return build_policy(policy, spec)

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        self.jobs: Dict[str, JobRuntime] = {}
        self.order: List[str] = []          # submission order
        self.active: Dict[str, JobRuntime] = {}   # insertion == submission
        # active jobs that have no completed or running task yet (paper
        # Algorithm 2 bootstrap precedence), in submission order
        self.bootstrap: Dict[str, JobRuntime] = {}
        self.local_pending_count: List[int] = [0] * spec.num_nodes
        self.total_pending_maps = 0
        self.ready_pending_reduces = 0
        # active jobs whose map phase is still open — with
        # total_pending_maps this gives the backlog's mean job width
        # (the adaptive park-admission signal), maintained at the same
        # transitions as the map_done flag
        self.map_open_jobs = 0
        # fault integration (FaultConfig): nodes currently crashed.  Always
        # empty when faults are off, so every guard on it is parity-inert.
        self.down_nodes: Set[int] = set()

    # -- lifecycle ----------------------------------------------------------
    def job_added(self, job: JobSpec, now: float) -> None:
        rt = JobRuntime(spec=job, seq=len(self.order))
        self.jobs[job.job_id] = rt
        self.order.append(job.job_id)
        self.active[job.job_id] = rt
        self.bootstrap[job.job_id] = rt
        self.total_pending_maps += job.u_m
        self.map_open_jobs += 1
        counts = self.local_pending_count
        for placement in job.block_placement[:job.u_m]:
            for node in set(placement):
                counts[node] += 1
        self.on_job_added(rt, now)

    def on_job_added(self, job: JobRuntime, now: float) -> None:
        pass

    def task_started(self, task: TaskId, node: int, now: float) -> None:
        job = self.jobs[task.job_id]
        if task.kind == TaskKind.MAP:
            self._start_map(job, task.index, node)
        else:
            self._start_reduce(job, task.index, node)

    def task_finished(self, task: TaskId, node: int, now: float,
                      duration: float) -> None:
        job = self.jobs[task.job_id]
        if task.kind == TaskKind.MAP:
            job.running_map.pop(task.index, None)
            self._drop_pending_map(job, task.index)   # defensive: no-op if started
            job.completed_map.add(task.index)
            job.map_durations.append(duration)
            job.map_duration_sum += duration
            if not job.map_done and job.map_finished:
                job.map_done = True
                self.map_open_jobs -= 1
                # reduces become schedulable the moment the map phase ends
                self.ready_pending_reduces += len(job.pending_reduce)
        else:
            job.running_reduce.pop(task.index, None)
            if task.index in job.pending_reduce:      # defensive
                job.pending_reduce.discard(task.index)
                if job.map_done:
                    self.ready_pending_reduces -= 1
            job.completed_reduce.add(task.index)
            job.reduce_durations.append(duration)
            job.reduce_duration_sum += duration
        if not job.all_done and job.finished:
            job.all_done = True
            if job.finish_time is None:
                job.finish_time = now
            self.active.pop(job.spec.job_id, None)
            self._job_deactivated(job)
        self.on_task_finished(job, task, now)

    def _job_deactivated(self, job: JobRuntime) -> None:
        pass

    def on_task_finished(self, job: JobRuntime, task: TaskId, now: float) -> None:
        pass

    # -- fault integration (FaultConfig; never called when faults are off) --
    def node_down(self, nodes: List[int], now: float) -> None:
        """Simulator hook: these nodes just crashed.  Down nodes stop
        heartbeating (so ``select`` is never offered their slots) and are
        excluded as park targets until they restart."""
        self.down_nodes.update(nodes)
        self.on_nodes_down(nodes, now)

    def node_up(self, nodes: List[int], now: float) -> None:
        self.down_nodes.difference_update(nodes)
        self.on_nodes_up(nodes, now)

    def on_nodes_down(self, nodes: List[int], now: float) -> None:
        pass

    def on_nodes_up(self, nodes: List[int], now: float) -> None:
        pass

    def task_lost(self, task: TaskId, node: int, now: float) -> None:
        """A node crash killed this *running* task: make it schedulable
        again.  The exact inverse of the start transition — restores the
        pending sets, the lazy heaps (popped entries never resurface on
        their own, so the index is pushed back), ``total_pending_maps``,
        the per-node local counters, and the bootstrap precedence set when
        a job loses every task it ever ran.  ``map_open_jobs`` needs no
        recount: a running map implies the phase was still open, and a
        lost reduce cannot reopen a finished map phase."""
        job = self.jobs[task.job_id]
        if task.kind == TaskKind.MAP:
            if job.running_map.pop(task.index, None) is None:
                return                       # already resolved (twin finished)
            if task.index in job.completed_map or task.index in job.pending_map:
                return
            self._repend_map(job, task.index)
        else:
            if job.running_reduce.pop(task.index, None) is None:
                return
            if (task.index in job.completed_reduce
                    or task.index in job.pending_reduce):
                return
            job.pending_reduce.add(task.index)
            heapq.heappush(job._pending_reduce_heap, task.index)
            if job.map_done:
                self.ready_pending_reduces += 1
        if job.has_progress and not job.started:
            # the job lost every task it ever ran: it needs a bootstrap
            # probe again (Algorithm 2's precedence set) so the estimator
            # can re-seed — it re-enters at the back of the set, which only
            # reorders against other re-bootstrapped jobs
            job.has_progress = False
            self.bootstrap[job.spec.job_id] = job
        self.on_task_lost(job, task, now)

    def _repend_map(self, job: JobRuntime, idx: int) -> None:
        """Inverse of ``_drop_pending_map`` + the heap pops it implies."""
        job.pending_map.add(idx)
        heapq.heappush(job._pending_map_heap, idx)
        self.total_pending_maps += 1
        placement = job.spec.block_placement
        if idx < len(placement):
            counts = self.local_pending_count
            for node in set(placement[idx]):
                counts[node] += 1
                heapq.heappush(job._local_heaps.setdefault(node, []), idx)

    def on_task_lost(self, job: JobRuntime, task: TaskId, now: float) -> None:
        pass

    def parked_task_crashed(self, task: TaskId, now: float) -> None:
        """The machine holding this task's AQ entry (or in-flight plug)
        crashed; the task is still pending and simply re-enters normal
        scheduling."""
        pass

    # -- indexed transitions -------------------------------------------------
    def _drop_pending_map(self, job: JobRuntime, idx: int) -> bool:
        """Remove idx from the job's pending set + per-node counters."""
        if idx not in job.pending_map:
            return False
        job.pending_map.discard(idx)
        self.total_pending_maps -= 1
        placement = job.spec.block_placement
        if idx < len(placement):
            counts = self.local_pending_count
            for node in set(placement[idx]):
                counts[node] -= 1
        return True

    def _start_map(self, job: JobRuntime, idx: int, node: int) -> None:
        job.running_map[idx] = node
        self._drop_pending_map(job, idx)
        if not job.has_progress:
            job.has_progress = True
            self.bootstrap.pop(job.spec.job_id, None)

    def _start_reduce(self, job: JobRuntime, idx: int, node: int) -> None:
        job.running_reduce[idx] = node
        if not job.has_progress:
            job.has_progress = True
            self.bootstrap.pop(job.spec.job_id, None)
        if idx in job.pending_reduce:
            job.pending_reduce.discard(idx)
            if job.map_done:
                self.ready_pending_reduces -= 1

    # -- helpers --------------------------------------------------------------
    def _unstarted_map_tasks(self, job: JobRuntime) -> List[int]:
        """Full unstarted list — O(pending); kept for tests/introspection.
        Hot paths use the first_pending_* index queries instead."""
        return sorted(job.pending_map)

    def _unstarted_reduce_tasks(self, job: JobRuntime) -> List[int]:
        return sorted(job.pending_reduce)

    def _local_map_candidates(self, job: JobRuntime, node: int) -> List[int]:
        return sorted(i for i in job.pending_map
                      if node in job.spec.block_placement[i])

    def active_jobs(self) -> List[JobRuntime]:
        return list(self.active.values())

    def has_active_jobs(self) -> bool:
        return bool(self.active)

    def has_local_pending(self, vm: int) -> bool:
        """Does any active job still have an unstarted map task whose data
        lives on ``vm``?  O(1) via the per-node pending counters."""
        return self.local_pending_count[vm] > 0

    # subclasses implement:
    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        raise NotImplementedError


class CompletionTimeScheduler(SchedulerBase):
    """The paper's proposed scheduler (Algorithm 2 + Algorithm 1)."""

    name = "proposed"
    uses_reconfig = True

    #: overload-policy vocabulary (the policy registry's ``overload`` axis):
    #: ``none`` never enters the latch, ``latch`` is the sticky-until-drain
    #: detector, ``reduce_aware`` keys the latch on map-side pressure only
    OVERLOAD_POLICIES = ("none", "latch", "reduce_aware")

    def __init__(self, spec: ClusterSpec, reconfig: Optional[Reconfigurator] = None,
                 estimator: Optional[OnlineEstimator] = None, *,
                 park_depth: int = 2, parking: bool = True,
                 overload: str = "latch"):
        super().__init__(spec)
        if overload not in self.OVERLOAD_POLICIES:
            raise ValueError(f"unknown overload policy {overload!r}; "
                             f"one of {self.OVERLOAD_POLICIES}")
        self.reconfig = reconfig or Reconfigurator(spec)
        self.estimator = estimator or OnlineEstimator()
        self.adaptive = self.reconfig.adaptive
        self.overload_policy = overload
        # park-admission switch: False = the edf_nopark ablation — every
        # non-local candidate launches remotely at once, and the simulator
        # skips the reconfigurator integration entirely (static capacity)
        self.parking = parking
        if not parking:
            self.uses_reconfig = False      # instance attr shadows the class
        self.parked: Set[TaskId] = set()
        self._parked_maps_per_job: Dict[str, int] = {}
        # tasks whose reconfiguration wait expired once run remotely instead
        # of re-parking (bounds per-task wait at max_wait)
        self.no_park: Set[TaskId] = set()
        # max parked tasks per target machine's AQ
        self.park_depth = park_depth
        self.max_slots = spec.num_nodes * spec.base_map_slots
        # adaptive overload detection: active jobs whose absolute deadline
        # has passed (completion-time goal lost), materialized lazily from a
        # deadline min-heap as the clock advances — O(1) amortized per job
        self.overdue: Set[str] = set()
        self._overdue_heap: List[Tuple[float, int, str]] = []
        # hysteresis latch: overload mode persists through the drain until
        # the map backlog genuinely clears (a surge's damage is done in its
        # tail, which sits below any instantaneous entry threshold)
        self.overload_mode = False
        # active jobs ordered by (absolute deadline, admission seq): the
        # admission tiebreak reproduces the seed's stable sort exactly;
        # _edf_jobs mirrors _edf with the JobRuntime objects so select
        # iterates without rebuilding a list
        self._edf: List[Tuple[float, int, str]] = []
        self._edf_jobs: List[JobRuntime] = []
        # fault integration: crashed machines, maintained by on_nodes_down/
        # on_nodes_up so the overload latch prices pressure against the
        # *effective* capacity (0 whenever faults are off)
        self._machines_down = 0
        # re-pend debt: map tasks a crash threw back into the pending sets
        # and that have not been rescheduled yet.  The latch already priced
        # this work in the first time around — counting it again makes
        # churn read as a fresh overload surge while the crash is also
        # *lowering* the trip bars (slots/machines shrink with the fleet).
        # Only populated when adaptive.enabled and crash_discount are on,
        # so every other configuration keeps the set empty for free.
        self._repend_debt: Set[TaskId] = set()
        # relief latch: once _churn_relief sees a live churn signal it
        # stays true for the rest of the run — the locality the crashes
        # destroyed never fully recovers, so the gates stay stood down.
        # A fleet *configured* crash-prone arms it from t=0: the prologue
        # before the first crash already runs on borrowed locality, and
        # parks denied there are wins surrendered once the churn starts.
        self._relief_sticky = (
            self.adaptive.enabled and self.adaptive.crash_discount
            and spec.faults.enabled and spec.faults.crash_mtbf > 0.0)

    # -- Algorithm 2 line 2 + lines 17-20 ----------------------------------
    def on_job_added(self, job: JobRuntime, now: float) -> None:
        entry = (job.absolute_deadline, job.seq, job.spec.job_id)
        i = bisect.bisect_left(self._edf, entry)
        self._edf.insert(i, entry)
        self._edf_jobs.insert(i, job)
        if self.adaptive.enabled:
            heapq.heappush(self._overdue_heap, entry)
            if self.overload_mode and len(self.active) == 1:
                # this job found a fully-drained cluster (select never runs
                # while idle, so the latch cannot observe the drain itself):
                # the pressured epoch ended — release the overload latch
                self.overload_mode = False
                if self.trace is not None and self.trace.overload:
                    self.trace.emit(now, "latch_release",
                                    {"cause": "empty_cluster",
                                     "job": job.spec.job_id})
        self._recompute_demand(job, now)

    def _job_deactivated(self, job: JobRuntime) -> None:
        entry = (job.absolute_deadline, job.seq, job.spec.job_id)
        i = bisect.bisect_left(self._edf, entry)
        if i < len(self._edf) and self._edf[i] == entry:
            del self._edf[i]
            del self._edf_jobs[i]
        self.overdue.discard(job.spec.job_id)

    def _sync_overdue(self, now: float) -> None:
        """Move newly-overdue jobs off the deadline heap into ``overdue``
        (jobs that already finished are skipped — deactivation removed them
        from ``active`` and keeps them out of ``overdue``)."""
        heap = self._overdue_heap
        while heap and heap[0][0] < now:
            _, _, jid = heapq.heappop(heap)
            if jid in self.active:
                self.overdue.add(jid)

    def _wide_batch(self, pending: int) -> bool:
        """True when the queued map backlog averages at least
        ``AdaptiveConfig.surge_width`` pending maps per map-open job — a
        *healthy wide batch* (the paper's closed-mix regime at saturation,
        or churn re-pending lost work), not the many-small-jobs surge the
        overload latch exists for.  Measured at the latch trip on the
        regime atlas: saturated/50x2 and 100x2 sit at ~28 pending maps per
        open job, while the diurnal / bursty / churn surges the latch
        correctly catches sit at 3-5 (and never exceed ~14 while held).
        ``surge_width == 0`` disables the signal (pre-PR-8 behavior)."""
        a = self.adaptive
        return (a.surge_width > 0.0 and self.map_open_jobs > 0
                and pending >= a.surge_width * self.map_open_jobs)

    def _churn_relief(self, now: float) -> bool:
        """True once the cluster has churned: a machine is down, a
        crash-lost map is still waiting to reschedule (_repend_debt), or
        either has already happened this run (sticky: the locality damage
        from a crash outlives the repair — replicas come back on *other*
        machines — so there is no point the gates' calibration becomes
        trustworthy again).  Churn is the fixed policy's best regime — re-replication
        starves locality, so parked maps win big — and the adaptive
        signals' worst misread: re-pended lost work inflates ``pending``
        exactly while the crash lowers the trip bars (slots/machines track
        the surviving fleet), crashed donors read as core starvation, and
        the between-crash gap windows still run on locality the churn
        already destroyed.  While this holds, the latch stands down and
        park admission reverts to the fixed policy's gates.  Off with
        ``crash_discount`` (the pre-PR-8 churn behavior), and always False
        when faults are off."""
        if not self.adaptive.crash_discount:
            return False
        if self._machines_down > 0 or self._repend_debt:
            self._relief_sticky = True
            return True
        return self._relief_sticky

    def _overload_check(self, now: float) -> bool:
        """Latching overload detector over the incremental pressure state.

        Enter when the queued map backlog exceeds the entry fraction of
        cluster slots *and* active jobs outnumber the entry fraction of
        machines (many small jobs squeezed through shares far below their
        width — the Fair regime); leave only once the cluster has fully
        drained (hysteresis: the makespan damage of a surge happens in its
        drain tail, which sits below any instantaneous entry threshold).
        The ``overdue`` set (active jobs past their deadline) is kept in
        sync here as an observable signal.

        ``reduce_aware`` variant (the ``adaptive_ra`` policy): the latch is
        a *map-side* pressure response — parking and EDF slot allocation
        only shape the map phase — so the crowd bar counts **map-open**
        jobs rather than all active jobs (a fleet of long reduce tails is
        not an overload), and the latch releases as soon as the map
        backlog drains instead of waiting for the full cluster drain
        (shuffle-heavy mixes hold reduce backlogs for most of the run,
        which kept the plain latch stuck and parking suspended — the
        shuffle_heavy/20x2 −3.7% regression)."""
        self._sync_overdue(now)
        a = self.adaptive
        pending = self.total_pending_maps
        reduce_aware = self.overload_policy == "reduce_aware"
        # effective capacity: crashed nodes serve nothing, so the latch
        # prices pressure against the surviving fleet (identical values —
        # and floats — to the static bars while no node is down)
        slots = self.max_slots - len(self.down_nodes) * self.spec.base_map_slots
        machines = self.spec.num_machines - self._machines_down
        if self.overload_mode:
            # the plain latch stays until the cluster fully drains; select
            # never runs while idle, so the actual release happens when the
            # next job finds an empty cluster (see on_job_added).  The
            # reduce-aware latch releases on map-backlog drain.
            if not self.active or (reduce_aware and self.map_open_jobs == 0):
                self.overload_mode = False
                if self.trace is not None and self.trace.overload:
                    self.trace.emit(now, "latch_release", {
                        "cause": ("cluster_drained" if not self.active
                                  else "maps_drained"),
                        "pending_maps": pending,
                        "active_jobs": len(self.active)})
            elif (self.spec.faults.enabled and self.spec.faults.crash_mtbf > 0
                    and pending == 0 and self.ready_pending_reduces == 0):
                # under churn the "next job finds an empty cluster" release
                # may never fire (crashes keep re-pending work, stretching
                # the drain past the arrival horizon) — an empty backlog is
                # the epoch's true end, so the latch must not wedge there.
                # Gated on the crash process, not just `enabled`: a config
                # with no crash source cannot wedge, and stays bit-exact
                # with the faults-off latch semantics
                self.overload_mode = False
                if self.trace is not None and self.trace.overload:
                    self.trace.emit(now, "latch_release", {
                        "cause": "churn_drain",
                        "active_jobs": len(self.active)})
            elif self._churn_relief(now):
                # see _churn_relief: mid-churn the latch stands down
                self.overload_mode = False
                if self.trace is not None and self.trace.overload:
                    self.trace.emit(now, "latch_release", {
                        "cause": "churn_relief",
                        "machines_down": self._machines_down,
                        "repend_debt": len(self._repend_debt),
                        "pending_maps": pending,
                        "active_jobs": len(self.active)})
            elif (self._wide_batch(pending)
                    and self.reconfig.park_outcome_ewma >= a.park_win_floor):
                # win-aware release: the backlog evolved into a wide batch
                # (churn re-pending lost work is the canonical path) —
                # exact-Fair surrenders the parking win there, so the
                # latch opens back into EDF + parking.  Vetoed while the
                # park win-rate EWMA sits under the suspension floor:
                # releasing into parking that demonstrably loses would
                # just thrash (the width signal also gates the trip, so a
                # release cannot immediately re-trip).
                self.overload_mode = False
                if self.trace is not None and self.trace.overload:
                    self.trace.emit(now, "latch_release", {
                        "cause": "win_release",
                        "pending_maps": pending,
                        "map_open_jobs": self.map_open_jobs,
                        "surge_width": a.surge_width,
                        "ewma": self.reconfig.park_outcome_ewma})
        elif self.active:
            # both conditions strictly: a backlogged cluster with few wide
            # jobs (the paper's closed mix) is EDF's home regime — only the
            # many-small-jobs crowd flips the economics
            crowd = self.map_open_jobs if reduce_aware else len(self.active)
            if (pending >= a.overload_pending_factor * slots
                    and crowd >= a.overload_active_factor * machines
                    and not self._wide_batch(pending)
                    and not self._churn_relief(now)):
                self.overload_mode = True
                if self.trace is not None and self.trace.overload:
                    self.trace.emit(now, "latch_trip", {
                        "pending_maps": pending, "crowd": crowd,
                        "pending_bar": a.overload_pending_factor * slots,
                        "crowd_bar": a.overload_active_factor * machines,
                        "slots": slots, "machines": machines,
                        "active_jobs": len(self.active),
                        "map_open_jobs": self.map_open_jobs,
                        "surge_width": a.surge_width,
                        "repend_debt": len(self._repend_debt),
                        "overdue": len(self.overdue)})
        return self.overload_mode

    def on_task_finished(self, job: JobRuntime, task: TaskId, now: float) -> None:
        self._recompute_demand(job, now)

    def on_task_lost(self, job: JobRuntime, task: TaskId, now: float) -> None:
        # remaining work grew: the Eq.-10 demand must see it immediately
        self._recompute_demand(job, now)
        if (task.kind == TaskKind.MAP and self.adaptive.enabled
                and self.adaptive.crash_discount):
            self._repend_debt.add(task)

    def _drop_pending_map(self, job: JobRuntime, idx: int) -> bool:
        # a debted map leaving the pending set (rescheduled, or its
        # speculative twin finished first) settles its re-pend debt
        if self._repend_debt:
            self._repend_debt.discard(
                TaskId(job.spec.job_id, TaskKind.MAP, idx))
        return super()._drop_pending_map(job, idx)

    def parked_task_crashed(self, task: TaskId, now: float) -> None:
        self._unpark(task)

    def on_nodes_down(self, nodes: List[int], now: float) -> None:
        self._machines_down = len(
            {self.spec.machine_of(v) for v in self.down_nodes})

    def on_nodes_up(self, nodes: List[int], now: float) -> None:
        self._machines_down = len(
            {self.spec.machine_of(v) for v in self.down_nodes})

    def _recompute_demand(self, job: JobRuntime, now: float) -> None:
        job.demand = self.estimator.demand(
            job, now, max_map_slots=self.max_slots,
            max_reduce_slots=self.max_slots)

    # -- scheduled counts include parked tasks ------------------------------
    def _scheduled_maps(self, job: JobRuntime) -> int:
        return (len(job.running_map)
                + self._parked_maps_per_job.get(job.spec.job_id, 0))

    # -- Algorithm 2 main loop ----------------------------------------------
    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        # Nothing this node could possibly run or park -> O(1) heartbeat.
        # The parked check keeps the remote_fill donation pass reachable: a
        # parked task that also launched through the local path leaves an AQ
        # entry behind with no pending work, and the seed still donates idle
        # cores toward it.
        if ((free_map <= 0 or (self.total_pending_maps == 0
                               and not self.parked))
                and (free_reduce <= 0 or self.ready_pending_reduces == 0)):
            return []
        if (self.adaptive.enabled and self.overload_policy != "none"
                and self._overload_check(now)):
            # pressured epoch: EDF-ordered allocation starves late-deadline
            # jobs and serializes the drain — degenerate to the exact Fair
            # assignment (parking suspended) until the cluster fully drains
            return self._select_overloaded(node, free_map, free_reduce, now)
        out: List[Launch] = []
        # bootstrap jobs first (no completed or running tasks), oldest first;
        # then EDF ascending absolute deadline — both maintained
        # incrementally, and iterated lazily so an early slot exhaustion
        # stops the scan
        edf_jobs = self._edf_jobs
        for phase in ("demand", "backfill", "remote_fill"):
            if free_map <= 0 and free_reduce <= 0:
                break       # later phases cannot launch or donate anything
            # Pass 1 "demand": Eq.-10 minimum demands, bootstrap jobs first
            #   (probe tasks), then EDF (Algorithm 2).  Non-local map
            #   candidates are parked for reconfiguration (Algorithm 1).
            # Pass 2 "backfill": work-conserving — the abstract's "maximize
            #   the use of resources among the active jobs": leftover slots
            #   go to jobs beyond their minimum in EDF order, still parking
            #   non-local candidates.
            # Pass 3 "remote_fill": any core still idle takes a remote task
            #   (last resort — patient parking must never idle the cluster).
            if phase == "demand" and self.bootstrap:
                # snapshot: a bootstrap job that launches its probe task
                # mid-phase must not be revisited in EDF position
                ordered = (list(self.bootstrap.values())
                           + [j for j in edf_jobs if j.has_progress])
            else:
                # no bootstrap jobs -> every active job has progress, and
                # the EDF list is exactly the seed's stable-sorted order
                ordered = edf_jobs
            if phase == "remote_fill":
                # Before burning idle cores on *remote* tasks, donate them to
                # parked *local* tasks waiting on this machine's AQ — a local
                # task on the sibling VM is strictly faster than a remote one
                # here (this is what makes Algorithm 1 pay off: the donor
                # core must not be re-occupied by remote work).
                free_map = self._donate_idle_cores(node, free_map, now)
            for job in ordered:
                if free_map <= 0 and free_reduce <= 0:
                    break
                demand = job.demand
                n_m = demand.n_m if demand else 1   # bootstrap: one probe task
                n_r = demand.n_r if demand else 1
                if phase != "demand":
                    n_m, n_r = job.spec.u_m, job.spec.v_r
                if not job.map_done:
                    parked_count = self._parked_maps_per_job
                    while free_map > 0 and (
                            len(job.running_map)
                            + parked_count.get(job.spec.job_id, 0)) < n_m:
                        launch = self._assign_map(
                            job, node, now, allow_park=(phase != "remote_fill"))
                        if launch is None:
                            break
                        if launch.via_reconfig:
                            # task parked on AQ; node's core is only *offered*
                            # (RQ) — it keeps serving until the match actually
                            # unplugs it, so the slot stays schedulable now
                            pass
                        else:
                            self._launch_map(job, launch, out, now)
                            free_map -= 1
                elif not job.all_done:
                    while (free_reduce > 0 and job.pending_reduce
                           and len(job.running_reduce) < n_r):
                        idx = job.first_pending_reduce()
                        t = TaskId(job.spec.job_id, TaskKind.REDUCE, idx)
                        out.append(Launch(t, node, local=True))
                        self._start_reduce(job, idx, node)
                        free_reduce -= 1
        return out

    def _launch_map(self, job: JobRuntime, launch: Launch,
                    out: List[Launch], now: float) -> None:
        """Commit a (non-parked) map launch + adaptive outcome feedback: a
        task that parked earlier (still-queued reservation or expired) just
        resolved — data-locally (the park paid) or remotely (it didn't)."""
        out.append(launch)
        self._start_map(job, launch.task.index, launch.node)
        if launch.local:
            job.local_map_launches += 1
        else:
            job.remote_map_launches += 1
        if self.adaptive.enabled:
            task = launch.task
            if task in self.parked or task in self.no_park:
                if (not launch.local and self.adaptive.crash_discount
                        and self.down_nodes
                        and all(v in self.down_nodes
                                for v in job.spec.block_placement[
                                    task.index])):
                    # the park lost to the crash, not to core starvation:
                    # every live replica of its data is down, so the
                    # remote launch was forced — resolve the park without
                    # charging the fail-streak / win-rate gates
                    self.reconfig.discard_park_outcome(task, now)
                else:
                    self.reconfig.note_park_outcome(task, now,
                                                    won=launch.local)

    # -- adaptive overload mode (AdaptiveConfig, off by default) --------------

    def _select_overloaded(self, node: int, free_map: int, free_reduce: int,
                           now: float) -> List[Launch]:
        """Latched-overload variant of ``select``: pure deficit round-robin
        (the Fair regime).  Many small jobs squeezed through shares far
        below their width is exactly where EDF-ordered allocation only
        picks arbitrary winners, starves late-deadline jobs and serializes
        the drain; new jobs have zero deficit, so the bootstrap-probe
        precedence emerges on its own.  Parking is suspended here
        (``_assign_map`` checks ``overload_mode``) — measured, even
        live-offer parks queue behind stale offers under saturation."""
        out: List[Launch] = []
        free_map, free_reduce = self._fair_backfill(node, free_map,
                                                    free_reduce, now, out)
        # donate still-idle cores to parked tasks waiting on this machine
        # (same donation rule as the legacy remote_fill pass)
        self._donate_idle_cores(node, free_map, now)
        return out

    def _donate_idle_cores(self, node: int, free_map: int,
                           now: float) -> int:
        """Offer idle cores on ``node`` toward parked tasks waiting on its
        machine's AQ (one offer per sibling-targeted entry, never below the
        vCPU minimum); returns the remaining free slots."""
        m = self.spec.machine_of(node)
        pending = sum(1 for p in self.reconfig.aq[m] if p.target_vm != node)
        while (free_map > 0 and pending > 0
               and self.reconfig.vcpus[node] > self.spec.min_vcpus_per_vm):
            self.reconfig.release_core(node, now)
            free_map -= 1
            pending -= 1
        return free_map

    def _fair_backfill(self, node: int, free_map: int, free_reduce: int,
                       now: float, out: List[Launch]) -> Tuple[int, int]:
        """Deficit round-robin over active jobs (the Fair baseline's loop),
        with map candidates resolved through ``_assign_map`` — under the
        overload latch (the only current caller) that means local-first
        then immediate remote, parking bypassed.  The ``via_reconfig``
        rotation below is defensive: if a future caller runs this loop
        with parking admitted, a job that just parked rotates to the back
        (commitment counts include parked maps) instead of re-parking."""
        jobs = list(self.active.values())
        if not jobs:
            return free_map, free_reduce
        by_seq = {j.seq: j for j in jobs}
        parked_count = self._parked_maps_per_job

        def commit(job: JobRuntime) -> int:
            return (len(job.running_map) + len(job.running_reduce)
                    + parked_count.get(job.spec.job_id, 0))

        entries = sorted((commit(j), j.spec.submit_time, j.seq) for j in jobs)
        while free_map > 0 or free_reduce > 0:
            served: Optional[int] = None
            for pos, (_, _, seq) in enumerate(entries):
                job = by_seq[seq]
                if free_map > 0 and not job.map_done:
                    launch = self._assign_map(job, node, now)
                    if launch is None:
                        continue        # nothing launchable for this job now
                    if launch.via_reconfig:
                        served = pos    # parked: slot stays offered, rotate
                        break
                    self._launch_map(job, launch, out, now)
                    free_map -= 1
                    served = pos
                    break
                if (free_reduce > 0 and job.map_done and not job.all_done
                        and job.pending_reduce):
                    idx = job.first_pending_reduce()
                    t = TaskId(job.spec.job_id, TaskKind.REDUCE, idx)
                    out.append(Launch(t, node, local=True))
                    self._start_reduce(job, idx, node)
                    free_reduce -= 1
                    served = pos
                    break
            if served is None:
                break
            _, _, seq = entries.pop(served)
            job = by_seq[seq]
            bisect.insort(entries, (commit(job), job.spec.submit_time, seq))
        return free_map, free_reduce

    # -- Algorithm 1 -----------------------------------------------------------
    def _first_pending_not_parked(self, job: JobRuntime) -> Optional[int]:
        """Smallest pending map index whose TaskId is not parked.  Parked
        tasks stay pending (they may expire back), so they cannot be lazily
        evicted from the heap — pop them aside and push back."""
        jid = job.spec.job_id
        if not self._parked_maps_per_job.get(jid):
            return job.first_pending_map()   # nothing parked: plain peek
        heap, pend = job._pending_map_heap, job.pending_map
        skipped: List[int] = []
        idx: Optional[int] = None
        while heap:
            top = heap[0]
            if top not in pend:
                heapq.heappop(heap)
                continue
            if TaskId(jid, TaskKind.MAP, top) in self.parked:
                skipped.append(heapq.heappop(heap))
                continue
            idx = top
            break
        for s in skipped:
            heapq.heappush(heap, s)
        return idx

    def _assign_map(self, job: JobRuntime, node: int, now: float,
                    allow_park: bool = True) -> Optional[Launch]:
        local_idx = job.first_local_pending_map(node)
        if local_idx is not None:
            return Launch(TaskId(job.spec.job_id, TaskKind.MAP, local_idx),
                          node, local=True)
        idx = self._first_pending_not_parked(job)
        if idx is None:
            return None
        task = TaskId(job.spec.job_id, TaskKind.MAP, idx)
        placement = job.spec.block_placement[idx]
        slack = job.absolute_deadline - now
        # Deadline-critical or once-expired tasks run remotely right away;
        # everything else prefers parking (Algorithm 1), falling through to
        # the remote-fill pass only when the AQ is saturated.
        deadline_critical = slack <= 3.0 * self.reconfig.max_wait
        if (not self.parking or task in self.no_park or deadline_critical
                or not allow_park):
            if self.trace is not None and self.trace.parks:
                self._trace_deny(now, task, node,
                                 "parking_off" if not self.parking
                                 else "no_park" if task in self.no_park
                                 else "deadline_critical" if deadline_critical
                                 else "remote_fill",
                                 slack=slack)
            return Launch(task, node, local=False)
        adaptive = self.reconfig.adaptive
        # the crowd bar: under the reduce-aware overload policy only
        # map-open jobs count — jobs riding out long reduce tails do not
        # compete for map slots, so they must not suppress parking
        # (measured on shuffle_heavy/20x2: the all-active crowd bar kept
        # parking shut for the whole run, locality 50% -> 17%; letting the
        # park-outcome EWMA override the crowd instead was measured worse —
        # reservation-effect "wins" still cost throughput under saturation)
        crowd = (self.map_open_jobs if self.overload_policy == "reduce_aware"
                 else len(self.active))
        if adaptive.enabled and (
                self.overload_mode
                or (crowd >= adaptive.park_active_factor
                    * (self.spec.num_machines - self._machines_down)
                    and not self._wide_batch(self.total_pending_maps)
                    and not self._churn_relief(now))):
            # Overload latch or a crowd of active jobs: per-job shares sit
            # far below job widths, every parked map lands on its job's
            # phase-critical path, and even live-offer parks queue behind
            # stale offers under pressure (measured) — no park beats
            # starting remotely right now, so both parking paths (S_rq and
            # S_aq) are bypassed.  Two crowds are exempt: a crowd of *wide*
            # jobs (the saturated closed mix: _wide_batch), where every job
            # has plenty of sibling maps to absorb a park's wait, and a
            # churning fleet (_churn_relief), where re-replication is
            # starving locality and parking is how the fixed policy wins —
            # both are exactly where parking pays; the latch
            # (overload_mode) still suspends parking unconditionally.
            if self.trace is not None and self.trace.parks:
                self._trace_deny(
                    now, task, node,
                    "overload_latch" if self.overload_mode else "crowd_bar",
                    overload=self.overload_mode, crowd=crowd,
                    bar=adaptive.park_active_factor
                    * (self.spec.num_machines - self._machines_down))
            return Launch(task, node, local=False)
        if self.down_nodes:
            # crashed nodes cannot host a parked task; with every replica
            # down the task runs remotely (re-read from the durable store)
            # until re-replication restores a live replica
            placement = tuple(v for v in placement
                              if v not in self.down_nodes)
            if not placement:
                if self.trace is not None and self.trace.parks:
                    self._trace_deny(now, task, node, "replicas_down")
                return Launch(task, node, local=False)
        # S_rq: data nodes by RQ entries desc (a pre-offered donor core means
        # wait ≈ hot-plug latency); else S_aq: data nodes by AQ entries asc.
        s_rq = sorted(placement, key=lambda v: -self.reconfig.rq_len(v))
        wait_bound = None
        if self.reconfig.rq_len(s_rq[0]) > 0:
            p = s_rq[0]
            if (adaptive.enabled and not self._churn_relief(now)
                    and not self._wide_batch(self.total_pending_maps)):
                # a live donor offer: the match is imminent, so the park
                # only needs the shortest patience in case it goes stale.
                # Mid-churn (_churn_relief) the full patience applies
                # instead: offers go stale because the *donor* crashed,
                # and a 4-second fuse would expire the park into the
                # no_park blacklist, disqualifying the task from every
                # later park for no fault of the machine's.  Wide batches
                # (_wide_batch) also keep full patience: a parked map has
                # siblings to keep its phase busy, so the stale-offer
                # downside the fuse hedges against is not on the critical
                # path there
                wait_bound = adaptive.max_wait_floor
        else:
            p = min(placement, key=lambda v: self.reconfig.aq_len(v))
            if len(self.reconfig.aq[self.spec.machine_of(p)]) >= self.park_depth:
                if self.trace is not None and self.trace.parks:
                    self._trace_deny(now, task, node, "aq_saturated",
                                     machine=self.spec.machine_of(p),
                                     depth=self.park_depth)
                return None      # AQ saturated: leave for remote-fill / later
            if adaptive.enabled and not self._churn_relief(now):
                # width gate — stands down under churn relief
                # (_churn_relief): on a churning fleet narrow backlogs
                # still park profitably, because re-replication keeps
                # locality scarce fleet-wide.  Otherwise: a narrow
                # backlog (few pending maps per map-open job) puts every
                # parked map on its job's phase-critical path — launch
                # remotely instead.  Wide jobs (the paper's closed mix)
                # park for free: a parked map has plenty of siblings to
                # keep its phase busy.
                if (self.total_pending_maps
                        < adaptive.park_min_width * self.map_open_jobs):
                    self.reconfig.stats["park_declined"] += 1
                    if self.trace is not None and self.trace.parks:
                        self._trace_deny(
                            now, task, node, "width_gate",
                            pending_maps=self.total_pending_maps,
                            map_open_jobs=self.map_open_jobs,
                            min_width=adaptive.park_min_width)
                    return Launch(task, node, local=False)
            if (adaptive.enabled and not self._churn_relief(now)
                    and not self._wide_batch(self.total_pending_maps)):
                # pressure gate: park only when a donor core is predicted
                # within the task's remote-launch break-even (the extra
                # time a remote read would cost on this fabric).  Like the
                # width gate it stands down under churn relief *and* on
                # wide batches: both are regimes where parking wins by
                # default (measured: its win-floor pruning alone cost the
                # saturated closed mix ~2/3 of the fixed policy's paired
                # win), so admission reverts to the fixed policy's and the
                # EWMAs idle as observers
                prof = job.spec.profile
                breakeven = (prof.map_time * prof.remote_penalty
                             * self.spec.remote_penalty_scale)
                if (self.spec.faults.enabled
                        and self.spec.faults.machine_classes):
                    # heterogeneous fleet: the bar is per-class — a slow
                    # machine's map takes longer and its fabric makes the
                    # remote read costlier, both scale the break-even
                    mc = self.spec.machine_class(self.spec.machine_of(p))
                    breakeven *= mc.speed * mc.fabric
                ok, wait_bound = self.reconfig.park_decision(
                    self.spec.machine_of(p), now, breakeven)
                if not ok:
                    if self.trace is not None and self.trace.parks:
                        # the reconfigurator stashed which of its three
                        # gates declined (fail_streak / predicted_wait /
                        # win_floor) plus the signal values it saw
                        gate, signals = (self.reconfig.last_decline
                                         or ("park_decision", {}))
                        self._trace_deny(now, task, node, gate,
                                         machine=self.spec.machine_of(p),
                                         **signals)
                    return Launch(task, node, local=False)
        self.reconfig.park_task(task, p, now, wait_bound=wait_bound)
        self.reconfig.release_core(node, now)   # RQ of machine(node)
        self.parked.add(task)
        self._parked_maps_per_job[job.spec.job_id] = (
            self._parked_maps_per_job.get(job.spec.job_id, 0) + 1)
        if self.trace is not None and self.trace.parks:
            self.trace.emit(now, "park_admit", {
                "task": task, "job": job.spec.job_id,
                "target_vm": p, "machine": self.spec.machine_of(p),
                "offering_node": node, "wait_bound": wait_bound})
        return Launch(task, p, local=True, via_reconfig=True)

    def _trace_deny(self, now: float, task: TaskId, node: int,
                    gate: str, **signals: object) -> None:
        """Emit a park_deny record naming the Algorithm-1 gate that turned
        this map's park into a remote launch (see tracing.PARK_GATES)."""
        data: Dict[str, object] = {"task": task, "job": task.job_id,
                                   "node": node, "gate": gate}
        data.update(signals)
        self.trace.emit(now, "park_deny", data)

    def _unpark(self, task: TaskId) -> None:
        if task in self.parked:
            self.parked.discard(task)
            self._parked_maps_per_job[task.job_id] -= 1

    # -- callbacks from the simulator for reconfig lifecycle -------------------
    def parked_task_launched(self, task: TaskId, node: int, now: float) -> None:
        self._unpark(task)
        job = self.jobs[task.job_id]
        self._start_map(job, task.index, node)
        job.local_map_launches += 1
        job.reconfig_map_launches += 1
        if self.trace is not None and self.trace.parks:
            self.trace.emit(now, "unpark", {
                "task": task, "job": task.job_id, "node": node,
                "machine": self.spec.machine_of(node)})

    def parked_task_expired(self, task: TaskId, now: float) -> None:
        self._unpark(task)
        self.no_park.add(task)
