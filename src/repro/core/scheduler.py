"""Completion-time based scheduler — paper §4.2, Algorithm 2 (+ Algorithm 1
for map-task assignment through resource reconfiguration).

Policy, exactly as the paper states it:

* jobs with no completed or running tasks take precedence (oldest first) so
  the online estimator can bootstrap (initial tasks give the Eq.-1 sample);
* remaining jobs are sorted by EDF (ascending deadline);
* a job only receives map slots while ``scheduled_maps < n_m`` and reduce
  slots while ``scheduled_reduces < n_r`` (Eq. 10 demand, recomputed on every
  task completion with remaining work and remaining time);
* reduces launch only after the job's map phase finishes (Algorithm 2 l.10);
* map assignment prefers a data-local task on the heartbeating node; a
  non-local candidate is parked for VM reconfiguration on a node that holds
  its data (Algorithm 1): AQ entry on the data node's machine, RQ entry on
  the heartbeating node's machine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.estimator import OnlineEstimator
from repro.core.reconfigurator import Reconfigurator
from repro.core.types import (ClusterSpec, JobRuntime, JobSpec, TaskId,
                              TaskKind)


@dataclass
class Launch:
    """Scheduler decision: run task on node (immediately)."""
    task: TaskId
    node: int
    local: bool
    via_reconfig: bool = False


class SchedulerBase:
    """Common bookkeeping shared by all scheduler policies."""

    name = "base"
    uses_reconfig = False

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        self.jobs: Dict[str, JobRuntime] = {}
        self.order: List[str] = []          # submission order

    # -- lifecycle ----------------------------------------------------------
    def job_added(self, job: JobSpec, now: float) -> None:
        rt = JobRuntime(spec=job)
        self.jobs[job.job_id] = rt
        self.order.append(job.job_id)
        self.on_job_added(rt, now)

    def on_job_added(self, job: JobRuntime, now: float) -> None:
        pass

    def task_started(self, task: TaskId, node: int, now: float) -> None:
        job = self.jobs[task.job_id]
        if task.kind == TaskKind.MAP:
            job.running_map[task.index] = node
        else:
            job.running_reduce[task.index] = node

    def task_finished(self, task: TaskId, node: int, now: float,
                      duration: float) -> None:
        job = self.jobs[task.job_id]
        if task.kind == TaskKind.MAP:
            job.running_map.pop(task.index, None)
            job.completed_map.add(task.index)
            job.map_durations.append(duration)
        else:
            job.running_reduce.pop(task.index, None)
            job.completed_reduce.add(task.index)
            job.reduce_durations.append(duration)
        if job.finished and job.finish_time is None:
            job.finish_time = now
        self.on_task_finished(job, task, now)

    def on_task_finished(self, job: JobRuntime, task: TaskId, now: float) -> None:
        pass

    # -- helpers --------------------------------------------------------------
    def _unstarted_map_tasks(self, job: JobRuntime) -> List[int]:
        done = job.completed_map
        running = job.running_map
        return [i for i in range(job.spec.u_m)
                if i not in done and i not in running]

    def _unstarted_reduce_tasks(self, job: JobRuntime) -> List[int]:
        done = job.completed_reduce
        running = job.running_reduce
        return [i for i in range(job.spec.v_r)
                if i not in done and i not in running]

    def _local_map_candidates(self, job: JobRuntime, node: int) -> List[int]:
        return [i for i in self._unstarted_map_tasks(job)
                if node in job.spec.block_placement[i]]

    def active_jobs(self) -> List[JobRuntime]:
        return [self.jobs[j] for j in self.order if not self.jobs[j].finished]

    # subclasses implement:
    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        raise NotImplementedError


class CompletionTimeScheduler(SchedulerBase):
    """The paper's proposed scheduler (Algorithm 2 + Algorithm 1)."""

    name = "proposed"
    uses_reconfig = True

    def __init__(self, spec: ClusterSpec, reconfig: Optional[Reconfigurator] = None,
                 estimator: Optional[OnlineEstimator] = None):
        super().__init__(spec)
        self.reconfig = reconfig or Reconfigurator(spec)
        self.estimator = estimator or OnlineEstimator()
        self.parked: Set[TaskId] = set()
        # tasks whose reconfiguration wait expired once run remotely instead
        # of re-parking (bounds per-task wait at max_wait)
        self.no_park: Set[TaskId] = set()
        # max parked tasks per target machine's AQ
        self.park_depth = 2
        self.max_slots = spec.num_nodes * spec.base_map_slots

    # -- Algorithm 2 line 2 + lines 17-20 ----------------------------------
    def on_job_added(self, job: JobRuntime, now: float) -> None:
        self._recompute_demand(job, now)

    def on_task_finished(self, job: JobRuntime, task: TaskId, now: float) -> None:
        self._recompute_demand(job, now)

    def _recompute_demand(self, job: JobRuntime, now: float) -> None:
        job.demand = self.estimator.demand(
            job, now, max_map_slots=self.max_slots,
            max_reduce_slots=self.max_slots)

    # -- scheduled counts include parked tasks ------------------------------
    def _scheduled_maps(self, job: JobRuntime) -> int:
        parked = sum(1 for t in self.parked if t.job_id == job.spec.job_id
                     and t.kind == TaskKind.MAP)
        return len(job.running_map) + parked

    # -- Algorithm 2 main loop ----------------------------------------------
    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        out: List[Launch] = []
        jobs = self.active_jobs()
        # bootstrap jobs first (no completed or running tasks), oldest first;
        # then EDF ascending absolute deadline
        bootstrap = [j for j in jobs if not j.started]
        edf = sorted((j for j in jobs if j.started),
                     key=lambda j: j.absolute_deadline)
        for phase in ("demand", "backfill", "remote_fill"):
            # Pass 1 "demand": Eq.-10 minimum demands, bootstrap jobs first
            #   (probe tasks), then EDF (Algorithm 2).  Non-local map
            #   candidates are parked for reconfiguration (Algorithm 1).
            # Pass 2 "backfill": work-conserving — the abstract's "maximize
            #   the use of resources among the active jobs": leftover slots
            #   go to jobs beyond their minimum in EDF order, still parking
            #   non-local candidates.
            # Pass 3 "remote_fill": any core still idle takes a remote task
            #   (last resort — patient parking must never idle the cluster).
            if phase == "demand":
                ordered = bootstrap + edf
            else:
                ordered = sorted(jobs, key=lambda j: j.absolute_deadline)
            if phase == "remote_fill":
                # Before burning idle cores on *remote* tasks, donate them to
                # parked *local* tasks waiting on this machine's AQ — a local
                # task on the sibling VM is strictly faster than a remote one
                # here (this is what makes Algorithm 1 pay off: the donor
                # core must not be re-occupied by remote work).
                m = self.spec.machine_of(node)
                pending = sum(1 for p in self.reconfig.aq[m]
                              if p.target_vm != node)
                while (free_map > 0 and pending > 0
                       and self.reconfig.vcpus[node] > self.spec.min_vcpus_per_vm):
                    self.reconfig.release_core(node, now)
                    free_map -= 1
                    pending -= 1
            for job in ordered:
                if free_map <= 0 and free_reduce <= 0:
                    break
                demand = job.demand
                n_m = demand.n_m if demand else 1   # bootstrap: one probe task
                n_r = demand.n_r if demand else 1
                if phase != "demand":
                    n_m, n_r = job.spec.u_m, job.spec.v_r
                if not job.map_finished:
                    while free_map > 0 and self._scheduled_maps(job) < n_m:
                        launch = self._assign_map(
                            job, node, now, allow_park=(phase != "remote_fill"))
                        if launch is None:
                            break
                        if launch.via_reconfig:
                            # task parked on AQ; node's core is only *offered*
                            # (RQ) — it keeps serving until the match actually
                            # unplugs it, so the slot stays schedulable now
                            pass
                        else:
                            out.append(launch)
                            free_map -= 1
                            job.running_map[launch.task.index] = launch.node
                            if launch.local:
                                job.local_map_launches += 1
                            else:
                                job.remote_map_launches += 1
                elif not job.finished:
                    unstarted = self._unstarted_reduce_tasks(job)
                    while (free_reduce > 0 and unstarted
                           and len(job.running_reduce) < n_r):
                        idx = unstarted.pop(0)
                        t = TaskId(job.spec.job_id, TaskKind.REDUCE, idx)
                        out.append(Launch(t, node, local=True))
                        job.running_reduce[idx] = node
                        free_reduce -= 1
        return out

    # -- Algorithm 1 -----------------------------------------------------------
    def _assign_map(self, job: JobRuntime, node: int, now: float,
                    allow_park: bool = True) -> Optional[Launch]:
        local = self._local_map_candidates(job, node)
        if local:
            idx = local[0]
            return Launch(TaskId(job.spec.job_id, TaskKind.MAP, idx), node,
                          local=True)
        unstarted = [i for i in self._unstarted_map_tasks(job)
                     if TaskId(job.spec.job_id, TaskKind.MAP, i) not in self.parked]
        if not unstarted:
            return None
        idx = unstarted[0]
        task = TaskId(job.spec.job_id, TaskKind.MAP, idx)
        placement = job.spec.block_placement[idx]
        slack = job.absolute_deadline - now
        # Deadline-critical or once-expired tasks run remotely right away;
        # everything else prefers parking (Algorithm 1), falling through to
        # the remote-fill pass only when the AQ is saturated.
        deadline_critical = slack <= 3.0 * self.reconfig.max_wait
        if task in self.no_park or deadline_critical or not allow_park:
            return Launch(task, node, local=False)
        # S_rq: data nodes by RQ entries desc (a pre-offered donor core means
        # wait ≈ hot-plug latency); else S_aq: data nodes by AQ entries asc.
        s_rq = sorted(placement, key=lambda v: -self.reconfig.rq_len(v))
        if self.reconfig.rq_len(s_rq[0]) > 0:
            p = s_rq[0]
        else:
            p = min(placement, key=lambda v: self.reconfig.aq_len(v))
            if len(self.reconfig.aq[self.spec.machine_of(p)]) >= self.park_depth:
                return None      # AQ saturated: leave for remote-fill / later
        self.reconfig.park_task(task, p, now)   # AQ of machine(p)
        self.reconfig.release_core(node, now)   # RQ of machine(node)
        self.parked.add(task)
        return Launch(task, p, local=True, via_reconfig=True)

    def has_local_pending(self, vm: int) -> bool:
        """Does any active job still have an unstarted map task whose data
        lives on ``vm``?  (Used for the release-on-finish decision.)"""
        for job in self.active_jobs():
            if job.map_finished:
                continue
            for i in self._unstarted_map_tasks(job):
                if vm in job.spec.block_placement[i]:
                    return True
        return False

    # -- callbacks from the simulator for reconfig lifecycle -------------------
    def parked_task_launched(self, task: TaskId, node: int, now: float) -> None:
        self.parked.discard(task)
        job = self.jobs[task.job_id]
        job.running_map[task.index] = node
        job.local_map_launches += 1
        job.reconfig_map_launches += 1

    def parked_task_expired(self, task: TaskId, now: float) -> None:
        self.parked.discard(task)
        self.no_park.add(task)
