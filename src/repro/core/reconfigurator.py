"""Resource Reconfigurator — paper §4.1, Algorithm 1.

Per-physical-machine **Assign Queues (AQ)** and **Release Queues (RQ)**:

* a VM with a surplus free core registers it in its machine's RQ;
* a map task that *should* run data-locally on VM ``p`` (but ``p`` has no free
  slot) is parked in machine(p)'s AQ;
* whenever both queues of one machine are non-empty, a vCPU is hot-unplugged
  from the releasing VM and hot-plugged into the target VM (latency
  ``ClusterSpec.hotplug_latency``), and the parked task launches data-locally.

The queues are decoupled exactly as in the paper: releases are lazy,
assignment waits until the machine actually has a donor core.  CPU never
crosses a physical machine boundary (paper: "CPU resource cannot be
transferred beyond the physical system boundary").

A parked task that waits longer than ``max_wait`` is handed back to the
scheduler for a remote launch — the paper observes this wait is negligible
("tasks ... finish in less than a minute"), but an implementation must bound
it to protect deadlines.

Scaling note: ``match`` visits only machines whose AQ *and* RQ are both
non-empty (tracked incrementally, ascending machine order — identical
matching order to a full 0..M-1 sweep), and ``expire_stale`` keeps a global
min-heap on park time so the common no-expiry heartbeat costs O(1) instead
of scanning every machine's queue.  Both are pure-performance changes; the
queue semantics are pinned by the parity test against
``repro.simcluster._legacy``.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

from repro.core.types import ClusterSpec, TaskId


@dataclass
class ParkedTask:
    task: TaskId
    target_vm: int
    parked_at: float


@dataclass
class PendingPlug:
    """A matched release->assign pair in flight (hot-plug latency)."""
    machine: int
    from_vm: int
    to_vm: int
    task: TaskId
    ready_at: float


class Reconfigurator:
    """Tracks AQ/RQ per machine and per-VM vCPU counts."""

    def __init__(self, spec: ClusterSpec, max_wait: float = 15.0):
        self.spec = spec
        self.max_wait = max_wait
        self.vcpus: List[int] = [spec.base_map_slots] * spec.num_nodes
        self.aq: List[Deque[ParkedTask]] = [deque() for _ in range(spec.num_machines)]
        self.rq: List[Deque[int]] = [deque() for _ in range(spec.num_machines)]  # vm ids
        self.in_flight: List[PendingPlug] = []
        # host-integration hook: validates that an offered core is still free
        # (an RQ entry goes stale when the VM re-occupies the core before the
        # match).  Set by the simulator / fleet runtime.
        self.validator: Optional[Callable[[int], bool]] = None
        self.stats = {"reconfigurations": 0, "parked": 0, "expired": 0,
                      "total_wait": 0.0}
        # machines with a non-empty AQ / RQ, so match() touches only
        # machines that can possibly pair instead of sweeping all of them
        self._aq_nonempty: Set[int] = set()
        self._rq_nonempty: Set[int] = set()
        # (parked_at, seq, machine, entry) min-heap; entries are lazy — a
        # task already matched/cancelled fails the identity check on pop
        self._park_heap: List[Tuple[float, int, int, ParkedTask]] = []
        self._park_seq = 0

    def _valid_donor(self, vm: int) -> bool:
        if self.vcpus[vm] <= self.spec.min_vcpus_per_vm:
            return False
        return self.validator(vm) if self.validator is not None else True

    # -- queue registration (Algorithm 1 lines 4-12) -----------------------
    def aq_len(self, vm: int) -> int:
        return sum(1 for t in self.aq[self.spec.machine_of(vm)]
                   if t.target_vm == vm)

    def rq_len(self, vm: int) -> int:
        """Count of *currently valid* donor offers on vm's machine."""
        return sum(1 for cand in self.rq[self.spec.machine_of(vm)]
                   if cand != vm and self._valid_donor(cand))

    def park_task(self, task: TaskId, target_vm: int, now: float) -> None:
        """AQ entry: task waits for a core on target_vm's machine."""
        m = self.spec.machine_of(target_vm)
        entry = ParkedTask(task, target_vm, now)
        self.aq[m].append(entry)
        self._aq_nonempty.add(m)
        self._park_seq += 1
        heapq.heappush(self._park_heap, (now, self._park_seq, m, entry))
        self.stats["parked"] += 1

    def release_core(self, vm: int, now: float) -> None:
        """RQ entry: vm offers one core (never below min_vcpus)."""
        if self.vcpus[vm] <= self.spec.min_vcpus_per_vm:
            return
        m = self.spec.machine_of(vm)
        self.rq[m].append(vm)
        self._rq_nonempty.add(m)

    def _aq_sync(self, m: int) -> None:
        if not self.aq[m]:
            self._aq_nonempty.discard(m)

    def cancel_parked(self, task: TaskId) -> bool:
        for m, q in enumerate(self.aq):
            for item in list(q):
                if item.task == task:
                    q.remove(item)
                    self._aq_sync(m)
                    return True
        return False

    # -- matching ------------------------------------------------------------
    def match(self, now: float, donor_ok=None) -> List[PendingPlug]:
        """Pair AQ/RQ entries per machine; returns newly started hot-plugs.

        ``donor_ok(vm)`` lets the caller veto donors whose offered core got
        re-occupied between the offer and the match."""
        started = []
        for m in sorted(self._aq_nonempty & self._rq_nonempty):
            while self.aq[m] and self.rq[m]:
                parked = self.aq[m].popleft()
                donor = None
                while self.rq[m]:
                    cand = self.rq[m].popleft()
                    if (cand != parked.target_vm and self._valid_donor(cand)
                            and (donor_ok is None or donor_ok(cand))):
                        donor = cand
                        break
                    # stale / self-targeted offer: drop it
                if donor is None:
                    self.aq[m].appendleft(parked)
                    break
                if self.vcpus[parked.target_vm] >= self.spec.max_vcpus_per_vm:
                    # target saturated: requeue task, put donor back
                    self.rq[m].append(donor)
                    self.aq[m].append(parked)
                    break
                self.vcpus[donor] -= 1
                plug = PendingPlug(m, donor, parked.target_vm, parked.task,
                                   now + self.spec.hotplug_latency)
                self.in_flight.append(plug)
                started.append(plug)
                self.stats["reconfigurations"] += 1
                self.stats["total_wait"] += now - parked.parked_at
            self._aq_sync(m)
            if not self.rq[m]:
                self._rq_nonempty.discard(m)
        return started

    def complete_plugs(self, now: float) -> List[PendingPlug]:
        """Hot-plugs whose latency elapsed; caller launches the task."""
        done = [p for p in self.in_flight if p.ready_at <= now]
        self.in_flight = [p for p in self.in_flight if p.ready_at > now]
        for p in done:
            self.vcpus[p.to_vm] += 1
        return done

    def expire_stale(self, now: float) -> List[ParkedTask]:
        """Parked tasks past max_wait -> hand back for remote launch.

        The park-time heap makes the common "nothing expired" case O(1);
        popped entries whose task already left its AQ (matched / cancelled)
        are discarded."""
        out = []
        heap = self._park_heap
        # NB: `now - parked_at > max_wait` is the seed's exact expression;
        # rewriting it as `parked_at < now - max_wait` is NOT float-identical
        # at the boundary and breaks decision parity.
        while heap and now - heap[0][0] > self.max_wait:
            parked_at, _, m, item = heapq.heappop(heap)
            q = self.aq[m]
            if not any(it is item for it in q):
                continue            # already matched or cancelled
            q.remove(item)
            self._aq_sync(m)
            out.append(item)
            self.stats["expired"] += 1
        return out

    def next_event_time(self) -> Optional[float]:
        if not self.in_flight:
            return None
        return min(p.ready_at for p in self.in_flight)

    @property
    def total_vcpus(self) -> int:
        return sum(self.vcpus) + len(self.in_flight)
