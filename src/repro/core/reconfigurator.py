"""Resource Reconfigurator — paper §4.1, Algorithm 1.

Per-physical-machine **Assign Queues (AQ)** and **Release Queues (RQ)**:

* a VM with a surplus free core registers it in its machine's RQ;
* a map task that *should* run data-locally on VM ``p`` (but ``p`` has no free
  slot) is parked in machine(p)'s AQ;
* whenever both queues of one machine are non-empty, a vCPU is hot-unplugged
  from the releasing VM and hot-plugged into the target VM (latency
  ``ClusterSpec.hotplug_latency``), and the parked task launches data-locally.

The queues are decoupled exactly as in the paper: releases are lazy,
assignment waits until the machine actually has a donor core.  CPU never
crosses a physical machine boundary (paper: "CPU resource cannot be
transferred beyond the physical system boundary").

A parked task that waits longer than ``max_wait`` is handed back to the
scheduler for a remote launch — the paper observes this wait is negligible
("tasks ... finish in less than a minute"), but an implementation must bound
it to protect deadlines.

**Pressure-adaptive mode** (``ClusterSpec.adaptive``, off by default): the
fixed ``max_wait`` bet fails under sustained saturation — no VM ever offers
a core, so every parked task burns its full patience before the remote
fallback.  With ``AdaptiveConfig.enabled`` the reconfigurator additionally
tracks, per machine and incrementally,

* ``rq_depth`` — queued donor offers (mirror of ``len(rq[m])``, audited by
  the invariant suite),
* ``offer_ewma`` / ``last_offer`` — an EWMA over the intervals between
  donor-core offers, fed by the simulator's release events,
* ``free_ewma`` / ``last_free`` — the same over raw core-free events
  (``ClusterSim`` notifies via :meth:`observe_core_free`),
* ``fail_streak`` — consecutive park *outcomes* on the machine that ended
  in a remote launch (the scheduler reports outcomes through
  :meth:`note_park_outcome`): a park pays when its task eventually runs
  data-locally — via a donor match **or** via the target node's own freed
  slot (most parks resolve this way: the AQ entry acts as a reservation) —
  and fails when the task burns its full patience and launches remotely
  anyway.  A machine whose streak hits the limit stops admitting parks
  until an offer arrives, a park pays, or ``fail_cooldown`` elapses
  (periodic probing keeps the signal fresh),

and exposes :meth:`predicted_core_wait` + :meth:`park_decision`, which the
scheduler uses to gate park admission against a task's remote-launch
break-even and to bound each park's patience (see ``AdaptiveConfig``).
Disabled, every decision path is bit-exact against the legacy engine.

Scaling note: ``match`` visits only machines whose AQ *and* RQ are both
non-empty (tracked incrementally, ascending machine order — identical
matching order to a full 0..M-1 sweep), and ``expire_stale`` keeps a global
min-heap on park time so the common no-expiry heartbeat costs O(1) instead
of scanning every machine's queue.  Both are pure-performance changes; the
queue semantics are pinned by the parity test against
``repro.simcluster._legacy``.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

from repro.core.types import ClusterSpec, TaskId


@dataclass(eq=False)
class ParkedTask:
    """One AQ entry.  ``eq=False``: queue/heap bookkeeping is by identity —
    two parks of one task at the same instant must stay distinguishable.

    ``wait_bound`` is the adaptive per-park patience; ``None`` means the
    legacy fixed ``Reconfigurator.max_wait`` applies."""

    task: TaskId
    target_vm: int
    parked_at: float
    wait_bound: Optional[float] = None


@dataclass
class PendingPlug:
    """A matched release->assign pair in flight (hot-plug latency)."""
    machine: int
    from_vm: int
    to_vm: int
    task: TaskId
    ready_at: float


class Reconfigurator:
    """Tracks AQ/RQ per machine and per-VM vCPU counts."""

    # decision-trace bus (repro.core.tracing.TraceBus); attached by the
    # simulator when ClusterSpec.tracing is enabled, None otherwise — every
    # emission site is a single `is None` guard, so tracing-off stays
    # bit-exact against the legacy engine
    trace = None

    def __init__(self, spec: ClusterSpec, max_wait: float = 15.0):
        self.spec = spec
        self.max_wait = max_wait
        self.adaptive = spec.adaptive
        self.vcpus: List[int] = [spec.base_map_slots] * spec.num_nodes
        self.aq: List[Deque[ParkedTask]] = [deque() for _ in range(spec.num_machines)]
        self.rq: List[Deque[int]] = [deque() for _ in range(spec.num_machines)]  # vm ids
        self.in_flight: List[PendingPlug] = []
        # host-integration hook: validates that an offered core is still free
        # (an RQ entry goes stale when the VM re-occupies the core before the
        # match).  Set by the simulator / fleet runtime.
        self.validator: Optional[Callable[[int], bool]] = None
        self.stats = {"reconfigurations": 0, "parked": 0, "expired": 0,
                      "total_wait": 0.0, "park_declined": 0,
                      "park_wins": 0, "park_losses": 0, "park_crashed": 0,
                      "park_crash_discounted": 0,
                      "harvest_borrows": 0, "harvest_returns": 0}
        # machines with a non-empty AQ / RQ, so match() touches only
        # machines that can possibly pair instead of sweeping all of them
        self._aq_nonempty: Set[int] = set()
        self._rq_nonempty: Set[int] = set()
        # (key, seq, machine, entry) min-heap; key is the park time (legacy
        # fixed max_wait) or the absolute expiry time (adaptive per-park
        # bounds).  Entries are lazy — a task already matched/cancelled
        # fails the identity check on pop.
        self._park_heap: List[Tuple[float, int, int, ParkedTask]] = []
        self._park_seq = 0
        # task-id -> (machine, entry): O(1) cancel_parked / membership
        self._parked_entry: Dict[TaskId, Tuple[int, ParkedTask]] = {}
        # -- per-machine pressure signals (see AdaptiveConfig) --------------
        m = spec.num_machines
        # incremental mirror of len(self.rq[machine]) — updated at every
        # offer/consume site, recounted by the invariant suite
        self.rq_depth: List[int] = [0] * m
        self.offer_ewma: List[Optional[float]] = [None] * m
        self.last_offer: List[Optional[float]] = [None] * m
        self.free_ewma: List[Optional[float]] = [None] * m
        self.last_free: List[Optional[float]] = [None] * m
        self.fail_streak: List[int] = [0] * m
        self.last_fail: List[Optional[float]] = [None] * m
        # cluster-level park win-rate EWMA (1 = every park ends local,
        # 0 = every park ends remote); starts optimistic so the paper's
        # closed-mix regime parks from the first heartbeat
        self.park_outcome_ewma: float = 1.0
        self._last_park: Optional[float] = None
        # expired parks whose outcome (local vs remote launch) is still
        # unknown: task -> target machine, resolved by note_park_outcome
        self._expired_machine: Dict[TaskId, int] = {}
        # last park_decision decline: (gate, signals) — written only when
        # the trace bus is attached, read by the scheduler so the park_deny
        # record carries the task context this method never sees
        self.last_decline: Optional[Tuple[str, Dict[str, object]]] = None

    def _valid_donor(self, vm: int) -> bool:
        if self.vcpus[vm] <= self.spec.min_vcpus_per_vm:
            return False
        return self.validator(vm) if self.validator is not None else True

    # -- queue registration (Algorithm 1 lines 4-12) -----------------------
    def aq_len(self, vm: int) -> int:
        return sum(1 for t in self.aq[self.spec.machine_of(vm)]
                   if t.target_vm == vm)

    def rq_len(self, vm: int) -> int:
        """Count of *currently valid* donor offers on vm's machine."""
        return sum(1 for cand in self.rq[self.spec.machine_of(vm)]
                   if cand != vm and self._valid_donor(cand))

    def park_task(self, task: TaskId, target_vm: int, now: float,
                  wait_bound: Optional[float] = None) -> None:
        """AQ entry: task waits for a core on target_vm's machine.

        ``wait_bound`` is the adaptive per-park patience; in adaptive mode a
        missing bound defaults to the clamped ``max_wait`` so direct callers
        (tests, fleet runtime) stay within [floor, ceiling] too."""
        m = self.spec.machine_of(target_vm)
        if self.adaptive.enabled:
            if wait_bound is None:
                wait_bound = min(self.adaptive.max_wait_ceiling,
                                 max(self.adaptive.max_wait_floor,
                                     self.max_wait))
            key = now + wait_bound          # heap orders by expiry time
        else:
            wait_bound = None               # legacy: fixed max_wait applies
            key = now                       # heap orders by park time
        entry = ParkedTask(task, target_vm, now, wait_bound)
        self.aq[m].append(entry)
        self._aq_nonempty.add(m)
        self._park_seq += 1
        heapq.heappush(self._park_heap, (key, self._park_seq, m, entry))
        self._parked_entry[task] = (m, entry)
        self.stats["parked"] += 1

    def release_core(self, vm: int, now: float) -> None:
        """RQ entry: vm offers one core (never below min_vcpus)."""
        if self.vcpus[vm] <= self.spec.min_vcpus_per_vm:
            return
        m = self.spec.machine_of(vm)
        self.rq[m].append(vm)
        self.rq_depth[m] += 1
        self._rq_nonempty.add(m)
        if self.adaptive.enabled:
            # a donor offer is the machine's "core freed for neighbours"
            # event: update the offer-interval EWMA and re-open parking
            last = self.last_offer[m]
            if last is not None:
                self.offer_ewma[m] = self._ewma(self.offer_ewma[m], now - last)
            self.last_offer[m] = now
            self.fail_streak[m] = 0

    def _ewma(self, prev: Optional[float], sample: float) -> float:
        if prev is None:
            return sample
        a = self.adaptive
        if (a.enabled and a.ewma_gap_cap > 0.0 and prev > 0.0
                and sample > a.ewma_gap_cap * prev):
            # an interval spanning a restart gap (or any long disruption)
            # says "nothing happened for a while", not "the machine got
            # this much slower" — clamp it so one outage cannot inflate
            # the predicted core wait for the whole next epoch.  The
            # `prev > 0` guard keeps a zero-interval sample (two offers on
            # one event) from wedging the EWMA at zero forever
            sample = a.ewma_gap_cap * prev
        return a.ewma_alpha * sample + (1.0 - a.ewma_alpha) * prev

    def observe_core_free(self, vm: int, now: float) -> None:
        """Simulator hook: a core on ``vm`` just freed (map finish), whether
        or not it was offered.  Feeds the raw core-free-interval EWMA."""
        m = self.spec.machine_of(vm)
        last = self.last_free[m]
        if last is not None:
            self.free_ewma[m] = self._ewma(self.free_ewma[m], now - last)
        self.last_free[m] = now

    def _aq_sync(self, m: int) -> None:
        if not self.aq[m]:
            self._aq_nonempty.discard(m)

    def _drop_parked_entry(self, task: TaskId, entry: ParkedTask) -> None:
        """Clear the cancel index when ``entry`` leaves its AQ (but never a
        newer park of the same task id)."""
        cur = self._parked_entry.get(task)
        if cur is not None and cur[1] is entry:
            del self._parked_entry[task]

    def cancel_parked(self, task: TaskId) -> bool:
        """Remove ``task``'s AQ entry, O(1) lookup via the park index (the
        deque removal only walks that one machine's queue, bounded by the
        scheduler's park depth — not every AQ in the cluster)."""
        hit = self._parked_entry.pop(task, None)
        if hit is None:
            return False
        m, entry = hit
        self.aq[m].remove(entry)            # identity: ParkedTask has eq=False
        self._aq_sync(m)
        return True

    # -- fault integration (FaultConfig; never reached when faults are off) --
    def machine_down(self, machine: int, now: float) -> List[TaskId]:
        """Machine crashed: drop every AQ entry and RQ offer on it and abort
        its in-flight hot-plugs (plugs never cross a machine boundary, so
        returning each aborted plug's core to its donor VM keeps the
        machine's vCPU sum — and the cluster conservation invariant —
        exact).  Returns the task ids whose park or plug was cancelled so
        the scheduler can make them schedulable again."""
        cancelled: List[TaskId] = []
        for entry in list(self.aq[machine]):
            self._drop_parked_entry(entry.task, entry)
            cancelled.append(entry.task)
        self.aq[machine].clear()
        self._aq_nonempty.discard(machine)
        self.rq[machine].clear()
        self.rq_depth[machine] = 0
        self._rq_nonempty.discard(machine)
        keep: List[PendingPlug] = []
        for plug in self.in_flight:
            if plug.machine == machine:
                self.vcpus[plug.from_vm] += 1
                cancelled.append(plug.task)
            else:
                keep.append(plug)
        self.in_flight = keep
        # unresolved expired-park outcomes on this machine die with it: a
        # post-crash remote launch must not charge the machine's (reset)
        # fail streak for a pre-crash park
        for task in [t for t, m in self._expired_machine.items()
                     if m == machine]:
            del self._expired_machine[task]
        self.stats["park_crashed"] += len(cancelled)
        if self.trace is not None and self.trace.parks:
            for task in cancelled:
                self.trace.emit(now, "park_crashed", {
                    "task": task, "job": task.job_id,
                    "machine": machine})
        return cancelled

    def machine_restarted(self, machine: int, now: float) -> None:
        """Machine back up: its VMs boot with the base slot shape (the
        pre-crash vCPU distribution redistributes within the machine, so
        the sum is unchanged) and every pressure signal resets — EWMAs and
        fail streaks from the pre-crash epoch would otherwise poison park
        admission on the fresh machine."""
        vpm = self.spec.vms_per_machine
        for vm in range(machine * vpm, (machine + 1) * vpm):
            self.vcpus[vm] = self.spec.base_map_slots
        self.offer_ewma[machine] = None
        self.last_offer[machine] = None
        self.free_ewma[machine] = None
        self.last_free[machine] = None
        self.fail_streak[machine] = 0
        self.last_fail[machine] = None

    # -- Borg-style harvesting (ServeConfig; policy axis `harvest`) ----------
    # The serving layer owns the borrow/return *decisions* (utilization
    # EWMA vs the headroom bar, preemptive return on load spikes or churn
    # relief); the reconfigurator owns the *accounting* — the counters the
    # trace-bus harvest events reconcile against in the invariant audit.
    # Borrowed cores never move through vcpus/in_flight: a loan shrinks
    # the service's pinned reservation on its own VM (raising that VM's
    # map capacity in the engine), so total_vcpus conservation is exact.

    def harvest_borrow(self, now: float, *, machine: int, node: int,
                       service: str, replica: int, signal: str,
                       util: float, cores_left: int) -> None:
        """One service core lent to the batch side (``signal`` names the
        trigger: parked_demand / map_backlog)."""
        self.stats["harvest_borrows"] += 1
        if self.trace is not None and self.trace.serve:
            self.trace.emit(now, "harvest_borrow", {
                "machine": machine, "node": node, "service": service,
                "replica": replica, "signal": signal, "util": util,
                "cores_left": cores_left})

    def harvest_return(self, now: float, *, machine: int, node: int,
                       service: str, replica: int, signal: str,
                       util: float, cores_left: int) -> None:
        """A borrowed core returned to its service (``signal`` names the
        trigger: util_spike / p99_pressure / churn_relief / machine_down)."""
        self.stats["harvest_returns"] += 1
        if self.trace is not None and self.trace.serve:
            self.trace.emit(now, "harvest_return", {
                "machine": machine, "node": node, "service": service,
                "replica": replica, "signal": signal, "util": util,
                "cores_left": cores_left})

    # -- matching ------------------------------------------------------------
    def match(self, now: float, donor_ok=None) -> List[PendingPlug]:
        """Pair AQ/RQ entries per machine; returns newly started hot-plugs.

        ``donor_ok(vm)`` lets the caller veto donors whose offered core got
        re-occupied between the offer and the match."""
        started = []
        for m in sorted(self._aq_nonempty & self._rq_nonempty):
            while self.aq[m] and self.rq[m]:
                parked = self.aq[m].popleft()
                donor = None
                while self.rq[m]:
                    cand = self.rq[m].popleft()
                    self.rq_depth[m] -= 1
                    if (cand != parked.target_vm and self._valid_donor(cand)
                            and (donor_ok is None or donor_ok(cand))):
                        donor = cand
                        break
                    # stale / self-targeted offer: drop it
                if donor is None:
                    self.aq[m].appendleft(parked)
                    break
                if self.vcpus[parked.target_vm] >= self.spec.max_vcpus_per_vm:
                    # target saturated: requeue task, put donor back
                    self.rq[m].append(donor)
                    self.rq_depth[m] += 1
                    self.aq[m].append(parked)
                    break
                self.vcpus[donor] -= 1
                plug = PendingPlug(m, donor, parked.target_vm, parked.task,
                                   now + self.spec.hotplug_latency)
                self.in_flight.append(plug)
                started.append(plug)
                cur = self._parked_entry.get(parked.task)
                live = cur is not None and cur[1] is parked
                self._drop_parked_entry(parked.task, parked)
                if self.adaptive.enabled and live:
                    # a donor match of a *live* park is a win — record it
                    # here: the matched task launches through the plug path,
                    # which never reaches the scheduler's _launch_map
                    # feedback.  A stale entry (its task already resolved
                    # and reported) still gets the donated core, but must
                    # not count a second win for the same park.
                    self.fail_streak[m] = 0
                    self.last_fail[m] = None
                    a = self.adaptive
                    self.park_outcome_ewma = (
                        a.outcome_alpha
                        + (1.0 - a.outcome_alpha) * self.park_outcome_ewma)
                    self.stats["park_wins"] += 1
                    if self.trace is not None and self.trace.parks:
                        self.trace.emit(now, "park_outcome", {
                            "task": parked.task, "job": parked.task.job_id,
                            "machine": m, "won": True, "cause": "donor_match",
                            "ewma": self.park_outcome_ewma})
                self.stats["reconfigurations"] += 1
                self.stats["total_wait"] += now - parked.parked_at
                if self.trace is not None and self.trace.parks:
                    self.trace.emit(now, "reconfig_match", {
                        "task": parked.task, "job": parked.task.job_id,
                        "machine": m, "from_vm": donor,
                        "to_vm": parked.target_vm,
                        "wait": now - parked.parked_at})
            self._aq_sync(m)
            if not self.rq[m]:
                self._rq_nonempty.discard(m)
        return started

    def complete_plugs(self, now: float) -> List[PendingPlug]:
        """Hot-plugs whose latency elapsed; caller launches the task."""
        done = [p for p in self.in_flight if p.ready_at <= now]
        self.in_flight = [p for p in self.in_flight if p.ready_at > now]
        for p in done:
            self.vcpus[p.to_vm] += 1
        return done

    def expire_stale(self, now: float) -> List[ParkedTask]:
        """Parked tasks past their wait bound -> hand back for remote launch.

        The park heap makes the common "nothing expired" case O(1); popped
        entries whose task already left its AQ (matched / cancelled) are
        discarded.  Legacy mode keys the heap by park time against the fixed
        ``max_wait``; adaptive mode keys it by each entry's absolute expiry
        time (per-park bounds vary, so park order is not expiry order)."""
        out = []
        heap = self._park_heap
        adaptive = self.adaptive.enabled
        # NB: `now - parked_at > max_wait` is the seed's exact expression;
        # rewriting it (as `parked_at < now - max_wait`, or against the
        # precomputed `parked_at + wait_bound` heap key) is NOT
        # float-identical at the boundary — and the boundary is the common
        # case, because parks and expiry checks share the heartbeat grid.
        # Adaptive mode therefore only *orders* by the expiry key and pops
        # with the seed's expression against each entry's own bound.
        while heap and (now - heap[0][3].parked_at > heap[0][3].wait_bound
                        if adaptive else now - heap[0][0] > self.max_wait):
            _, _, m, item = heapq.heappop(heap)
            q = self.aq[m]
            if not any(it is item for it in q):
                continue            # already matched or cancelled
            q.remove(item)
            self._aq_sync(m)
            cur = self._parked_entry.get(item.task)
            live = cur is not None and cur[1] is item
            self._drop_parked_entry(item.task, item)
            if adaptive and live:
                # outcome unknown yet: the task may still launch locally on
                # its data node (the reservation paid) or remotely (it
                # didn't) — the scheduler reports which via
                # note_park_outcome.  A stale entry's task already resolved
                # and reported, so recording it here would leak the dict
                # entry forever (the task never launches again).
                self._expired_machine[item.task] = m
            out.append(item)
            self.stats["expired"] += 1
            if self.trace is not None and self.trace.parks:
                self.trace.emit(now, "park_expired", {
                    "task": item.task, "job": item.task.job_id,
                    "machine": m, "parked_at": item.parked_at,
                    "waited": now - item.parked_at,
                    "wait_bound": item.wait_bound})
        return out

    def note_park_outcome(self, task: TaskId, now: float, won: bool) -> None:
        """Scheduler feedback closing the park-admission loop: ``task`` —
        parked (possibly expired) earlier — just launched.  ``won`` means it
        ran data-locally (reservation or match paid); a remote launch after
        a full-patience wait is the genuine starvation signal that feeds the
        machine's fail streak.

        The park index entry is dropped here: the park is *resolved*, and a
        leftover AQ entry is from now on pure-stale — a later donor match
        of it must not count a second win for the same park."""
        hit = self._parked_entry.get(task)
        if hit is not None:
            self._drop_parked_entry(task, hit[1])
            m = hit[0]
        else:
            m = self._expired_machine.pop(task, None)
        if m is None:
            return
        a = self.adaptive
        self.park_outcome_ewma = (a.outcome_alpha * (1.0 if won else 0.0)
                                  + (1.0 - a.outcome_alpha)
                                  * self.park_outcome_ewma)
        if won:
            self.fail_streak[m] = 0
            self.last_fail[m] = None    # full park patience restored
            self.stats["park_wins"] += 1
        else:
            self.fail_streak[m] += 1
            self.last_fail[m] = now
            self.stats["park_losses"] += 1
        if self.trace is not None and self.trace.parks:
            self.trace.emit(now, "park_outcome", {
                "task": task, "job": task.job_id, "machine": m,
                "won": won, "cause": "reservation" if won else "remote",
                "fail_streak": self.fail_streak[m],
                "ewma": self.park_outcome_ewma})

    def discard_park_outcome(self, task: TaskId, now: float) -> None:
        """Crash-discounted resolution of a pending park outcome: ``task``
        just launched remotely because every live replica of its data is
        down — the park lost to the crash, not to core starvation, so the
        fail-streak and win-rate gates must not be charged
        (``AdaptiveConfig.crash_discount``).  The park index entry is
        dropped exactly as in :meth:`note_park_outcome` so the resolution
        stays one-shot."""
        hit = self._parked_entry.get(task)
        if hit is not None:
            self._drop_parked_entry(task, hit[1])
            m = hit[0]
        else:
            m = self._expired_machine.pop(task, None)
        if m is None:
            return
        self.stats["park_crash_discounted"] += 1
        if self.trace is not None and self.trace.parks:
            self.trace.emit(now, "park_outcome", {
                "task": task, "job": task.job_id, "machine": m,
                "won": False, "cause": "crash_discount",
                "fail_streak": self.fail_streak[m],
                "ewma": self.park_outcome_ewma})

    # -- adaptive pressure queries (see AdaptiveConfig) ---------------------
    def predicted_core_wait(self, machine: int, now: float) -> Optional[float]:
        """Best-effort seconds until ``machine`` can serve a parked task a
        core (donor match or its own freed slot), from the incremental
        pressure signals.  ``None`` = no signal yet (optimistic: the caller
        parks as a probe)."""
        if self.rq_depth[machine] > 0 and any(
                self._valid_donor(c) for c in self.rq[machine]):
            return self.spec.hotplug_latency    # a live offer is queued
        free = self.free_ewma[machine]
        if free is None:
            return None
        # cores recycle every ~free seconds; each AQ entry ahead plus the
        # machine's own local backlog stretches the wait, so the queue depth
        # scales the estimate (the "AQ wait distribution" signal)
        return free * (1 + len(self.aq[machine]))

    def _effective_streak(self, machine: int, now: float) -> int:
        """Fail streak with cool-down: after ``fail_cooldown`` quiet seconds
        the machine earns a fresh probe (otherwise a suspended machine could
        never re-qualify — no parks, no outcomes, no signal).  ``last_fail``
        is kept, so post-cooldown probes still run at floor patience until
        one actually pays off."""
        streak = self.fail_streak[machine]
        if streak and self.last_fail[machine] is not None \
                and now - self.last_fail[machine] > self.adaptive.fail_cooldown:
            streak = self.fail_streak[machine] = 0
        return streak

    def park_decision(self, machine: int, now: float,
                      breakeven: float) -> Tuple[bool, float]:
        """Adaptive park admission for a task whose remote launch would cost
        ``breakeven`` extra seconds: returns ``(should_park, wait_bound)``.

        Declines when the machine's recent parks keep ending in remote
        launches (fail streak at the limit) or the predicted core wait
        exceeds the (margin-scaled) break-even — the caller then launches
        remotely immediately.  A machine that has lost a park since its last
        win only earns short floor-patience probes; full patience returns
        once a probe pays off."""
        a = self.adaptive
        streak = self._effective_streak(machine, now)
        if streak >= a.fail_streak_limit:
            self.stats["park_declined"] += 1
            if self.trace is not None:
                self.last_decline = ("fail_streak", {
                    "streak": streak, "limit": a.fail_streak_limit})
            return False, 0.0
        allowance = a.breakeven_margin * breakeven
        pred = self.predicted_core_wait(machine, now)
        if pred is not None and pred + self.spec.hotplug_latency > allowance:
            self.stats["park_declined"] += 1
            if self.trace is not None:
                self.last_decline = ("predicted_wait", {
                    "predicted": pred, "allowance": allowance,
                    "breakeven": breakeven})
            return False, 0.0
        probing = False
        if self.park_outcome_ewma < a.park_win_floor:
            # cluster-wide, parks have been ending remote: suspend parking,
            # letting one cheap probe through per cooldown so recovery
            # (wins push the EWMA back up) is still detectable
            if self._last_park is not None \
                    and now - self._last_park < a.fail_cooldown:
                self.stats["park_declined"] += 1
                if self.trace is not None:
                    self.last_decline = ("win_floor", {
                        "ewma": self.park_outcome_ewma,
                        "floor": a.park_win_floor})
                return False, 0.0
            probing = True
        base = (a.max_wait_floor
                if probing or self.last_fail[machine] is not None
                else self.max_wait)
        bound = min(a.max_wait_ceiling, max(a.max_wait_floor, base))
        self._last_park = now
        return True, bound

    def next_event_time(self) -> Optional[float]:
        if not self.in_flight:
            return None
        return min(p.ready_at for p in self.in_flight)

    @property
    def total_vcpus(self) -> int:
        return sum(self.vcpus) + len(self.in_flight)
