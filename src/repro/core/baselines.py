"""Baseline schedulers the paper evaluates against.

* ``FairScheduler`` — Hadoop Fair Scheduler semantics [paper ref 3]: equal
  instantaneous share per active job; on each heartbeat the job furthest
  below its fair share is served first.  Optional *delay scheduling*
  [Zaharia, EuroSys'10 — paper ref 16]: a job skips up to ``locality_delay``
  scheduling opportunities while it has no local task on the offered node.
* ``FIFOScheduler`` — Hadoop default: submission order.

Neither baseline uses deadlines, the resource estimator, or the
reconfigurator — that is the paper's point of comparison.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.scheduler import Launch, SchedulerBase
from repro.core.types import ClusterSpec, JobRuntime, TaskId, TaskKind


class FairScheduler(SchedulerBase):
    name = "fair"

    def __init__(self, spec: ClusterSpec, locality_delay: int = 0):
        super().__init__(spec)
        self.locality_delay = locality_delay
        self._skips: Dict[str, int] = {}

    def _running_slots(self, job: JobRuntime) -> int:
        return len(job.running_map) + len(job.running_reduce)

    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        out: List[Launch] = []
        while free_map > 0 or free_reduce > 0:
            jobs = [j for j in self.active_jobs()]
            if not jobs:
                break
            # deficit order: fewest running tasks relative to fair share
            jobs.sort(key=lambda j: (self._running_slots(j),
                                     j.spec.submit_time))
            launched = False
            for job in jobs:
                jid = job.spec.job_id
                if free_map > 0 and not job.map_finished:
                    local = self._local_map_candidates(job, node)
                    if local:
                        idx = local[0]
                        self._skips[jid] = 0
                        t = TaskId(jid, TaskKind.MAP, idx)
                        out.append(Launch(t, node, local=True))
                        job.running_map[idx] = node
                        job.local_map_launches += 1
                        free_map -= 1
                        launched = True
                        break
                    unstarted = self._unstarted_map_tasks(job)
                    if unstarted:
                        if self._skips.get(jid, 0) < self.locality_delay:
                            self._skips[jid] = self._skips.get(jid, 0) + 1
                            continue   # delay scheduling: wait for locality
                        self._skips[jid] = 0
                        idx = unstarted[0]
                        t = TaskId(jid, TaskKind.MAP, idx)
                        out.append(Launch(t, node, local=False))
                        job.running_map[idx] = node
                        job.remote_map_launches += 1
                        free_map -= 1
                        launched = True
                        break
                if free_reduce > 0 and job.map_finished and not job.finished:
                    unstarted = self._unstarted_reduce_tasks(job)
                    if unstarted:
                        idx = unstarted[0]
                        t = TaskId(jid, TaskKind.REDUCE, idx)
                        out.append(Launch(t, node, local=True))
                        job.running_reduce[idx] = node
                        free_reduce -= 1
                        launched = True
                        break
            if not launched:
                break
        return out


class FIFOScheduler(SchedulerBase):
    name = "fifo"

    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        out: List[Launch] = []
        for jid in self.order:
            job = self.jobs[jid]
            if job.finished:
                continue
            while free_map > 0 and not job.map_finished:
                local = self._local_map_candidates(job, node)
                cand = local or self._unstarted_map_tasks(job)
                if not cand:
                    break
                idx = cand[0]
                is_local = bool(local)
                out.append(Launch(TaskId(jid, TaskKind.MAP, idx), node,
                                  local=is_local))
                job.running_map[idx] = node
                if is_local:
                    job.local_map_launches += 1
                else:
                    job.remote_map_launches += 1
                free_map -= 1
            while (free_reduce > 0 and job.map_finished and not job.finished):
                unstarted = self._unstarted_reduce_tasks(job)
                if not unstarted:
                    break
                idx = unstarted[0]
                out.append(Launch(TaskId(jid, TaskKind.REDUCE, idx), node,
                                  local=True))
                job.running_reduce[idx] = node
                free_reduce -= 1
            if free_map <= 0 and free_reduce <= 0:
                break
        return out
