"""Baseline schedulers the paper evaluates against.

* ``FairScheduler`` — Hadoop Fair Scheduler semantics [paper ref 3]: equal
  instantaneous share per active job; on each heartbeat the job furthest
  below its fair share is served first.  Optional *delay scheduling*
  [Zaharia, EuroSys'10 — paper ref 16]: a job skips up to ``locality_delay``
  scheduling opportunities while it has no local task on the offered node.
* ``FIFOScheduler`` — Hadoop default: submission order.

Neither baseline uses deadlines, the resource estimator, or the
reconfigurator — that is the paper's point of comparison.

Both run on the indexed ``SchedulerBase``: candidate lookup is amortized
O(1) via the per-job pending heaps and the per-node local-task index.  The
Fair deficit order is kept as a sorted list keyed by
``(running_slots, submit_time, admission_seq)`` — the seed implementation
re-sorted the submission-ordered active list with a stable sort on
``(running_slots, submit_time)`` after every launch, which is exactly this
total order, so only the launched job needs re-insertion (one bisect)
instead of an O(J log J) sort per launched task.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Tuple

from repro.core.scheduler import Launch, SchedulerBase
from repro.core.types import ClusterSpec, JobRuntime, TaskId, TaskKind


class FairScheduler(SchedulerBase):
    name = "fair"

    def __init__(self, spec: ClusterSpec, locality_delay: int = 0):
        super().__init__(spec)
        self.locality_delay = locality_delay
        self._skips: Dict[str, int] = {}

    def _running_slots(self, job: JobRuntime) -> int:
        return len(job.running_map) + len(job.running_reduce)

    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        if ((free_map <= 0 or self.total_pending_maps == 0)
                and (free_reduce <= 0 or self.ready_pending_reduces == 0)):
            return []
        jobs = self.active_jobs()
        if not jobs:
            return []
        out: List[Launch] = []
        by_seq = {j.seq: j for j in jobs}
        # deficit order: fewest running tasks relative to fair share
        entries: List[Tuple[int, float, int]] = sorted(
            (self._running_slots(j), j.spec.submit_time, j.seq) for j in jobs)
        while free_map > 0 or free_reduce > 0:
            launched: Optional[int] = None     # position in entries
            for pos, (_, _, seq) in enumerate(entries):
                job = by_seq[seq]
                jid = job.spec.job_id
                if free_map > 0 and not job.map_done:
                    idx = job.first_local_pending_map(node)
                    if idx is not None:
                        self._skips[jid] = 0
                        t = TaskId(jid, TaskKind.MAP, idx)
                        out.append(Launch(t, node, local=True))
                        self._start_map(job, idx, node)
                        job.local_map_launches += 1
                        free_map -= 1
                        launched = pos
                        break
                    if job.pending_map:
                        if self._skips.get(jid, 0) < self.locality_delay:
                            self._skips[jid] = self._skips.get(jid, 0) + 1
                            continue   # delay scheduling: wait for locality
                        self._skips[jid] = 0
                        idx = job.first_pending_map()
                        t = TaskId(jid, TaskKind.MAP, idx)
                        out.append(Launch(t, node, local=False))
                        self._start_map(job, idx, node)
                        job.remote_map_launches += 1
                        free_map -= 1
                        launched = pos
                        break
                if free_reduce > 0 and job.map_done and not job.all_done:
                    if job.pending_reduce:
                        idx = job.first_pending_reduce()
                        t = TaskId(jid, TaskKind.REDUCE, idx)
                        out.append(Launch(t, node, local=True))
                        self._start_reduce(job, idx, node)
                        free_reduce -= 1
                        launched = pos
                        break
            if launched is None:
                break
            _, _, seq = entries.pop(launched)
            job = by_seq[seq]
            bisect.insort(entries, (self._running_slots(job),
                                    job.spec.submit_time, seq))
        return out


class FIFOScheduler(SchedulerBase):
    name = "fifo"

    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        if ((free_map <= 0 or self.total_pending_maps == 0)
                and (free_reduce <= 0 or self.ready_pending_reduces == 0)):
            return []
        out: List[Launch] = []
        for job in self.active_jobs():
            jid = job.spec.job_id
            while free_map > 0 and not job.map_done:
                local_idx = job.first_local_pending_map(node)
                idx = (local_idx if local_idx is not None
                       else job.first_pending_map())
                if idx is None:
                    break
                is_local = local_idx is not None
                out.append(Launch(TaskId(jid, TaskKind.MAP, idx), node,
                                  local=is_local))
                self._start_map(job, idx, node)
                if is_local:
                    job.local_map_launches += 1
                else:
                    job.remote_map_launches += 1
                free_map -= 1
            while (free_reduce > 0 and job.map_done and not job.all_done):
                if not job.pending_reduce:
                    break
                idx = job.first_pending_reduce()
                out.append(Launch(TaskId(jid, TaskKind.REDUCE, idx), node,
                                  local=True))
                self._start_reduce(job, idx, node)
                free_reduce -= 1
            if free_map <= 0 and free_reduce <= 0:
                break
        return out
