"""Multi-tenant serving layer: latency-SLO request streams co-located
with the batch MapReduce workload on one reconfigurable fleet.

The ``ServeConfig`` on ``ClusterSpec`` declares long-lived services; each
replica pins vCPUs on one VM (round-robin over machines, then VMs) and
receives an open-arrival request stream — a non-homogeneous Poisson
process with the same diurnal/flash-crowd shape as
``repro.simcluster.traces.ArrivalConfig``, thinned incrementally from a
dedicated ``random.Random(f"{seed}:serve:{service}:{replica}")`` stream.
Zero draws come from the decision RNG, and the arrival/service-time
schedule is a pure function of (config, seed) — byte-reproducible per
(config, seed, workload, policy), independent of scheduler decisions.

Per-request queueing is folded incrementally on the sim's serve tick
(one global chain at the heartbeat interval): each replica is an FCFS
G/G/c queue over its effective cores, arrivals since the last tick are
drained through per-core free-at heaps, and the sojourn times feed p50/
p99 latency and SLO-violation counters per tick plus exact whole-run
percentiles at the end.

The Borg-style **harvest** component (``PolicySpec`` axis ``harvest``,
accounted by ``core.reconfigurator``) runs on the same tick: a replica
whose utilization EWMA sits below ``ServeConfig.harvest_headroom`` lends
one pinned core per tick to the batch side — preferring machines whose
reconfigurator AQ holds parked maps, which the freed capacity plugs on
the next heartbeat — and takes cores back preemptively when the EWMA
crosses ``harvest_return_util`` or the tick's p99 reaches the SLO,
before the whole-run SLO is breached.  Harvesting stands down entirely
under the scheduler's churn-relief signal (read-only probe; a churning
fleet returns every borrowed core with the ``churn_relief`` signal), and
a crashed machine drops its service replicas, returning their borrowed
cores with the ``machine_down`` signal.
"""
from __future__ import annotations

import math
import random
from heapq import heappop, heappush
from typing import Dict, List, Optional, Tuple

from repro.core.types import ClusterSpec, ServiceSpec

TWO_PI = 2.0 * math.pi

#: harvest trigger signals, by direction (documented vocabulary for the
#: ``harvest_borrow``/``harvest_return`` trace events)
BORROW_SIGNALS: Tuple[str, ...] = ("parked_demand", "map_backlog")
RETURN_SIGNALS: Tuple[str, ...] = ("churn_relief", "util_spike",
                                   "p99_pressure", "machine_down")


class ServiceReplica:
    """One service instance: pinned cores on one VM plus its private
    request stream and FCFS multi-server queue state."""

    __slots__ = ("svc", "index", "machine", "node", "rng",
                 "next_base", "buf", "free", "borrowed", "down", "up_since",
                 "requests", "shed", "violations", "latencies",
                 "util_ewma", "borrows", "returns")

    def __init__(self, svc: ServiceSpec, index: int, machine: int,
                 node: int, seed: int) -> None:
        self.svc = svc
        self.index = index
        self.machine = machine
        self.node = node
        # dedicated stream: zero draws from the decision RNG, so the
        # request schedule is a pure function of (config, seed)
        self.rng = random.Random(f"{seed}:serve:{svc.name}:{index}")
        self.next_base = 0.0            # thinning process position
        self.buf: List[Tuple[float, float]] = []   # (arrival, service_time)
        self.free: List[float] = [0.0] * svc.vcpus  # per-core free-at heap
        self.borrowed = 0               # cores currently lent to batch
        self.down = False
        self.up_since = 0.0
        self.requests = 0
        self.shed = 0                   # arrivals hitting a down replica
        self.violations = 0             # sojourn > slo_p99_ms
        self.latencies: List[float] = []    # sojourn seconds, whole run
        self.util_ewma: Optional[float] = None
        self.borrows = 0
        self.returns = 0

    # -- arrival stream ------------------------------------------------------
    def rate_at(self, t: float) -> float:
        s = self.svc
        if s.diurnal_amplitude <= 0.0:
            return s.base_rps
        return s.base_rps * (1.0 + s.diurnal_amplitude * math.sin(
            TWO_PI * (t + s.diurnal_phase) / s.diurnal_period))

    def gen_until(self, until: float) -> None:
        """Advance the thinned Poisson base process (plus flash-crowd
        riders) through ``until``, buffering (arrival, service_time)."""
        s = self.svc
        rng = self.rng
        lam_max = s.base_rps * (1.0 + s.diurnal_amplitude)
        while self.next_base <= until:
            self.next_base += rng.expovariate(lam_max)
            t = self.next_base
            if rng.random() * lam_max > self.rate_at(t):
                continue
            self.buf.append((t, rng.expovariate(1.0 / s.service_time)))
            if s.burst_prob > 0.0 and rng.random() < s.burst_prob:
                extra = 1 + int(rng.expovariate(1.0 / s.burst_size_mean))
                tb = t
                for _ in range(extra):
                    tb += rng.expovariate(1.0 / s.burst_stagger)
                    self.buf.append((tb, rng.expovariate(1.0 / s.service_time)))

    # -- queue ---------------------------------------------------------------
    @property
    def cores(self) -> int:
        """Effective serving cores (pinned minus borrowed)."""
        return self.svc.vcpus - self.borrowed

    def drain(self, now: float) -> Tuple[int, int, List[float], float]:
        """Process buffered arrivals <= ``now`` through the FCFS c-server
        queue; returns (served, shed, interval sojourns, busy seconds)."""
        self.buf.sort()
        cut = 0
        for cut, (t, _) in enumerate(self.buf + [(math.inf, 0.0)]):
            if t > now:
                break
        batch, self.buf = self.buf[:cut], self.buf[cut:]
        served = shed = 0
        samples: List[float] = []
        busy = 0.0
        slo_s = self.svc.slo_p99_ms / 1000.0
        free = self.free
        for t, svc_t in batch:
            if self.down or t < self.up_since:
                shed += 1
                continue
            start = heappop(free)
            if start < t:
                start = t
            fin = start + svc_t
            heappush(free, fin)
            lat = fin - t
            samples.append(lat)
            busy += svc_t
            served += 1
            if lat > slo_s:
                self.violations += 1
        self.requests += served
        self.shed += shed
        self.latencies.extend(samples)
        return served, shed, samples, busy


class ServingLayer:
    """All service replicas plus the per-node pinned-core accounting the
    engine's ``map_capacity`` subtracts, the per-tick latency/SLO fold,
    and the harvest decision loop."""

    def __init__(self, spec: ClusterSpec, seed: int, *,
                 sched=None, reconfig=None, trace=None) -> None:
        self.spec = spec
        self.serve = spec.serve
        self.sched = sched
        self.reconfig = reconfig
        self.trace = trace
        # harvest runs only when the policy declares the component *and*
        # the reconfigurator (which accounts it) is attached
        self.harvest_on = bool(getattr(sched, "harvest", False)
                               and reconfig is not None)
        self.replicas: List[ServiceReplica] = []
        self.reserved: List[int] = [0] * spec.num_nodes
        self.by_machine: Dict[int, List[ServiceReplica]] = {}
        self.last_tick = 0.0
        self.log: List[list] = []        # per-tick per-replica entries
        g = 0
        for svc in self.serve.services:
            for r in range(svc.replicas):
                machine = g % spec.num_machines
                node = (machine * spec.vms_per_machine
                        + (g // spec.num_machines) % spec.vms_per_machine)
                rep = ServiceReplica(svc, r, machine, node, seed)
                if self.reserved[node] + svc.vcpus > spec.base_map_slots:
                    raise ValueError(
                        f"service {svc.name!r} replica {r} oversubscribes "
                        f"VM {node}: {self.reserved[node]} + {svc.vcpus} "
                        f"pinned cores > base_map_slots="
                        f"{spec.base_map_slots}")
                self.reserved[node] += svc.vcpus
                self.replicas.append(rep)
                self.by_machine.setdefault(machine, []).append(rep)
                g += 1

    # -- churn-relief stand-down (read-only probe of the PR 8 signal) -------
    def _churn_relief(self) -> bool:
        s = self.sched
        adaptive = getattr(s, "adaptive", None)
        if adaptive is None or not adaptive.crash_discount:
            return False
        return bool(getattr(s, "_relief_sticky", False)
                    or getattr(s, "_machines_down", 0) > 0
                    or getattr(s, "_repend_debt", ()))

    # -- the serve tick ------------------------------------------------------
    def tick(self, now: float) -> None:
        interval = now - self.last_tick
        if interval <= 0.0:
            return
        relief = self.harvest_on and self._churn_relief()
        alpha = self.serve.harvest_util_alpha
        for rep in self.replicas:
            rep.gen_until(now)
            served, shed, samples, busy = rep.drain(now)
            cores = rep.cores
            util = busy / (cores * interval) if cores > 0 else 0.0
            if not rep.down:
                rep.util_ewma = (util if rep.util_ewma is None else
                                 alpha * util + (1.0 - alpha) * rep.util_ewma)
            if samples:
                from repro.experiments.stats import percentile
                p50_ms = percentile(samples, 50.0) * 1000.0
                p99_ms = percentile(samples, 99.0) * 1000.0
            else:
                p50_ms = p99_ms = 0.0
            if self.harvest_on and not rep.down:
                self._harvest(rep, now, p99_ms, relief)
            self.log.append([now, rep.svc.name, rep.index, served, shed,
                             p50_ms, p99_ms,
                             rep.util_ewma if rep.util_ewma is not None
                             else 0.0, rep.cores])
            if self.trace is not None and self.trace.serve:
                self.trace.emit(now, "serve_tick", {
                    "service": rep.svc.name, "replica": rep.index,
                    "machine": rep.machine, "node": rep.node,
                    "served": served, "shed": shed,
                    "p50_ms": p50_ms, "p99_ms": p99_ms,
                    "slo_p99_ms": rep.svc.slo_p99_ms,
                    "util": util, "cores": rep.cores, "down": rep.down})
        self.last_tick = now

    # -- harvest (Borg-style core borrowing) ---------------------------------
    def _harvest(self, rep: ServiceReplica, now: float, p99_ms: float,
                 relief: bool) -> None:
        cfg = self.serve
        if relief:
            # churn relief: stand down — no borrowing, and give back one
            # borrowed core per tick until the service is whole again
            if rep.borrowed > 0:
                self._return_core(rep, now, "churn_relief")
            return
        if rep.borrowed > 0 and (
                (rep.util_ewma or 0.0) > cfg.harvest_return_util
                or p99_ms >= rep.svc.slo_p99_ms):
            # preemptive return on a load spike, before the whole-run p99
            # SLO is breached
            signal = ("util_spike"
                      if (rep.util_ewma or 0.0) > cfg.harvest_return_util
                      else "p99_pressure")
            self._return_core(rep, now, signal)
            return
        if (rep.cores > 1 and rep.free and rep.free[0] <= now
                and (rep.util_ewma or 0.0) < cfg.harvest_headroom):
            # an idle core under the headroom bar: lend it where the batch
            # side has demand — parked maps on this machine first
            if self.reconfig.aq[rep.machine]:
                signal = "parked_demand"
            elif getattr(self.sched, "total_pending_maps", 0) > 0:
                signal = "map_backlog"
            else:
                return
            self._borrow_core(rep, now, signal)

    def _borrow_core(self, rep: ServiceReplica, now: float,
                     signal: str) -> None:
        heappop(rep.free)                # the idle core leaves the queue
        rep.borrowed += 1
        rep.borrows += 1
        self.reserved[rep.node] -= 1
        self.reconfig.harvest_borrow(
            now, machine=rep.machine, node=rep.node, service=rep.svc.name,
            replica=rep.index, signal=signal,
            util=rep.util_ewma if rep.util_ewma is not None else 0.0,
            cores_left=rep.cores)

    def _return_core(self, rep: ServiceReplica, now: float,
                     signal: str) -> None:
        # the core rejoins the queue after the hot-plug latency; the batch
        # side stops launching on it immediately (map capacity drops now —
        # a map already running simply drains without replacement)
        heappush(rep.free, now + self.spec.hotplug_latency)
        rep.borrowed -= 1
        rep.returns += 1
        self.reserved[rep.node] += 1
        self.reconfig.harvest_return(
            now, machine=rep.machine, node=rep.node, service=rep.svc.name,
            replica=rep.index, signal=signal,
            util=rep.util_ewma if rep.util_ewma is not None else 0.0,
            cores_left=rep.cores)

    # -- chaos interaction ---------------------------------------------------
    def machine_down(self, machine: int, now: float) -> None:
        """A crashed machine drops its service replicas: queued and
        in-window arrivals shed, borrowed cores return immediately."""
        for rep in self.by_machine.get(machine, ()):
            while rep.borrowed > 0:
                self._return_core(rep, now, "machine_down")
            rep.down = True

    def machine_restarted(self, machine: int, now: float) -> None:
        for rep in self.by_machine.get(machine, ()):
            rep.down = False
            rep.up_since = now
            rep.free = [now] * rep.svc.vcpus
            rep.util_ewma = None

    # -- result fold ---------------------------------------------------------
    def outstanding_borrows(self) -> int:
        return sum(rep.borrowed for rep in self.replicas)

    def stats(self) -> Dict[str, object]:
        """Whole-run serving metrics: exact per-service p50/p99 over every
        request sample, SLO-violation counts, and harvest totals."""
        from repro.experiments.stats import latency_summary
        services: Dict[str, Dict[str, object]] = {}
        all_lat: List[float] = []
        tot_req = tot_shed = tot_viol = tot_bor = tot_ret = 0
        for svc in self.serve.services:
            reps = [r for r in self.replicas if r.svc is svc]
            lat: List[float] = []
            for r in reps:
                lat.extend(r.latencies)
            summary = latency_summary(lat)
            util = [r.util_ewma for r in reps if r.util_ewma is not None]
            requests = sum(r.requests for r in reps)
            services[svc.name] = {
                "replicas": len(reps),
                "vcpus": svc.vcpus,
                "requests": requests,
                "shed": sum(r.shed for r in reps),
                "violations": sum(r.violations for r in reps),
                "violation_rate": (sum(r.violations for r in reps) / requests
                                   if requests else 0.0),
                "slo_p99_ms": svc.slo_p99_ms,
                "p50_ms": summary["p50"] * 1000.0,
                "p99_ms": summary["p99"] * 1000.0,
                "mean_ms": summary["mean"] * 1000.0,
                "util_ewma": sum(util) / len(util) if util else 0.0,
                "borrows": sum(r.borrows for r in reps),
                "returns": sum(r.returns for r in reps),
            }
            all_lat.extend(lat)
            tot_req += requests
            tot_shed += sum(r.shed for r in reps)
            tot_viol += sum(r.violations for r in reps)
            tot_bor += sum(r.borrows for r in reps)
            tot_ret += sum(r.returns for r in reps)
        summary = latency_summary(all_lat)
        return {
            "services": services,
            "requests": tot_req,
            "shed": tot_shed,
            "violations": tot_viol,
            "violation_rate": tot_viol / tot_req if tot_req else 0.0,
            "p50_ms": summary["p50"] * 1000.0,
            "p99_ms": summary["p99"] * 1000.0,
            "harvest_borrows": tot_bor,
            "harvest_returns": tot_ret,
            "outstanding_borrows": self.outstanding_borrows(),
        }
