"""Frozen copy of the seed (pre-index) scheduling/simulation engine.

This module preserves the original O(jobs × tasks) hot paths exactly as they
shipped in the seed commit: list-rebuild task scans, full cross-job
``has_local_pending`` walks, per-heartbeat speculation rescans, and the
all-machines reconfigurator sweeps.  It exists for two reasons only:

* the decision-parity test (``tests/test_parity.py``) pins the optimized
  engine to these semantics — fixed-seed paper-cluster runs must reproduce
  the legacy ``SimResult`` metrics exactly;
* ``benchmarks/bench_sim.py`` measures the indexed engine's speedup against
  this baseline.

Do not "fix" or optimize anything here; behavioural drift silently weakens
the parity contract.  The only differences from the seed files are renames
(``Legacy*`` prefixes) and imports.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

import random

from repro.core.estimator import OnlineEstimator
from repro.core.types import (ClusterSpec, JobRuntime, JobSpec, TaskId,
                              TaskKind)
from repro.core.scheduler import Launch


# ---------------------------------------------------------------------------
# Reconfigurator (seed core/reconfigurator.py)
# ---------------------------------------------------------------------------
@dataclass
class LegacyParkedTask:
    task: TaskId
    target_vm: int
    parked_at: float


@dataclass
class LegacyPendingPlug:
    machine: int
    from_vm: int
    to_vm: int
    task: TaskId
    ready_at: float


class LegacyReconfigurator:
    """Seed AQ/RQ tracker: every query scans full queues / all machines."""

    def __init__(self, spec: ClusterSpec, max_wait: float = 15.0):
        self.spec = spec
        self.max_wait = max_wait
        self.vcpus: List[int] = [spec.base_map_slots] * spec.num_nodes
        self.aq: List[Deque[LegacyParkedTask]] = [
            deque() for _ in range(spec.num_machines)]
        self.rq: List[Deque[int]] = [deque() for _ in range(spec.num_machines)]
        self.in_flight: List[LegacyPendingPlug] = []
        self.validator: Optional[Callable[[int], bool]] = None
        self.stats = {"reconfigurations": 0, "parked": 0, "expired": 0,
                      "total_wait": 0.0}

    def _valid_donor(self, vm: int) -> bool:
        if self.vcpus[vm] <= self.spec.min_vcpus_per_vm:
            return False
        return self.validator(vm) if self.validator is not None else True

    def aq_len(self, vm: int) -> int:
        return sum(1 for t in self.aq[self.spec.machine_of(vm)]
                   if t.target_vm == vm)

    def rq_len(self, vm: int) -> int:
        return sum(1 for cand in self.rq[self.spec.machine_of(vm)]
                   if cand != vm and self._valid_donor(cand))

    def park_task(self, task: TaskId, target_vm: int, now: float) -> None:
        self.aq[self.spec.machine_of(target_vm)].append(
            LegacyParkedTask(task, target_vm, now))
        self.stats["parked"] += 1

    def release_core(self, vm: int, now: float) -> None:
        if self.vcpus[vm] <= self.spec.min_vcpus_per_vm:
            return
        self.rq[self.spec.machine_of(vm)].append(vm)

    def cancel_parked(self, task: TaskId) -> bool:
        for q in self.aq:
            for item in list(q):
                if item.task == task:
                    q.remove(item)
                    return True
        return False

    def match(self, now: float, donor_ok=None) -> List[LegacyPendingPlug]:
        started = []
        for m in range(self.spec.num_machines):
            while self.aq[m] and self.rq[m]:
                parked = self.aq[m].popleft()
                donor = None
                while self.rq[m]:
                    cand = self.rq[m].popleft()
                    if (cand != parked.target_vm and self._valid_donor(cand)
                            and (donor_ok is None or donor_ok(cand))):
                        donor = cand
                        break
                if donor is None:
                    self.aq[m].appendleft(parked)
                    break
                if self.vcpus[parked.target_vm] >= self.spec.max_vcpus_per_vm:
                    self.rq[m].append(donor)
                    self.aq[m].append(parked)
                    break
                self.vcpus[donor] -= 1
                plug = LegacyPendingPlug(m, donor, parked.target_vm,
                                         parked.task,
                                         now + self.spec.hotplug_latency)
                self.in_flight.append(plug)
                started.append(plug)
                self.stats["reconfigurations"] += 1
                self.stats["total_wait"] += now - parked.parked_at
        return started

    def complete_plugs(self, now: float) -> List[LegacyPendingPlug]:
        done = [p for p in self.in_flight if p.ready_at <= now]
        self.in_flight = [p for p in self.in_flight if p.ready_at > now]
        for p in done:
            self.vcpus[p.to_vm] += 1
        return done

    def expire_stale(self, now: float) -> List[LegacyParkedTask]:
        out = []
        for q in self.aq:
            for item in list(q):
                if now - item.parked_at > self.max_wait:
                    q.remove(item)
                    out.append(item)
                    self.stats["expired"] += 1
        return out

    @property
    def total_vcpus(self) -> int:
        return sum(self.vcpus) + len(self.in_flight)


# ---------------------------------------------------------------------------
# Schedulers (seed core/scheduler.py + core/baselines.py)
# ---------------------------------------------------------------------------
class LegacySchedulerBase:
    """Seed bookkeeping: unstarted sets rebuilt by scanning range(u_m)."""

    name = "base"
    uses_reconfig = False

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        self.jobs: Dict[str, JobRuntime] = {}
        self.order: List[str] = []

    def job_added(self, job: JobSpec, now: float) -> None:
        rt = JobRuntime(spec=job)
        self.jobs[job.job_id] = rt
        self.order.append(job.job_id)
        self.on_job_added(rt, now)

    def on_job_added(self, job: JobRuntime, now: float) -> None:
        pass

    def task_started(self, task: TaskId, node: int, now: float) -> None:
        job = self.jobs[task.job_id]
        if task.kind == TaskKind.MAP:
            job.running_map[task.index] = node
        else:
            job.running_reduce[task.index] = node

    def task_finished(self, task: TaskId, node: int, now: float,
                      duration: float) -> None:
        job = self.jobs[task.job_id]
        if task.kind == TaskKind.MAP:
            job.running_map.pop(task.index, None)
            job.completed_map.add(task.index)
            job.map_durations.append(duration)
        else:
            job.running_reduce.pop(task.index, None)
            job.completed_reduce.add(task.index)
            job.reduce_durations.append(duration)
        if job.finished and job.finish_time is None:
            job.finish_time = now
        self.on_task_finished(job, task, now)

    def on_task_finished(self, job: JobRuntime, task: TaskId,
                         now: float) -> None:
        pass

    def _unstarted_map_tasks(self, job: JobRuntime) -> List[int]:
        done = job.completed_map
        running = job.running_map
        return [i for i in range(job.spec.u_m)
                if i not in done and i not in running]

    def _unstarted_reduce_tasks(self, job: JobRuntime) -> List[int]:
        done = job.completed_reduce
        running = job.running_reduce
        return [i for i in range(job.spec.v_r)
                if i not in done and i not in running]

    def _local_map_candidates(self, job: JobRuntime, node: int) -> List[int]:
        return [i for i in self._unstarted_map_tasks(job)
                if node in job.spec.block_placement[i]]

    def active_jobs(self) -> List[JobRuntime]:
        return [self.jobs[j] for j in self.order if not self.jobs[j].finished]

    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        raise NotImplementedError


class LegacyCompletionTimeScheduler(LegacySchedulerBase):
    name = "proposed"
    uses_reconfig = True

    def __init__(self, spec: ClusterSpec,
                 reconfig: Optional[LegacyReconfigurator] = None,
                 estimator: Optional[OnlineEstimator] = None):
        super().__init__(spec)
        self.reconfig = reconfig or LegacyReconfigurator(spec)
        self.estimator = estimator or OnlineEstimator()
        self.parked: Set[TaskId] = set()
        self.no_park: Set[TaskId] = set()
        self.park_depth = 2
        self.max_slots = spec.num_nodes * spec.base_map_slots

    def on_job_added(self, job: JobRuntime, now: float) -> None:
        self._recompute_demand(job, now)

    def on_task_finished(self, job: JobRuntime, task: TaskId,
                         now: float) -> None:
        self._recompute_demand(job, now)

    def _recompute_demand(self, job: JobRuntime, now: float) -> None:
        job.demand = self.estimator.demand(
            job, now, max_map_slots=self.max_slots,
            max_reduce_slots=self.max_slots)

    def _scheduled_maps(self, job: JobRuntime) -> int:
        parked = sum(1 for t in self.parked if t.job_id == job.spec.job_id
                     and t.kind == TaskKind.MAP)
        return len(job.running_map) + parked

    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        out: List[Launch] = []
        jobs = self.active_jobs()
        bootstrap = [j for j in jobs if not j.started]
        edf = sorted((j for j in jobs if j.started),
                     key=lambda j: j.absolute_deadline)
        for phase in ("demand", "backfill", "remote_fill"):
            if phase == "demand":
                ordered = bootstrap + edf
            else:
                ordered = sorted(jobs, key=lambda j: j.absolute_deadline)
            if phase == "remote_fill":
                m = self.spec.machine_of(node)
                pending = sum(1 for p in self.reconfig.aq[m]
                              if p.target_vm != node)
                while (free_map > 0 and pending > 0
                       and self.reconfig.vcpus[node]
                       > self.spec.min_vcpus_per_vm):
                    self.reconfig.release_core(node, now)
                    free_map -= 1
                    pending -= 1
            for job in ordered:
                if free_map <= 0 and free_reduce <= 0:
                    break
                demand = job.demand
                n_m = demand.n_m if demand else 1
                n_r = demand.n_r if demand else 1
                if phase != "demand":
                    n_m, n_r = job.spec.u_m, job.spec.v_r
                if not job.map_finished:
                    while free_map > 0 and self._scheduled_maps(job) < n_m:
                        launch = self._assign_map(
                            job, node, now,
                            allow_park=(phase != "remote_fill"))
                        if launch is None:
                            break
                        if launch.via_reconfig:
                            pass
                        else:
                            out.append(launch)
                            free_map -= 1
                            job.running_map[launch.task.index] = launch.node
                            if launch.local:
                                job.local_map_launches += 1
                            else:
                                job.remote_map_launches += 1
                elif not job.finished:
                    unstarted = self._unstarted_reduce_tasks(job)
                    while (free_reduce > 0 and unstarted
                           and len(job.running_reduce) < n_r):
                        idx = unstarted.pop(0)
                        t = TaskId(job.spec.job_id, TaskKind.REDUCE, idx)
                        out.append(Launch(t, node, local=True))
                        job.running_reduce[idx] = node
                        free_reduce -= 1
        return out

    def _assign_map(self, job: JobRuntime, node: int, now: float,
                    allow_park: bool = True) -> Optional[Launch]:
        local = self._local_map_candidates(job, node)
        if local:
            idx = local[0]
            return Launch(TaskId(job.spec.job_id, TaskKind.MAP, idx), node,
                          local=True)
        unstarted = [i for i in self._unstarted_map_tasks(job)
                     if TaskId(job.spec.job_id, TaskKind.MAP, i)
                     not in self.parked]
        if not unstarted:
            return None
        idx = unstarted[0]
        task = TaskId(job.spec.job_id, TaskKind.MAP, idx)
        placement = job.spec.block_placement[idx]
        slack = job.absolute_deadline - now
        deadline_critical = slack <= 3.0 * self.reconfig.max_wait
        if task in self.no_park or deadline_critical or not allow_park:
            return Launch(task, node, local=False)
        s_rq = sorted(placement, key=lambda v: -self.reconfig.rq_len(v))
        if self.reconfig.rq_len(s_rq[0]) > 0:
            p = s_rq[0]
        else:
            p = min(placement, key=lambda v: self.reconfig.aq_len(v))
            if len(self.reconfig.aq[self.spec.machine_of(p)]) >= self.park_depth:
                return None
        self.reconfig.park_task(task, p, now)
        self.reconfig.release_core(node, now)
        self.parked.add(task)
        return Launch(task, p, local=True, via_reconfig=True)

    def has_local_pending(self, vm: int) -> bool:
        for job in self.active_jobs():
            if job.map_finished:
                continue
            for i in self._unstarted_map_tasks(job):
                if vm in job.spec.block_placement[i]:
                    return True
        return False

    def parked_task_launched(self, task: TaskId, node: int,
                             now: float) -> None:
        self.parked.discard(task)
        job = self.jobs[task.job_id]
        job.running_map[task.index] = node
        job.local_map_launches += 1
        job.reconfig_map_launches += 1

    def parked_task_expired(self, task: TaskId, now: float) -> None:
        self.parked.discard(task)
        self.no_park.add(task)


class LegacyFairScheduler(LegacySchedulerBase):
    name = "fair"

    def __init__(self, spec: ClusterSpec, locality_delay: int = 0):
        super().__init__(spec)
        self.locality_delay = locality_delay
        self._skips: Dict[str, int] = {}

    def _running_slots(self, job: JobRuntime) -> int:
        return len(job.running_map) + len(job.running_reduce)

    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        out: List[Launch] = []
        while free_map > 0 or free_reduce > 0:
            jobs = [j for j in self.active_jobs()]
            if not jobs:
                break
            jobs.sort(key=lambda j: (self._running_slots(j),
                                     j.spec.submit_time))
            launched = False
            for job in jobs:
                jid = job.spec.job_id
                if free_map > 0 and not job.map_finished:
                    local = self._local_map_candidates(job, node)
                    if local:
                        idx = local[0]
                        self._skips[jid] = 0
                        t = TaskId(jid, TaskKind.MAP, idx)
                        out.append(Launch(t, node, local=True))
                        job.running_map[idx] = node
                        job.local_map_launches += 1
                        free_map -= 1
                        launched = True
                        break
                    unstarted = self._unstarted_map_tasks(job)
                    if unstarted:
                        if self._skips.get(jid, 0) < self.locality_delay:
                            self._skips[jid] = self._skips.get(jid, 0) + 1
                            continue
                        self._skips[jid] = 0
                        idx = unstarted[0]
                        t = TaskId(jid, TaskKind.MAP, idx)
                        out.append(Launch(t, node, local=False))
                        job.running_map[idx] = node
                        job.remote_map_launches += 1
                        free_map -= 1
                        launched = True
                        break
                if free_reduce > 0 and job.map_finished and not job.finished:
                    unstarted = self._unstarted_reduce_tasks(job)
                    if unstarted:
                        idx = unstarted[0]
                        t = TaskId(jid, TaskKind.REDUCE, idx)
                        out.append(Launch(t, node, local=True))
                        job.running_reduce[idx] = node
                        free_reduce -= 1
                        launched = True
                        break
            if not launched:
                break
        return out


class LegacyFIFOScheduler(LegacySchedulerBase):
    name = "fifo"

    def select(self, node: int, free_map: int, free_reduce: int,
               now: float) -> List[Launch]:
        out: List[Launch] = []
        for jid in self.order:
            job = self.jobs[jid]
            if job.finished:
                continue
            while free_map > 0 and not job.map_finished:
                local = self._local_map_candidates(job, node)
                cand = local or self._unstarted_map_tasks(job)
                if not cand:
                    break
                idx = cand[0]
                is_local = bool(local)
                out.append(Launch(TaskId(jid, TaskKind.MAP, idx), node,
                                  local=is_local))
                job.running_map[idx] = node
                if is_local:
                    job.local_map_launches += 1
                else:
                    job.remote_map_launches += 1
                free_map -= 1
            while (free_reduce > 0 and job.map_finished and not job.finished):
                unstarted = self._unstarted_reduce_tasks(job)
                if not unstarted:
                    break
                idx = unstarted[0]
                out.append(Launch(TaskId(jid, TaskKind.REDUCE, idx), node,
                                  local=True))
                job.running_reduce[idx] = node
                free_reduce -= 1
            if free_map <= 0 and free_reduce <= 0:
                break
        return out


# ---------------------------------------------------------------------------
# Simulator (seed simcluster/sim.py)
# ---------------------------------------------------------------------------
from repro.simcluster.sim import RunningTask, SimResult  # noqa: E402


class LegacyClusterSim:
    """Seed discrete-event loop: per-heartbeat full rescans everywhere."""

    def __init__(self, spec: ClusterSpec, scheduler: LegacySchedulerBase, *,
                 seed: int = 0, straggler_prob: float = 0.03,
                 straggler_factor: float = 3.0, speculative: bool = True,
                 speculation_threshold: float = 2.0):
        self.spec = spec
        self.sched = scheduler
        self.rng = random.Random(seed)
        self.straggler_prob = straggler_prob
        self.straggler_factor = straggler_factor
        self.speculative = speculative
        self.spec_threshold = speculation_threshold

        n = spec.num_nodes
        self.map_running: List[List[RunningTask]] = [[] for _ in range(n)]
        self.red_running: List[List[RunningTask]] = [[] for _ in range(n)]
        self.live: Dict[Tuple[TaskId, bool], RunningTask] = {}
        self.finished_tasks: set = set()
        self.spec_launched: set = set()
        self.n_speculative = 0
        self.events: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self.events_processed = 0
        self.reconfig: Optional[LegacyReconfigurator] = getattr(
            scheduler, "reconfig", None) if scheduler.uses_reconfig else None
        if self.reconfig is not None:
            self.reconfig.validator = lambda vm: self.free_map(vm) > 0

    def map_capacity(self, node: int) -> int:
        if self.reconfig is not None:
            return self.reconfig.vcpus[node]
        return self.spec.base_map_slots

    def free_map(self, node: int) -> int:
        return self.map_capacity(node) - len(self.map_running[node])

    def free_reduce(self, node: int) -> int:
        return self.spec.base_reduce_slots - len(self.red_running[node])

    def _push(self, t: float, kind: str, data=None) -> None:
        self._seq += 1
        heapq.heappush(self.events, (t, self._seq, kind, data))

    def _jitter(self, cv: float) -> float:
        if cv <= 0:
            return 1.0
        sigma = math.sqrt(math.log(1 + cv * cv))
        return self.rng.lognormvariate(-sigma * sigma / 2, sigma)

    def task_duration(self, job: JobRuntime, task: TaskId,
                      local: bool) -> float:
        prof = job.spec.profile
        if task.kind == TaskKind.MAP:
            base = prof.map_time
            if not local:
                base *= 1.0 + prof.remote_penalty
        else:
            base = prof.reduce_time + job.spec.u_m * prof.shuffle_time_per_pair
        d = base * self._jitter(prof.time_cv)
        if self.rng.random() < self.straggler_prob:
            d *= self.straggler_factor
        return d

    def run(self, jobs: List[JobSpec], until: float = 10_000_000.0) -> SimResult:
        for job in jobs:
            self._push(job.submit_time, "submit", job)
        for node in range(self.spec.num_nodes):
            self._push(
                self.spec.heartbeat_interval * (1 + node / self.spec.num_nodes),
                "heartbeat", node)
        now = 0.0
        while self.events:
            now, _, kind, data = heapq.heappop(self.events)
            if now > until:
                break
            self.events_processed += 1
            if kind == "submit":
                self.sched.job_added(data, now)
            elif kind == "finish":
                self._on_finish(data, now)
            elif kind == "plug":
                self._on_plug_ready(now)
            elif kind == "heartbeat":
                node = data
                self._heartbeat(node, now)
                if any(not j.finished for j in self.sched.jobs.values()) or \
                        not self.sched.jobs:
                    self._push(now + self.spec.heartbeat_interval, "heartbeat",
                               node)
        result = SimResult(
            scheduler=self.sched.name,
            jobs=self.sched.jobs,
            makespan=max((j.finish_time or now)
                         for j in self.sched.jobs.values())
            if self.sched.jobs else 0.0,
            reconfig_stats=dict(self.reconfig.stats) if self.reconfig else {},
            speculative_launches=self.n_speculative,
            events_processed=self.events_processed,
        )
        return result

    def _launch(self, launch: Launch, now: float,
                speculative: bool = False) -> None:
        job = self.sched.jobs[launch.task.job_id]
        dur = self.task_duration(job, launch.task, launch.local)
        rt = RunningTask(launch.task, launch.node, now, now + dur,
                         launch.local, speculative)
        if launch.task.kind == TaskKind.MAP:
            self.map_running[launch.node].append(rt)
        else:
            self.red_running[launch.node].append(rt)
        self.live[(launch.task, speculative)] = rt
        self._push(rt.finish, "finish", rt)

    def _on_finish(self, rt: RunningTask, now: float) -> None:
        if (rt.task, rt.speculative) not in self.live:
            return
        del self.live[(rt.task, rt.speculative)]
        lst = (self.map_running if rt.task.kind == TaskKind.MAP
               else self.red_running)[rt.node]
        if rt in lst:
            lst.remove(rt)
        if rt.task in self.finished_tasks:
            return
        self.finished_tasks.add(rt.task)
        twin_key = (rt.task, not rt.speculative)
        if twin_key in self.live:
            twin = self.live.pop(twin_key)
            tl = (self.map_running if rt.task.kind == TaskKind.MAP
                  else self.red_running)[twin.node]
            if twin in tl:
                tl.remove(twin)
        self.sched.task_finished(rt.task, rt.node, now, now - rt.start)
        if self.reconfig is not None and rt.task.kind == TaskKind.MAP:
            vm = rt.node
            if (self.free_map(vm) > 0
                    and (self.reconfig.vcpus[vm] > self.spec.base_map_slots
                         or (isinstance(self.sched,
                                        LegacyCompletionTimeScheduler)
                             and not self.sched.has_local_pending(vm)))):
                self.reconfig.release_core(vm, now)
            self._match_reconfig(now)

    def _on_plug_ready(self, now: float) -> None:
        if self.reconfig is None:
            return
        for plug in self.reconfig.complete_plugs(now):
            task = plug.task
            job = self.sched.jobs.get(task.job_id)
            if job is None or task.index in job.completed_map:
                continue
            self.sched.parked_task_launched(task, plug.to_vm, now)
            self._launch(Launch(task, plug.to_vm, local=True,
                                via_reconfig=True), now)

    def _match_reconfig(self, now: float) -> None:
        if self.reconfig is None:
            return
        started = self.reconfig.match(
            now, donor_ok=lambda vm: self.free_map(vm) > 0)
        for plug in started:
            self._push(plug.ready_at, "plug", None)

    def _heartbeat(self, node: int, now: float) -> None:
        if self.reconfig is not None:
            for parked in self.reconfig.expire_stale(now):
                if isinstance(self.sched, LegacyCompletionTimeScheduler):
                    self.sched.parked_task_expired(parked.task, now)
            self._match_reconfig(now)
        fm, fr = self.free_map(node), self.free_reduce(node)
        if fm > 0 or fr > 0:
            for launch in self.sched.select(node, fm, fr, now):
                self._launch(launch, now)
            self._match_reconfig(now)
        if self.speculative:
            self._maybe_speculate(node, now)

    def _maybe_speculate(self, node: int, now: float) -> None:
        if self.free_map(node) <= 0:
            return
        for job in self.sched.jobs.values():
            if job.finished or not job.map_durations:
                continue
            mean = sum(job.map_durations) / len(job.map_durations)
            for idx, vnode in list(job.running_map.items()):
                task = TaskId(job.spec.job_id, TaskKind.MAP, idx)
                key = (task, False)
                if key not in self.live or task in self.spec_launched:
                    continue
                rt = self.live[key]
                if now - rt.start > self.spec_threshold * mean:
                    self.spec_launched.add(task)
                    self.n_speculative += 1
                    local = node in job.spec.block_placement[idx]
                    self._launch(Launch(task, node, local=local), now,
                                 speculative=True)
                    return
