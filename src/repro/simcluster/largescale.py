"""Large-fleet scenario suite — clusters far beyond the paper's 20 machines.

The paper evaluates on 20 machines × 2 VMs and ≤ 25 jobs.  The ROADMAP
north-star (and the virtual-cluster scheduler evaluations in
arXiv:1808.08040 / arXiv:1704.02632) call for schedulers exercised on
hundreds of machines and hundreds of jobs with realistic *bursty* submission
patterns — fleets the seed engine's O(jobs × tasks) heartbeat scans could
not simulate in reasonable time.  Each scenario here is a named, seedable
recipe: a ``ClusterSpec`` plus a job-arrival trace.

Burst patterns deliberately include long idle gaps between waves: a job
submitted after the cluster drains exercises the heartbeat re-arm path
(the seed engine deadlocked there — its heartbeat chains died with the last
finished job and never revived).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.types import (ClusterSpec, FaultConfig, JobSpec, MachineClass,
                              ServeConfig, ServiceSpec)
from repro.simcluster.workloads import (WORKLOADS, default_deadline, make_job,
                                        n_map_tasks)


@dataclass(frozen=True)
class Scenario:
    """A reproducible large-fleet experiment: cluster shape + arrival trace."""

    name: str
    description: str
    num_machines: int
    vms_per_machine: int
    num_jobs: int
    # jobs arrive in bursts: ``burst_size`` jobs every ``burst_gap`` seconds,
    # spaced ``intra_burst_stagger`` apart inside a burst
    burst_size: int
    burst_gap: float
    intra_burst_stagger: float = 2.0
    sizes_gb: Sequence[float] = (1.0, 2.0, 3.0, 4.0)
    skew: float = 1.0
    replication: int = 3
    deadline_slack: float = 2.2
    # fault-injection layer (FaultConfig, default disabled) — churn
    # scenarios run the same arrival trace on a fleet that loses nodes
    faults: FaultConfig = FaultConfig()
    # co-located serving layer (ServeConfig, default disabled) — serving
    # scenarios pin service cores the batch side can harvest back
    serve: ServeConfig = ServeConfig()

    def cluster(self) -> ClusterSpec:
        return ClusterSpec(num_machines=self.num_machines,
                           vms_per_machine=self.vms_per_machine,
                           replication=self.replication,
                           faults=self.faults,
                           serve=self.serve)

    def jobs(self, spec: ClusterSpec, seed: int = 0) -> List[JobSpec]:
        rng = random.Random(seed)
        workloads = list(WORKLOADS)
        jobs: List[JobSpec] = []
        t = 0.0
        # deadlines scale with how big the job is relative to the fleet, so
        # large fleets get proportionally tight (still feasible) goals
        slot_scale = max(1.0, spec.num_nodes * spec.base_map_slots / 40.0)
        for i in range(self.num_jobs):
            if i > 0 and i % self.burst_size == 0:
                t += self.burst_gap
            w = workloads[rng.randrange(len(workloads))]
            size = self.sizes_gb[rng.randrange(len(self.sizes_gb))]
            deadline = (default_deadline(w, size, slack=self.deadline_slack)
                        / slot_scale + 180.0)
            jobs.append(make_job(f"{w}-{i}", w, size, deadline, spec, rng,
                                 submit_time=t, skew=self.skew))
            t += self.intra_burst_stagger
        return jobs

    def total_tasks(self, jobs: Sequence[JobSpec]) -> int:
        return sum(j.u_m + j.v_r for j in jobs)


SCENARIOS: Dict[str, Scenario] = {s.name: s for s in [
    Scenario(
        name="fleet_100x2",
        description="100 machines x 2 VMs, 120 jobs in bursts of 30",
        num_machines=100, vms_per_machine=2, num_jobs=120,
        burst_size=30, burst_gap=240.0),
    Scenario(
        name="fleet_200x2",
        description="200 machines x 2 VMs, 250 jobs in bursts of 50",
        num_machines=200, vms_per_machine=2, num_jobs=250,
        burst_size=50, burst_gap=180.0, sizes_gb=(1.0, 2.0, 4.0, 6.0)),
    Scenario(
        name="fleet_200x4",
        description="200 machines x 4 VMs, 300 jobs in bursts of 75",
        num_machines=200, vms_per_machine=4, num_jobs=300,
        burst_size=75, burst_gap=150.0, sizes_gb=(2.0, 4.0, 6.0)),
    Scenario(
        name="fleet_400x2",
        description="400 machines x 2 VMs, 500 jobs in bursts of 100",
        num_machines=400, vms_per_machine=2, num_jobs=500,
        burst_size=100, burst_gap=120.0, sizes_gb=(2.0, 4.0, 8.0)),
    Scenario(
        name="fleet_100x2_sustained",
        description=("100 machines x 2 VMs, 150 jobs arriving continuously "
                     "at near-saturation (the cluster never drains, so the "
                     "seed engine can run it too — the apples-to-apples "
                     "speedup benchmark)"),
        num_machines=100, vms_per_machine=2, num_jobs=150,
        burst_size=150, burst_gap=0.0, intra_burst_stagger=2.0,
        sizes_gb=(3.0, 6.0, 9.0, 12.0)),
    Scenario(
        name="burst_idle_gap",
        description=("100 machines x 2 VMs, 100 jobs in bursts separated by "
                     "long idle gaps (heartbeat re-arm stress)"),
        num_machines=100, vms_per_machine=2, num_jobs=100,
        burst_size=20, burst_gap=1500.0, sizes_gb=(0.5, 1.0, 2.0)),
    Scenario(
        name="fleet_100x2_churn",
        description=("100 machines x 2 VMs, 120 jobs under node churn: "
                     "crashes (MTBF 1800 s, MTTR 120 s), straggler bursts, "
                     "and a 3:1 heterogeneous new/old machine mix — the "
                     "fault-injection benchmark scenario"),
        num_machines=100, vms_per_machine=2, num_jobs=120,
        burst_size=30, burst_gap=240.0,
        faults=FaultConfig(
            enabled=True,
            crash_mtbf=1800.0, crash_mttr=120.0,
            rereplicate_after=60.0,
            burst_rate=900.0, burst_duration=45.0, burst_slowdown=2.5,
            machine_classes=(
                MachineClass(name="new", weight=3),
                MachineClass(name="old", weight=1, speed=1.4, fabric=1.25,
                             mtbf_scale=0.5),
            ))),
    Scenario(
        name="fleet_100x2_serving",
        description=("100 machines x 2 VMs, 120 batch jobs co-located with "
                     "a 20-replica 2-vCPU service fleet (40 of 400 cores "
                     "pinned) — the serving/harvest benchmark scenario"),
        num_machines=100, vms_per_machine=2, num_jobs=120,
        burst_size=30, burst_gap=240.0,
        serve=ServeConfig(enabled=True, services=(
            ServiceSpec(name="api", replicas=20, vcpus=2, base_rps=15.0,
                        diurnal_amplitude=0.3, slo_p99_ms=600.0),
        ))),
    Scenario(
        name="smoke_40x2",
        description="40 machines x 2 VMs, 40 jobs — CI-sized smoke scenario",
        num_machines=40, vms_per_machine=2, num_jobs=40,
        burst_size=10, burst_gap=200.0, sizes_gb=(0.5, 1.0, 2.0)),
]}


# Cluster shapes for the regime atlas (experiments/regimes.py): the paper's
# 20x2 up to fleet scale.  Replication 1 matches the calibrated paper setting
# (per-VM virtual disks); the scenario suite above keeps replication 3 for
# the HDFS-default stress runs.
FLEET_SHAPES: Dict[str, Tuple[int, int]] = {
    "20x2": (20, 2),
    "50x2": (50, 2),
    "100x2": (100, 2),
}


def fleet_shape(name: str, replication: int = 1) -> ClusterSpec:
    """``ClusterSpec`` for a named ``MxV`` shape from ``FLEET_SHAPES``."""
    if name not in FLEET_SHAPES:
        raise ValueError(f"unknown fleet shape {name!r}; available: "
                         f"{', '.join(FLEET_SHAPES)}")
    machines, vms = FLEET_SHAPES[name]
    return ClusterSpec(num_machines=machines, vms_per_machine=vms,
                       replication=replication)


def build_scheduler(kind: str, spec: ClusterSpec, *, legacy: bool = False):
    """Deprecated string-keyed factory — the policy registry replaced it.

    Kept as a shim so old call sites keep working: ``kind`` is resolved
    through ``repro.core.policies`` (``PolicyError`` subclasses ValueError,
    so unknown names still raise ValueError).  New code should construct a
    ``PolicySpec`` and call ``.build(spec)`` directly."""
    import warnings

    from repro.core.policies import build_policy
    warnings.warn(
        "build_scheduler(kind: str, ...) is deprecated; use "
        "repro.core.policies.PolicySpec(name, params).build(cluster) "
        "or SchedulerBase.from_policy(...)",
        DeprecationWarning, stacklevel=2)
    return build_policy(kind, spec, legacy=legacy)


def run_scenario(name: str, *, scheduler="proposed", seed: int = 0,
                 engine: str = "indexed", until: float = 10_000_000.0,
                 tracing=None):
    """Run one named scenario; returns the ``SimResult``.  ``scheduler`` is
    any policy value ``PolicySpec.parse`` accepts (name, JSON, dict, spec).
    ``tracing`` enables the decision-trace bus on the indexed engine: pass a
    ``TraceConfig`` (or ``True`` for the default-on config); the result's
    ``trace`` attribute then carries the bus.  The legacy engine has no bus
    — tracing there is rejected rather than silently dropped."""
    import dataclasses

    from repro.core.policies import build_policy
    sc = SCENARIOS[name]
    spec = sc.cluster()
    if tracing:
        from repro.core.types import TraceConfig
        if tracing is True:
            tracing = TraceConfig(enabled=True)
        if engine == "legacy":
            raise ValueError("tracing requires the indexed engine")
        spec = dataclasses.replace(spec, tracing=tracing)
    jobs = sc.jobs(spec, seed=seed)
    sched = build_policy(scheduler, spec, legacy=(engine == "legacy"))
    if engine == "legacy":
        if spec.serve.active:
            raise ValueError("the legacy engine has no serving layer; "
                             "serving scenarios require engine='indexed'")
        from repro.simcluster._legacy import LegacyClusterSim
        sim = LegacyClusterSim(spec, sched, seed=seed)
    else:
        from repro.simcluster.sim import ClusterSim
        sim = ClusterSim(spec, sched, seed=seed)
    return sim.run(jobs, until=until)
