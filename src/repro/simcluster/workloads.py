"""The paper's five MapReduce workloads (§5) as simulator profiles + the
experiment job mixes.

Profiles are calibrated to 2012-era Hadoop on commodity nodes (128 MB block,
map task ≈ 20–40 s — the paper notes "tasks ... will be finished in less than
a minute"); the *relative* characteristics follow the paper's description:

* Grep — tiny intermediate data (shuffle-light)
* Word Count — moderate intermediate data
* Sort — identity map/reduce, shuffle ≈ input
* Permutation Generator — reduce-input-heavy (large intermediate data); the
  paper predicts ≈ no gain for it under the proposed scheduler (Fig. 3)
* Inverted Index — moderate-heavy intermediate

u_m = ⌈GB × 8⌉ map tasks (128 MB blocks); v_r per workload below.
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.types import ClusterSpec, JobSpec, WorkloadProfile

_BASE_COPY = 0.012     # s per mapper->reducer copy per GB-normalized stream
# remote_penalty=1.0: on 2012-era shared 1GbE a non-local map reads its
# 128 MB block over the network while shuffles compete -- ~2x map time
# (paper refs [10][16][17]: locality affects throughput 'considerably').

WORKLOADS: Dict[str, WorkloadProfile] = {
    "grep": WorkloadProfile(
        name="grep", map_time=20.0, reduce_time=8.0,
        shuffle_time_per_pair=_BASE_COPY * 0.2, intermediate_ratio=0.05,
        remote_penalty=1.0),
    "wordcount": WorkloadProfile(
        name="wordcount", map_time=30.0, reduce_time=12.0,
        shuffle_time_per_pair=_BASE_COPY, intermediate_ratio=0.8,
        remote_penalty=1.0),
    "sort": WorkloadProfile(
        name="sort", map_time=22.0, reduce_time=20.0,
        shuffle_time_per_pair=_BASE_COPY * 1.6, intermediate_ratio=1.0,
        remote_penalty=1.0),
    "permutation": WorkloadProfile(
        name="permutation", map_time=25.0, reduce_time=35.0,
        shuffle_time_per_pair=_BASE_COPY * 4.0, intermediate_ratio=4.0,
        remote_penalty=1.0),
    "inverted_index": WorkloadProfile(
        name="inverted_index", map_time=35.0, reduce_time=15.0,
        shuffle_time_per_pair=_BASE_COPY * 1.2, intermediate_ratio=1.2,
        remote_penalty=1.0),
}

_REDUCE_FRACTION = {          # v_r relative to u_m
    "grep": 0.15, "wordcount": 0.25, "sort": 0.5,
    "permutation": 0.6, "inverted_index": 0.3,
}


def n_map_tasks(input_gb: float) -> int:
    return max(1, int(math.ceil(input_gb * 8)))     # 128 MB blocks


def n_reduce_tasks(workload: str, input_gb: float) -> int:
    return max(1, int(round(n_map_tasks(input_gb) * _REDUCE_FRACTION[workload])))


def place_blocks(u_m: int, spec: ClusterSpec, rng: random.Random,
                 replication: Optional[int] = None,
                 skew: float = 0.0) -> List[Tuple[int, ...]]:
    """HDFS-style placement: `replication` distinct VMs per block.

    ``skew`` > 0 draws the primary machine from a power-law (weights
    (i+1)^-skew) — the hot/cold imbalance of real small virtual clusters
    (datanodes filling up, VM images co-placed) that the paper's
    reconfiguration mechanism targets.  0 = uniform."""
    r = replication or spec.replication
    nodes = list(range(spec.num_nodes))
    if skew <= 0:
        return [tuple(rng.sample(nodes, min(r, len(nodes)))) for _ in range(u_m)]
    # VM-level power-law skew with a per-job permutation of VM hotness:
    # VMs sharing a machine end up with *different* local demand, which is
    # exactly the imbalance Algorithm 1's intra-machine core transfer targets
    # (the paper's multi-tenant virtual clusters).
    perm = nodes[:]
    rng.shuffle(perm)
    weights = [(i + 1.0) ** -skew for i in range(len(perm))]
    out = []
    for _ in range(u_m):
        placed: List[int] = []
        while len(placed) < min(r, len(nodes)):
            vm = perm[rng.choices(range(len(perm)), weights=weights)[0]]
            if vm not in placed:
                placed.append(vm)
        out.append(tuple(placed))
    return out


def make_job(job_id: str, workload: str, input_gb: float, deadline: float,
             spec: ClusterSpec, rng: random.Random,
             submit_time: float = 0.0, skew: float = 0.0) -> JobSpec:
    u_m = n_map_tasks(input_gb)
    return JobSpec(
        job_id=job_id,
        profile=WORKLOADS[workload],
        u_m=u_m,
        v_r=n_reduce_tasks(workload, input_gb),
        deadline=deadline,
        submit_time=submit_time,
        input_size_gb=input_gb,
        block_placement=place_blocks(u_m, spec, rng, skew=skew),
    )


def default_deadline(workload: str, input_gb: float,
                     slack: float = 2.2) -> float:
    """A deadline proportional to the single-wave serial estimate / cluster."""
    prof = WORKLOADS[workload]
    u_m = n_map_tasks(input_gb)
    v_r = n_reduce_tasks(workload, input_gb)
    # rough two-wave estimate on ~20 map slots
    est = (u_m * prof.map_time / 20.0
           + v_r * (prof.reduce_time + u_m * prof.shuffle_time_per_pair) / 10.0)
    return slack * est + 120.0


# -- paper-calibrated cluster (§5): 20 machines, 2 VMs each, per-VM virtual
# disks (=> effective replication 1), skewed VM-level block distribution.
PAPER_SKEW = 1.0


def paper_cluster() -> ClusterSpec:
    return ClusterSpec(replication=1)


def paper_job_mix(spec: ClusterSpec, sizes_gb: Sequence[float] = (2, 4, 6, 8, 10),
                  seed: int = 0, stagger: float = 15.0,
                  skew: float = PAPER_SKEW) -> List[JobSpec]:
    """Fig.-2 experiment: all five workloads at each input size."""
    rng = random.Random(seed)
    jobs = []
    t = 0.0
    for size in sizes_gb:
        for w in WORKLOADS:
            jobs.append(make_job(f"{w}-{size}gb", w, size,
                                 default_deadline(w, size), spec, rng,
                                 submit_time=t, skew=skew))
            t += stagger
    return jobs


# the paper's Table-2 (workload, input GB, deadline s) rows — the evaluation
# job mix that Fig. 3 and the throughput-gain claim are measured on
PAPER_TABLE2_ROWS: Tuple[Tuple[str, int, float], ...] = (
    ("grep", 10, 650.0),
    ("wordcount", 5, 520.0),
    ("sort", 10, 500.0),
    ("permutation", 4, 850.0),
    ("inverted_index", 8, 720.0),
)


def paper_table2_jobs(spec: ClusterSpec, seed: int = 0,
                      skew: float = PAPER_SKEW) -> List[JobSpec]:
    """Table-2 experiment: the paper's (job, deadline, size) rows."""
    rng = random.Random(seed)
    return [make_job(f"{w}-t2", w, gb, dl, spec, rng, skew=skew)
            for (w, gb, dl) in PAPER_TABLE2_ROWS]
