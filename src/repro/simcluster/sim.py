"""Discrete-event simulator of the virtualized MapReduce cluster (paper §5).

Models: physical machines hosting VMs, per-VM map/reduce slots, HDFS-style
replicated block placement, remote-read penalty for non-local map tasks,
heartbeats (3 s), vCPU hot-plug latency, per-task duration jitter,
stragglers + speculative re-execution.

The simulator is scheduler-agnostic: any ``SchedulerBase`` subclass plugs in.
For ``CompletionTimeScheduler`` the per-VM map capacity follows the
reconfigurator's live vCPU counts (Algorithm 1); baselines keep the static
slot configuration — exactly the comparison of paper §5.

Engine notes (vs. the frozen seed engine in ``repro.simcluster._legacy``):

* **Speculation is incremental.**  The seed rescanned every running map of
  every job on every heartbeat.  Here each job keeps an insertion-ordered
  run queue (same order as ``running_map`` dict insertion, which the seed
  iterated) plus a lazy wake-time heap: a job is only examined once
  ``head_start + threshold × mean`` has passed.  Every event that can make
  a job eligible earlier (new sample changing the mean, new running task)
  pushes a fresh wake entry, so no eligibility point is missed.  The chosen
  (job, task) is identical to the seed scan: first job in submission order,
  first running map in insertion order.
* **Heartbeats stop when idle and re-arm on submit.**  The seed re-armed a
  node's heartbeat only while some *current* job was unfinished — a job
  submitted after an idle gap was never scheduled (deadlock), while a run
  with no jobs ticked forever.  Heartbeat chains now die when there is no
  active job, and every ``submit`` event revives dead chains.
* **Fault injection** (``ClusterSpec.faults``, off by default — see
  ``FaultConfig``): per-machine crash/restart processes with exponential
  up/down times, loss + deterministic re-execution of the crashed node's
  running tasks, re-replication of dead blocks after a grace window,
  correlated straggler bursts, and heterogeneous machine classes.  Every
  fault draw comes from dedicated per-machine RNG streams (seeded by the
  sim seed + machine id only), so the disabled path consumes zero draws
  from the duration RNG — decision parity with the legacy engine is
  untouched — and an enabled run's fault schedule is reproducible
  byte-for-byte per (config, seed).  Down nodes stop heartbeating (their
  chain epoch is bumped, so stale chains die on pop) and restart re-arms
  them; fault chains suspend while the cluster is idle and revive on
  submit, exactly like heartbeat chains, so a drained run terminates.
* ``events_processed`` counts processed events for benchmarking.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler, Launch, SchedulerBase
from repro.core.tracing import FaultEvent, TraceBus
from repro.core.types import ClusterSpec, JobRuntime, JobSpec, TaskId, TaskKind


@dataclass
class RunningTask:
    task: TaskId
    node: int
    start: float
    finish: float
    local: bool
    speculative: bool = False
    # set by _kill_running when a crash kills this attempt: its pending
    # finish event is void (the task may re-launch under the same live key)
    dead: bool = False
    # set when speculation cancels this attempt (its twin finished first):
    # distinguishes an already-killed attempt's stale finish from the
    # reconfig double-launch loser, which is dropped silently otherwise
    cancelled: bool = False


@dataclass
class SimResult:
    scheduler: str
    jobs: Dict[str, JobRuntime]
    makespan: float
    reconfig_stats: Dict[str, float] = field(default_factory=dict)
    speculative_launches: int = 0
    events_processed: int = 0
    # fault injection (empty when FaultConfig is off): per-kind counters
    # and the (time, kind, machine) event log — the log is the
    # determinism pin's artifact (same config+seed => byte-identical)
    fault_stats: Dict[str, int] = field(default_factory=dict)
    fault_log: List[FaultEvent] = field(default_factory=list)
    # decision-trace bus (ClusterSpec.tracing; None when tracing is off)
    trace: Optional[TraceBus] = None
    # serving layer (empty when ServeConfig is off): whole-run latency/
    # SLO/harvest stats plus the per-tick request log — the log is the
    # determinism pin's artifact (same config+seed => byte-identical)
    serve_stats: Dict[str, object] = field(default_factory=dict)
    serve_log: List[list] = field(default_factory=list)

    # -- derived metrics ----------------------------------------------------
    def completion_time(self, job_id: str) -> float:
        j = self.jobs[job_id]
        return (j.finish_time or math.inf) - j.spec.submit_time

    def throughput_jobs_per_hour(self) -> float:
        done = [j for j in self.jobs.values() if j.finish_time is not None]
        if not done or self.makespan <= 0:
            return 0.0
        return len(done) * 3600.0 / self.makespan

    def deadlines_met(self) -> int:
        return sum(1 for j in self.jobs.values()
                   if j.finish_time is not None
                   and j.finish_time <= j.absolute_deadline + 1e-9)

    def locality_rate(self) -> float:
        loc = sum(j.local_map_launches for j in self.jobs.values())
        tot = loc + sum(j.remote_map_launches for j in self.jobs.values())
        return loc / tot if tot else 0.0


class _SpecQueue:
    """Insertion-ordered running-map queue of one job, for speculation.

    Mirrors ``running_map`` dict-key order exactly: a re-launch of an index
    already present (parked task also launched directly) keeps its original
    position, like a dict key re-assignment.  Entries are (idx, append-time
    start); the *live* RunningTask's start is authoritative — a later
    re-launch refreshes it, which the eligibility walk accounts for.
    """

    __slots__ = ("entries", "head", "present")

    def __init__(self) -> None:
        self.entries: List[Tuple[int, float]] = []
        self.head = 0
        self.present: Set[int] = set()

    def append(self, idx: int, start: float) -> None:
        if idx not in self.present:
            self.present.add(idx)
            self.entries.append((idx, start))

    def compact(self) -> None:
        if self.head > 64 and self.head * 2 > len(self.entries):
            self.entries = self.entries[self.head:]
            self.head = 0


class ClusterSim:
    def __init__(self, spec: ClusterSpec, scheduler: SchedulerBase, *,
                 seed: int = 0, straggler_prob: float = 0.03,
                 straggler_factor: float = 3.0, speculative: bool = True,
                 speculation_threshold: float = 2.0):
        self.spec = spec
        self.sched = scheduler
        self.rng = random.Random(seed)
        self.straggler_prob = straggler_prob
        self.straggler_factor = straggler_factor
        self.speculative = speculative
        self.spec_threshold = speculation_threshold

        n = spec.num_nodes
        self.map_running: List[List[RunningTask]] = [[] for _ in range(n)]
        self.red_running: List[List[RunningTask]] = [[] for _ in range(n)]
        self.live: Dict[Tuple[TaskId, bool], RunningTask] = {}
        self.finished_tasks: set = set()
        self.spec_launched: set = set()
        self.n_speculative = 0
        self.events: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self.events_processed = 0
        # -- heartbeat liveness (deadlock/churn fix) -------------------------
        self._hb_dead: Set[int] = set()
        self._pending_submits = 0
        # -- incremental speculation state -----------------------------------
        self._spec_q: Dict[str, _SpecQueue] = {}
        self._job_seq: Dict[str, int] = {}
        # (wake_time, job_seq, job_id): job may have an eligible straggler
        # at wake_time; lazy — revalidated on pop
        self._spec_wake: List[Tuple[float, int, str]] = []
        # (job_seq, job_id): jobs whose wake time has passed
        self._spec_ready: List[Tuple[int, str]] = []
        self._spec_ready_set: Set[str] = set()
        self.reconfig: Optional[Reconfigurator] = getattr(
            scheduler, "reconfig", None) if scheduler.uses_reconfig else None
        if self.reconfig is not None:
            self.reconfig.validator = lambda vm: self.free_map(vm) > 0
        # -- decision-trace bus (TraceConfig; None = off, zero overhead) -----
        self.trace: Optional[TraceBus] = None
        if spec.tracing.enabled:
            self.trace = TraceBus(spec.tracing)
            # one bus shared by every decision maker: the scheduler and the
            # reconfigurator emit through the same sink, so the exported
            # trace interleaves launches, parks and latch flips in time order
            scheduler.trace = self.trace
            if self.reconfig is not None:
                self.reconfig.trace = self.trace
            self._next_pressure = 0.0
        # -- fault injection (FaultConfig; None = disabled, zero overhead) ---
        self.faults = spec.faults if spec.faults.enabled else None
        self.down_nodes: Set[int] = set()
        # FaultEvent named tuples: json.dumps renders them byte-identically
        # to the bare (time, kind, machine) tuples of earlier versions, so
        # the byte-reproducibility pins in tests/test_faults.py hold
        self.fault_log: List[FaultEvent] = []
        self.fault_stats = {"crashes": 0, "restarts": 0, "tasks_lost": 0,
                            "tasks_reexecuted": 0, "blocks_rereplicated": 0,
                            "bursts": 0}
        if self.faults is not None:
            m = spec.num_machines
            self.machine_up: List[bool] = [True] * m
            # dedicated per-machine streams: fault schedules are a function
            # of (config, seed, machine) and never touch self.rng, so the
            # duration/straggler draw order is identical with faults off
            self._crash_rng = [random.Random(f"{seed}:fault-crash:{i}")
                               for i in range(m)]
            self._burst_rng = [random.Random(f"{seed}:fault-burst:{i}")
                               for i in range(m)]
            self._machine_epoch: List[int] = [0] * m
            self._node_epoch: List[int] = [0] * spec.num_nodes
            self._burst_until: List[float] = [0.0] * m
            # lost (non-speculative) tasks not yet relaunched — drained by
            # _launch; the chaos audits assert it empties by sim end
            self.lost_pending: Set[TaskId] = set()
            # fault chains suspended because the cluster went idle; the
            # next submit revives them (same liveness rule as heartbeats)
            self._idle_crash_chains: Set[int] = set()
            self._idle_burst_chains: Set[int] = set()
        # -- serving layer (ServeConfig; None = disabled, zero overhead) -----
        # lazy import: serving pulls latency percentiles from
        # repro.experiments.stats, whose package imports this module
        self.serving = None
        self._serve_idle = False
        if spec.serve.active:
            from repro.simcluster.serving import ServingLayer
            self.serving = ServingLayer(spec, seed, sched=scheduler,
                                        reconfig=self.reconfig,
                                        trace=self.trace)

    # -- capacities ----------------------------------------------------------
    def map_capacity(self, node: int) -> int:
        cap = (self.reconfig.vcpus[node] if self.reconfig is not None
               else self.spec.base_map_slots)
        if self.serving is not None:
            # pinned service cores are carved out of the VM's map slots; a
            # harvest borrow shrinks the reservation (never the reconfig's
            # vcpu ledger), a preemptive return grows it back — free_map
            # may then go transiently negative: running maps drain, no new
            # ones launch
            cap -= self.serving.reserved[node]
        return cap

    def free_map(self, node: int) -> int:
        return self.map_capacity(node) - len(self.map_running[node])

    def free_reduce(self, node: int) -> int:
        return self.spec.base_reduce_slots - len(self.red_running[node])

    # -- event machinery ----------------------------------------------------
    def _push(self, t: float, kind: str, data=None) -> None:
        self._seq += 1
        heapq.heappush(self.events, (t, self._seq, kind, data))

    # -- duration model -------------------------------------------------------
    def _jitter(self, cv: float) -> float:
        if cv <= 0:
            return 1.0
        sigma = math.sqrt(math.log(1 + cv * cv))
        return self.rng.lognormvariate(-sigma * sigma / 2, sigma)

    def task_duration(self, job: JobRuntime, task: TaskId, local: bool,
                      node: Optional[int] = None, now: float = 0.0) -> float:
        prof = job.spec.profile
        mc = None
        if self.faults is not None and node is not None:
            # heterogeneous machine class of the hosting node (the base
            # class — all multipliers 1.0 — for a homogeneous fleet)
            mc = self.faults.machine_class(self.spec.machine_of(node))
        if task.kind == TaskKind.MAP:
            base = prof.map_time
            if not local:
                # remote_penalty_scale calibrates the fabric (1GbE -> 40GbE);
                # at the default 1.0 the product is bit-identical to the
                # seed's bare `prof.remote_penalty` (x * 1.0 == x in IEEE754)
                penalty = prof.remote_penalty * self.spec.remote_penalty_scale
                if mc is not None and mc.fabric != 1.0:
                    penalty *= mc.fabric
                base *= 1.0 + penalty
        else:
            # reduce = copy (one stream per mapper) + sort/reduce compute
            base = prof.reduce_time + job.spec.u_m * prof.shuffle_time_per_pair
        if mc is not None and mc.speed != 1.0:
            base *= mc.speed
        d = base * self._jitter(prof.time_cv)
        if self.rng.random() < self.straggler_prob:
            d *= self.straggler_factor
        if (self.faults is not None and node is not None
                and now < self._burst_until[self.spec.machine_of(node)]):
            # correlated straggler episode on this machine
            d *= self.faults.burst_slowdown
        return d

    # -- main loop --------------------------------------------------------------
    def run(self, jobs: List[JobSpec], until: float = 10_000_000.0) -> SimResult:
        faults = self.faults
        if faults is not None:
            # re-replication mutates block placements in place: give this
            # run its own placement lists so a caller-shared JobSpec (e.g.
            # the fuzz harness running one scenario through two engines)
            # never sees another run's mutations
            jobs = [dataclasses.replace(
                j, block_placement=[tuple(p) for p in j.block_placement])
                for j in jobs]
        self._pending_submits = len(jobs)
        for job in jobs:
            self._push(job.submit_time, "submit", job)
        for node in range(self.spec.num_nodes):
            self._push(self.spec.heartbeat_interval * (1 + node / self.spec.num_nodes),
                       "heartbeat", node if faults is None else (node, 0))
        if faults is not None:
            if faults.crash_mtbf > 0:
                for m in range(self.spec.num_machines):
                    self._push(faults.crash_warmup + self._next_uptime(m),
                               "crash", m)
            if faults.burst_rate > 0:
                for m in range(self.spec.num_machines):
                    self._push(self._burst_rng[m].expovariate(
                        1.0 / faults.burst_rate), "burst", m)
        if self.serving is not None:
            # one global serve chain at the heartbeat interval; like the
            # heartbeat/fault chains it dies when the cluster drains and
            # the next submit revives it (arrivals are generated from the
            # replicas' own streams at the next tick, so a revived tick
            # covers the whole idle gap with correctly-timed requests)
            self._push(self.spec.heartbeat_interval, "serve", None)
        now = 0.0
        while self.events:
            now, _, kind, data = heapq.heappop(self.events)
            if now > until:
                break
            self.events_processed += 1
            if kind == "submit":
                self._pending_submits -= 1
                self._job_seq[data.job_id] = len(self._job_seq)
                self.sched.job_added(data, now)
                if self.trace is not None and self.trace.launches:
                    rt_job = self.sched.jobs[data.job_id]
                    self.trace.emit(now, "job_submit", {
                        "job": data.job_id, "maps": data.u_m,
                        "reduces": data.v_r,
                        "deadline": rt_job.absolute_deadline})
                if self._hb_dead:
                    # revive heartbeat chains that stopped while the cluster
                    # was idle — without this, a job submitted after an idle
                    # gap would never be scheduled (seed deadlock)
                    if faults is None:
                        for node in sorted(self._hb_dead):
                            self._push(
                                now + self.spec.heartbeat_interval
                                * (1 + node / self.spec.num_nodes),
                                "heartbeat", node)
                        self._hb_dead.clear()
                    else:
                        # down nodes stay dead — their restart re-arms them
                        for node in sorted(self._hb_dead - self.down_nodes):
                            self._push(
                                now + self.spec.heartbeat_interval
                                * (1 + node / self.spec.num_nodes),
                                "heartbeat", (node, self._node_epoch[node]))
                            self._hb_dead.discard(node)
                if faults is not None:
                    self._revive_fault_chains(now)
                if self._serve_idle:
                    self._serve_idle = False
                    self._push(now + self.spec.heartbeat_interval,
                               "serve", None)
            elif kind == "finish":
                self._on_finish(data, now)
            elif kind == "plug":
                self._on_plug_ready(now)
            elif kind == "heartbeat":
                if faults is None:
                    node = data
                else:
                    node, epoch = data
                    if (epoch != self._node_epoch[node]
                            or node in self.down_nodes):
                        # stale chain (the node crashed since this beat was
                        # armed) or currently-down node: the chain dies
                        # here; the machine's restart arms a fresh one
                        continue
                self._heartbeat(node, now)
                if self.sched.has_active_jobs() or (
                        not self.sched.jobs and self._pending_submits > 0):
                    self._push(now + self.spec.heartbeat_interval, "heartbeat",
                               node if faults is None
                               else (node, self._node_epoch[node]))
                else:
                    # idle: let this chain die instead of ticking forever;
                    # the next submit revives it
                    self._hb_dead.add(node)
            elif kind == "serve":
                self._on_serve_tick(now)
            elif kind == "crash":
                self._on_crash(data, now)
            elif kind == "restart":
                self._on_restart(data, now)
            elif kind == "burst":
                self._on_burst(data, now)
            elif kind == "rereplicate":
                self._on_rereplicate(data[0], data[1], now)
        result = SimResult(
            scheduler=self.sched.name,
            jobs=self.sched.jobs,
            makespan=max((j.finish_time or now) for j in self.sched.jobs.values())
            if self.sched.jobs else 0.0,
            reconfig_stats=dict(self.reconfig.stats) if self.reconfig else {},
            speculative_launches=self.n_speculative,
            events_processed=self.events_processed,
            fault_stats=dict(self.fault_stats) if faults is not None else {},
            fault_log=list(self.fault_log),
            trace=self.trace,
            serve_stats=(self.serving.stats()
                         if self.serving is not None else {}),
            serve_log=(list(self.serving.log)
                       if self.serving is not None else []),
        )
        return result

    # -- handlers -------------------------------------------------------------
    def _launch(self, launch: Launch, now: float, speculative: bool = False) -> None:
        job = self.sched.jobs[launch.task.job_id]
        dur = self.task_duration(job, launch.task, launch.local,
                                 launch.node, now)
        if (self.faults is not None and not speculative
                and launch.task in self.lost_pending):
            self.lost_pending.discard(launch.task)
            self.fault_stats["tasks_reexecuted"] += 1
        rt = RunningTask(launch.task, launch.node, now, now + dur,
                         launch.local, speculative)
        if launch.task.kind == TaskKind.MAP:
            self.map_running[launch.node].append(rt)
            if not speculative:
                jid = launch.task.job_id
                q = self._spec_q.get(jid)
                if q is None:
                    q = self._spec_q[jid] = _SpecQueue()
                q.append(launch.task.index, now)
                if job.map_durations:
                    mean = job.map_duration_sum / len(job.map_durations)
                    self._spec_push_wake(
                        jid, now + self.spec_threshold * mean)
        else:
            self.red_running[launch.node].append(rt)
        self.live[(launch.task, speculative)] = rt
        self._push(rt.finish, "finish", rt)
        tr = self.trace
        if tr is not None and tr.launches:
            tr.emit(now, "launch", {
                "task": launch.task, "job": launch.task.job_id,
                "tkind": launch.task.kind.value, "node": launch.node,
                "machine": self.spec.machine_of(launch.node),
                "local": launch.local, "spec": speculative,
                "via_reconfig": launch.via_reconfig})

    def _on_finish(self, rt: RunningTask, now: float) -> None:
        if rt.dead:
            # a crash killed this attempt: its finish is void.  The task
            # may already be re-running under the same live key — without
            # this check the stale finish would complete the task early
            # and strand the re-execution's RunningTask in its slot.
            # (A *cancelled* duplicate is the next check: its live key is
            # gone.  The key-membership semantics below stay byte-exact
            # with the frozen engine for every non-crash path.)
            return
        if (rt.task, rt.speculative) not in self.live:
            # cancelled duplicate.  The frozen engine leaves a reconfig
            # double-launch's losing attempt in its running list forever
            # (a one-slot leak, bit-exactly mirrored while faults are
            # off); under churn a leaked slot compounds with crash
            # capacity loss, so the fault-aware engine frees it here.
            if self.faults is not None:
                lst = (self.map_running if rt.task.kind == TaskKind.MAP
                       else self.red_running)[rt.node]
                if rt in lst:
                    lst.remove(rt)
            if self.trace is not None and self.trace.launches \
                    and not rt.cancelled:
                # the reconfig double-launch loser: twin-cancelled attempts
                # already emitted their kill at cancellation time
                self.trace.emit(now, "kill", {
                    "task": rt.task, "job": rt.task.job_id,
                    "tkind": rt.task.kind.value, "node": rt.node,
                    "spec": rt.speculative, "start": rt.start,
                    "cause": "stale_duplicate"})
            return
        del self.live[(rt.task, rt.speculative)]
        lst = (self.map_running if rt.task.kind == TaskKind.MAP
               else self.red_running)[rt.node]
        if rt in lst:
            lst.remove(rt)
        if rt.task in self.finished_tasks:
            return
        self.finished_tasks.add(rt.task)
        # cancel the twin if speculation duplicated this task
        twin_key = (rt.task, not rt.speculative)
        if twin_key in self.live:
            twin = self.live.pop(twin_key)
            twin.cancelled = True
            tl = (self.map_running if rt.task.kind == TaskKind.MAP
                  else self.red_running)[twin.node]
            if twin in tl:
                tl.remove(twin)
            if self.trace is not None and self.trace.launches:
                self.trace.emit(now, "kill", {
                    "task": twin.task, "job": twin.task.job_id,
                    "tkind": twin.task.kind.value, "node": twin.node,
                    "spec": twin.speculative, "start": twin.start,
                    "cause": "twin_cancel"})
        self.sched.task_finished(rt.task, rt.node, now, now - rt.start)
        tr = self.trace
        if tr is not None and tr.launches:
            tr.emit(now, "finish", {
                "task": rt.task, "job": rt.task.job_id,
                "tkind": rt.task.kind.value, "node": rt.node,
                "machine": self.spec.machine_of(rt.node),
                "start": rt.start, "duration": now - rt.start,
                "local": rt.local, "spec": rt.speculative})
            fin_job = self.sched.jobs[rt.task.job_id]
            if fin_job.all_done and fin_job.finish_time == now:
                tr.emit(now, "job_finish", {
                    "job": rt.task.job_id,
                    "duration": now - fin_job.spec.submit_time,
                    "deadline_met": now <= fin_job.absolute_deadline + 1e-9})
        if rt.task.kind == TaskKind.MAP:
            # the job's mean map duration changed: its head straggler may
            # now cross the speculation threshold earlier (or at all)
            jid = rt.task.job_id
            job = self.sched.jobs[jid]
            q = self._spec_q.get(jid)
            if q is not None and job.running_map and job.map_durations:
                mean = job.map_duration_sum / len(job.map_durations)
                head = self._spec_head_start(q, job)
                if head is not None:
                    self._spec_push_wake(
                        jid, max(now, head + self.spec_threshold * mean))
        # Paper §4.1: "the target system will soon have a free core, as a
        # task finishes in one of the VMs, and a local task is not found for
        # the VM" — on every map finish, a VM with no local pending work
        # offers its freed core if a neighbour VM has a parked task waiting.
        if self.reconfig is not None and rt.task.kind == TaskKind.MAP:
            vm = rt.node
            if self.reconfig.adaptive.enabled:
                # release-interval hook: every map finish frees a core on vm
                # (whether or not it is offered below) — feed the machine's
                # core-free EWMA so park_decision can price the wait
                self.reconfig.observe_core_free(vm, now)
            if (self.free_map(vm) > 0
                    and (self.reconfig.vcpus[vm] > self.spec.base_map_slots
                         or (isinstance(self.sched, CompletionTimeScheduler)
                             and not self.sched.has_local_pending(vm)))):
                self.reconfig.release_core(vm, now)
            self._match_reconfig(now)

    def _on_plug_ready(self, now: float) -> None:
        if self.reconfig is None:
            return
        for plug in self.reconfig.complete_plugs(now):
            task = plug.task
            job = self.sched.jobs.get(task.job_id)
            if job is None or task.index in job.completed_map:
                continue
            self.sched.parked_task_launched(task, plug.to_vm, now)
            self._launch(Launch(task, plug.to_vm, local=True,
                                via_reconfig=True), now)

    def _match_reconfig(self, now: float) -> None:
        if self.reconfig is None:
            return
        started = self.reconfig.match(now, donor_ok=lambda vm: self.free_map(vm) > 0)
        for plug in started:
            self._push(plug.ready_at, "plug", None)

    def _heartbeat(self, node: int, now: float) -> None:
        # expire stale parked tasks back to the scheduler for remote launch
        if self.reconfig is not None:
            for parked in self.reconfig.expire_stale(now):
                if isinstance(self.sched, CompletionTimeScheduler):
                    self.sched.parked_task_expired(parked.task, now)
            self._match_reconfig(now)
        fm, fr = max(0, self.free_map(node)), self.free_reduce(node)
        if fm > 0 or fr > 0:
            for launch in self.sched.select(node, fm, fr, now):
                self._launch(launch, now)
            self._match_reconfig(now)   # pair fresh AQ entries immediately
        if self.speculative:
            self._maybe_speculate(node, now)
        tr = self.trace
        if (tr is not None and tr.pressure_every > 0.0
                and now >= self._next_pressure):
            self._next_pressure = now + tr.pressure_every
            self._emit_pressure(now)

    def _emit_pressure(self, now: float) -> None:
        """Periodic cluster pressure snapshot (TraceConfig.pressure_every):
        the same incremental signals park_decision and the overload latch
        read, so a timeline of these explains every admission flip."""
        sched = self.sched
        data: Dict[str, object] = {
            "active_jobs": len(sched.active),
            "pending_maps": sched.total_pending_maps,
            "ready_reduces": sched.ready_pending_reduces,
            "map_open_jobs": sched.map_open_jobs,
            "overload": bool(getattr(sched, "overload_mode", False)),
            "down_nodes": len(self.down_nodes),
        }
        rc = self.reconfig
        if rc is not None:
            data["parked"] = sum(len(q) for q in rc.aq)
            data["rq_depth"] = list(rc.rq_depth)
            data["fail_streak"] = list(rc.fail_streak)
            data["offer_ewma"] = list(rc.offer_ewma)
            data["free_ewma"] = list(rc.free_ewma)
            data["park_outcome_ewma"] = rc.park_outcome_ewma
        self.trace.emit(now, "pressure", data)

    # -- serving layer (ServeConfig; handler unreachable when off) ------------
    def _on_serve_tick(self, now: float) -> None:
        """One global serve tick: advance every replica's arrival stream,
        drain its queue, fold latency/SLO counters, run harvest.  The
        chain follows the heartbeat liveness rule so a drained run
        terminates; a revived tick covers the idle gap exactly (arrivals
        carry their true times)."""
        if not (self.sched.has_active_jobs() or self._pending_submits > 0):
            self._serve_idle = True
            return
        self.serving.tick(now)
        self._push(now + self.spec.heartbeat_interval, "serve", None)

    # -- fault injection (FaultConfig; handlers unreachable when off) ---------
    def _fault_live(self) -> bool:
        """Fault chains follow the heartbeat liveness rule: they tick only
        while there is (or will be) work, so a drained run terminates."""
        return self.sched.has_active_jobs() or self._pending_submits > 0

    def _next_uptime(self, machine: int) -> float:
        f = self.faults
        mtbf = f.crash_mtbf * f.machine_class(machine).mtbf_scale
        return self._crash_rng[machine].expovariate(1.0 / mtbf)

    def _revive_fault_chains(self, now: float) -> None:
        f = self.faults
        for m in sorted(self._idle_crash_chains):
            self._push(now + self._next_uptime(m), "crash", m)
        self._idle_crash_chains.clear()
        for m in sorted(self._idle_burst_chains):
            self._push(now + self._burst_rng[m].expovariate(
                1.0 / f.burst_rate), "burst", m)
        self._idle_burst_chains.clear()

    def _machine_nodes(self, machine: int) -> List[int]:
        vpm = self.spec.vms_per_machine
        return list(range(machine * vpm, (machine + 1) * vpm))

    def _on_crash(self, machine: int, now: float) -> None:
        f = self.faults
        if not self._fault_live():
            self._idle_crash_chains.add(machine)
            return
        self.machine_up[machine] = False
        self.fault_stats["crashes"] += 1
        self.fault_log.append(FaultEvent(now, "crash", machine))
        nodes = self._machine_nodes(machine)
        if self.trace is not None and self.trace.faults:
            self.trace.emit(now, "crash", {
                "machine": machine, "nodes": nodes,
                "running": sum(len(self.map_running[v])
                               + len(self.red_running[v]) for v in nodes)})
        self.down_nodes.update(nodes)
        for v in nodes:
            # bump the chain epoch: any pending heartbeat of this node is
            # now stale and dies on pop (restart arms the next chain)
            self._node_epoch[v] += 1
        for v in nodes:
            for rt in self.map_running[v] + self.red_running[v]:
                self._kill_running(rt, now)
            self.map_running[v].clear()
            self.red_running[v].clear()
        if self.reconfig is not None:
            # cancelled AQ entries and aborted in-flight plugs: their tasks
            # are still pending and re-enter normal scheduling
            for task in self.reconfig.machine_down(machine, now):
                self.sched.parked_task_crashed(task, now)
        self.sched.node_down(nodes, now)
        if self.serving is not None:
            # chaos interaction: the machine's service replicas go down —
            # in-window arrivals shed, borrowed cores return immediately
            self.serving.machine_down(machine, now)
        self._push(now + self._crash_rng[machine].expovariate(
            1.0 / f.crash_mttr), "restart", machine)
        self._push(now + f.rereplicate_after, "rereplicate",
                   (machine, self._machine_epoch[machine]))

    def _kill_running(self, rt: RunningTask, now: float) -> None:
        """A crash killed this running task.  A speculative copy simply
        dies (the original keeps running and may be re-speculated); losing
        the original also kills any surviving speculative twin — the
        attempt's lineage is re-executed from scratch — and hands the task
        back to the scheduler (``task_lost`` restores the pending state)."""
        key = (rt.task, rt.speculative)
        if key not in self.live:
            return                        # already resolved this instant
        del self.live[key]
        rt.dead = True                    # voids the pending finish event
        self.fault_stats["tasks_lost"] += 1
        tr = self.trace
        if tr is not None and tr.launches:
            tr.emit(now, "kill", {
                "task": rt.task, "job": rt.task.job_id,
                "tkind": rt.task.kind.value, "node": rt.node,
                "spec": rt.speculative, "start": rt.start, "cause": "crash"})
        if rt.speculative:
            self.spec_launched.discard(rt.task)
            return
        twin = self.live.pop((rt.task, True), None)
        if twin is not None:
            twin.dead = True
            tl = (self.map_running if rt.task.kind == TaskKind.MAP
                  else self.red_running)[twin.node]
            if twin in tl:
                tl.remove(twin)
            self.spec_launched.discard(rt.task)
            if tr is not None and tr.launches:
                tr.emit(now, "kill", {
                    "task": twin.task, "job": twin.task.job_id,
                    "tkind": twin.task.kind.value, "node": twin.node,
                    "spec": True, "start": twin.start, "cause": "crash"})
        self.lost_pending.add(rt.task)
        self.sched.task_lost(rt.task, rt.node, now)

    def _on_restart(self, machine: int, now: float) -> None:
        f = self.faults
        self.machine_up[machine] = True
        self._machine_epoch[machine] += 1
        self.fault_stats["restarts"] += 1
        self.fault_log.append(FaultEvent(now, "restart", machine))
        if self.trace is not None and self.trace.faults:
            self.trace.emit(now, "restart", {"machine": machine})
        nodes = self._machine_nodes(machine)
        self.down_nodes.difference_update(nodes)
        if self.reconfig is not None:
            self.reconfig.machine_restarted(machine, now)
        if self.serving is not None:
            self.serving.machine_restarted(machine, now)
        self.sched.node_up(nodes, now)
        for v in nodes:
            # fresh heartbeat chain (the crash staled the old one); if the
            # cluster is idle the chain dies into _hb_dead as usual
            self._hb_dead.discard(v)
            self._push(now + self.spec.heartbeat_interval
                       * (1 + v / self.spec.num_nodes),
                       "heartbeat", (v, self._node_epoch[v]))
        if self._fault_live():
            self._push(now + self._next_uptime(machine), "crash", machine)
        else:
            self._idle_crash_chains.add(machine)

    def _on_burst(self, machine: int, now: float) -> None:
        f = self.faults
        if not self._fault_live():
            self._idle_burst_chains.add(machine)
            return
        self._burst_until[machine] = now + f.burst_duration
        self.fault_stats["bursts"] += 1
        self.fault_log.append(FaultEvent(now, "burst", machine))
        if self.trace is not None and self.trace.faults:
            self.trace.emit(now, "burst", {
                "machine": machine, "until": self._burst_until[machine],
                "slowdown": f.burst_slowdown})
        self._push(now + self._burst_rng[machine].expovariate(
            1.0 / f.burst_rate), "burst", machine)

    def _on_rereplicate(self, machine: int, epoch: int, now: float) -> None:
        """Grace window elapsed with the machine still down: every pending
        map block whose replicas are *all* on crashed nodes gets one new
        replica (restored from the durable store) on a surviving node —
        deterministically the nearest live node id after the block's
        primary — restoring schedulable locality.  Blocks with a live
        replica are left alone (the scheduler already reaches them)."""
        if self.machine_up[machine] or self._machine_epoch[machine] != epoch:
            return                        # restarted before the window
        n = self.spec.num_nodes
        down = self.down_nodes
        count = 0
        for job in list(self.sched.active.values()):
            placement = job.spec.block_placement
            for idx in sorted(job.pending_map):
                pl = placement[idx]
                if not pl or any(v not in down for v in pl):
                    continue
                new = next((c for k in range(1, n)
                            if (c := (pl[0] + k) % n) not in down), None)
                if new is None:
                    continue              # whole cluster down
                placement[idx] = pl + (new,)
                heapq.heappush(job._local_heaps.setdefault(new, []), idx)
                self.sched.local_pending_count[new] += 1
                count += 1
        if count:
            self.fault_stats["blocks_rereplicated"] += count
            self.fault_log.append(FaultEvent(now, "rereplicate", machine))
            if self.trace is not None and self.trace.faults:
                self.trace.emit(now, "rereplicate",
                                {"machine": machine, "blocks": count})

    # -- incremental speculative execution ------------------------------------
    def _spec_push_wake(self, jid: str, wake: float) -> None:
        # nudge the wake a hair early: `start + θ·mean` can round *above* the
        # exact eligibility boundary `now - start > θ·mean`; waking early is
        # harmless (candidates are revalidated with the exact expression),
        # waking late would miss the seed's pick
        heapq.heappush(self._spec_wake,
                       (wake - 1e-6, self._job_seq.get(jid, 0), jid))

    def _spec_head_start(self, q: _SpecQueue, job: JobRuntime) -> Optional[float]:
        """Drop permanently-dead head entries; return the head's *recorded*
        (append-time) start.  Recorded starts are non-decreasing along the
        queue and never exceed the live start, so a wake computed from the
        head's recorded start lower-bounds every entry's eligibility time —
        even when a re-launch refreshed some entry's live start.  An early
        wake only costs one extra revalidation."""
        entries, running = q.entries, job.running_map
        while q.head < len(entries):
            idx, start = entries[q.head]
            if idx not in running or TaskId(
                    job.spec.job_id, TaskKind.MAP, idx) in self.spec_launched:
                q.present.discard(idx)
                q.head += 1
                continue
            q.compact()
            return start
        q.compact()
        return None

    def _spec_candidate(self, job: JobRuntime, q: _SpecQueue,
                        now: float) -> Optional[TaskId]:
        """First speculation-eligible running map in insertion order.

        Append-time starts are non-decreasing, so once an entry whose live
        start equals its recorded start is ineligible, every later entry is
        too, and the walk stops.  An entry whose start was *refreshed* by a
        re-launch (live start > recorded) does not bound its successors, so
        the walk continues past it — matching the seed's full dict scan.
        """
        if not job.map_durations:
            return None
        threshold = (self.spec_threshold
                     * (job.map_duration_sum / len(job.map_durations)))
        entries, running = q.entries, job.running_map
        jid = job.spec.job_id
        i = q.head
        while i < len(entries):
            idx, rec_start = entries[i]
            task = TaskId(jid, TaskKind.MAP, idx)
            if idx not in running or task in self.spec_launched:
                if i == q.head:           # permanently dead: drop from head
                    q.present.discard(idx)
                    q.head += 1
                i += 1
                continue
            rt = self.live.get((task, False))
            if rt is None:
                i += 1                    # running but not live: seed skips it
                continue
            if now - rt.start > threshold:
                return task
            if rt.start <= rec_start:
                return None               # unrefreshed + ineligible: walk ends
            i += 1                        # refreshed entry: keep scanning
        return None

    def _maybe_speculate(self, node: int, now: float) -> None:
        """Hadoop-style speculative re-execution of straggling maps.

        Identical decisions to the seed's per-heartbeat full rescan, found
        via the lazy wake heap: first submitted job with an eligible
        straggler, earliest-launched eligible map of that job."""
        if self.free_map(node) <= 0:
            return
        wake, ready, ready_set = (self._spec_wake, self._spec_ready,
                                  self._spec_ready_set)
        while wake and wake[0][0] <= now:
            _, seq, jid = heapq.heappop(wake)
            if jid not in ready_set:
                ready_set.add(jid)
                heapq.heappush(ready, (seq, jid))
        while ready:
            seq, jid = ready[0]
            job = self.sched.jobs[jid]
            q = self._spec_q.get(jid)
            task = (None if (job.finished or q is None)
                    else self._spec_candidate(job, q, now))
            if task is not None:
                self.spec_launched.add(task)
                self.n_speculative += 1
                idx = task.index
                local = node in job.spec.block_placement[idx]
                self._launch(Launch(task, node, local=local), now,
                             speculative=True)
                return
            # not eligible now: drop from the ready set and, if the job still
            # has a live head, schedule its next possible eligibility time
            heapq.heappop(ready)
            ready_set.discard(jid)
            if q is not None and not job.finished and job.map_durations:
                head = self._spec_head_start(q, job)
                if head is not None:
                    mean = job.map_duration_sum / len(job.map_durations)
                    self._spec_push_wake(
                        jid, max(now, head + self.spec_threshold * mean))
