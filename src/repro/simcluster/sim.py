"""Discrete-event simulator of the virtualized MapReduce cluster (paper §5).

Models: physical machines hosting VMs, per-VM map/reduce slots, HDFS-style
replicated block placement, remote-read penalty for non-local map tasks,
heartbeats (3 s), vCPU hot-plug latency, per-task duration jitter,
stragglers + speculative re-execution.

The simulator is scheduler-agnostic: any ``SchedulerBase`` subclass plugs in.
For ``CompletionTimeScheduler`` the per-VM map capacity follows the
reconfigurator's live vCPU counts (Algorithm 1); baselines keep the static
slot configuration — exactly the comparison of paper §5.
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler, Launch, SchedulerBase
from repro.core.types import ClusterSpec, JobRuntime, JobSpec, TaskId, TaskKind


@dataclass
class RunningTask:
    task: TaskId
    node: int
    start: float
    finish: float
    local: bool
    speculative: bool = False


@dataclass
class SimResult:
    scheduler: str
    jobs: Dict[str, JobRuntime]
    makespan: float
    reconfig_stats: Dict[str, float] = field(default_factory=dict)
    speculative_launches: int = 0

    # -- derived metrics ----------------------------------------------------
    def completion_time(self, job_id: str) -> float:
        j = self.jobs[job_id]
        return (j.finish_time or math.inf) - j.spec.submit_time

    def throughput_jobs_per_hour(self) -> float:
        done = [j for j in self.jobs.values() if j.finish_time is not None]
        if not done or self.makespan <= 0:
            return 0.0
        return len(done) * 3600.0 / self.makespan

    def deadlines_met(self) -> int:
        return sum(1 for j in self.jobs.values()
                   if j.finish_time is not None
                   and j.finish_time <= j.absolute_deadline + 1e-9)

    def locality_rate(self) -> float:
        loc = sum(j.local_map_launches for j in self.jobs.values())
        tot = loc + sum(j.remote_map_launches for j in self.jobs.values())
        return loc / tot if tot else 0.0


class ClusterSim:
    def __init__(self, spec: ClusterSpec, scheduler: SchedulerBase, *,
                 seed: int = 0, straggler_prob: float = 0.03,
                 straggler_factor: float = 3.0, speculative: bool = True,
                 speculation_threshold: float = 2.0):
        self.spec = spec
        self.sched = scheduler
        self.rng = random.Random(seed)
        self.straggler_prob = straggler_prob
        self.straggler_factor = straggler_factor
        self.speculative = speculative
        self.spec_threshold = speculation_threshold

        n = spec.num_nodes
        self.map_running: List[List[RunningTask]] = [[] for _ in range(n)]
        self.red_running: List[List[RunningTask]] = [[] for _ in range(n)]
        self.live: Dict[Tuple[TaskId, bool], RunningTask] = {}
        self.finished_tasks: set = set()
        self.spec_launched: set = set()
        self.n_speculative = 0
        self.events: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self.reconfig: Optional[Reconfigurator] = getattr(
            scheduler, "reconfig", None) if scheduler.uses_reconfig else None
        if self.reconfig is not None:
            self.reconfig.validator = lambda vm: self.free_map(vm) > 0

    # -- capacities ----------------------------------------------------------
    def map_capacity(self, node: int) -> int:
        if self.reconfig is not None:
            return self.reconfig.vcpus[node]
        return self.spec.base_map_slots

    def free_map(self, node: int) -> int:
        return self.map_capacity(node) - len(self.map_running[node])

    def free_reduce(self, node: int) -> int:
        return self.spec.base_reduce_slots - len(self.red_running[node])

    # -- event machinery ----------------------------------------------------
    def _push(self, t: float, kind: str, data=None) -> None:
        self._seq += 1
        heapq.heappush(self.events, (t, self._seq, kind, data))

    # -- duration model -------------------------------------------------------
    def _jitter(self, cv: float) -> float:
        if cv <= 0:
            return 1.0
        sigma = math.sqrt(math.log(1 + cv * cv))
        return self.rng.lognormvariate(-sigma * sigma / 2, sigma)

    def task_duration(self, job: JobRuntime, task: TaskId, local: bool) -> float:
        prof = job.spec.profile
        if task.kind == TaskKind.MAP:
            base = prof.map_time
            if not local:
                base *= 1.0 + prof.remote_penalty
        else:
            # reduce = copy (one stream per mapper) + sort/reduce compute
            base = prof.reduce_time + job.spec.u_m * prof.shuffle_time_per_pair
        d = base * self._jitter(prof.time_cv)
        if self.rng.random() < self.straggler_prob:
            d *= self.straggler_factor
        return d

    # -- main loop --------------------------------------------------------------
    def run(self, jobs: List[JobSpec], until: float = 10_000_000.0) -> SimResult:
        for job in jobs:
            self._push(job.submit_time, "submit", job)
        for node in range(self.spec.num_nodes):
            self._push(self.spec.heartbeat_interval * (1 + node / self.spec.num_nodes),
                       "heartbeat", node)
        now = 0.0
        while self.events:
            now, _, kind, data = heapq.heappop(self.events)
            if now > until:
                break
            if kind == "submit":
                self.sched.job_added(data, now)
            elif kind == "finish":
                self._on_finish(data, now)
            elif kind == "plug":
                self._on_plug_ready(now)
            elif kind == "heartbeat":
                node = data
                self._heartbeat(node, now)
                if any(not j.finished for j in self.sched.jobs.values()) or \
                        not self.sched.jobs:
                    self._push(now + self.spec.heartbeat_interval, "heartbeat",
                               node)
        result = SimResult(
            scheduler=self.sched.name,
            jobs=self.sched.jobs,
            makespan=max((j.finish_time or now) for j in self.sched.jobs.values())
            if self.sched.jobs else 0.0,
            reconfig_stats=dict(self.reconfig.stats) if self.reconfig else {},
            speculative_launches=self.n_speculative,
        )
        return result

    # -- handlers -------------------------------------------------------------
    def _launch(self, launch: Launch, now: float, speculative: bool = False) -> None:
        job = self.sched.jobs[launch.task.job_id]
        dur = self.task_duration(job, launch.task, launch.local)
        rt = RunningTask(launch.task, launch.node, now, now + dur,
                         launch.local, speculative)
        if launch.task.kind == TaskKind.MAP:
            self.map_running[launch.node].append(rt)
        else:
            self.red_running[launch.node].append(rt)
        self.live[(launch.task, speculative)] = rt
        self._push(rt.finish, "finish", rt)

    def _on_finish(self, rt: RunningTask, now: float) -> None:
        if (rt.task, rt.speculative) not in self.live:
            return                      # cancelled duplicate
        del self.live[(rt.task, rt.speculative)]
        lst = (self.map_running if rt.task.kind == TaskKind.MAP
               else self.red_running)[rt.node]
        if rt in lst:
            lst.remove(rt)
        if rt.task in self.finished_tasks:
            return
        self.finished_tasks.add(rt.task)
        # cancel the twin if speculation duplicated this task
        twin_key = (rt.task, not rt.speculative)
        if twin_key in self.live:
            twin = self.live.pop(twin_key)
            tl = (self.map_running if rt.task.kind == TaskKind.MAP
                  else self.red_running)[twin.node]
            if twin in tl:
                tl.remove(twin)
        self.sched.task_finished(rt.task, rt.node, now, now - rt.start)
        # Paper §4.1: "the target system will soon have a free core, as a
        # task finishes in one of the VMs, and a local task is not found for
        # the VM" — on every map finish, a VM with no local pending work
        # offers its freed core if a neighbour VM has a parked task waiting.
        if self.reconfig is not None and rt.task.kind == TaskKind.MAP:
            vm = rt.node
            if (self.free_map(vm) > 0
                    and (self.reconfig.vcpus[vm] > self.spec.base_map_slots
                         or (isinstance(self.sched, CompletionTimeScheduler)
                             and not self.sched.has_local_pending(vm)))):
                self.reconfig.release_core(vm, now)
            self._match_reconfig(now)

    def _on_plug_ready(self, now: float) -> None:
        if self.reconfig is None:
            return
        for plug in self.reconfig.complete_plugs(now):
            task = plug.task
            job = self.sched.jobs.get(task.job_id)
            if job is None or task.index in job.completed_map:
                continue
            self.sched.parked_task_launched(task, plug.to_vm, now)
            self._launch(Launch(task, plug.to_vm, local=True,
                                via_reconfig=True), now)

    def _match_reconfig(self, now: float) -> None:
        if self.reconfig is None:
            return
        started = self.reconfig.match(now, donor_ok=lambda vm: self.free_map(vm) > 0)
        for plug in started:
            self._push(plug.ready_at, "plug", None)

    def _heartbeat(self, node: int, now: float) -> None:
        # expire stale parked tasks back to the scheduler for remote launch
        if self.reconfig is not None:
            for parked in self.reconfig.expire_stale(now):
                if isinstance(self.sched, CompletionTimeScheduler):
                    self.sched.parked_task_expired(parked.task, now)
            self._match_reconfig(now)
        fm, fr = self.free_map(node), self.free_reduce(node)
        if fm > 0 or fr > 0:
            for launch in self.sched.select(node, fm, fr, now):
                self._launch(launch, now)
            self._match_reconfig(now)   # pair fresh AQ entries immediately
        if self.speculative:
            self._maybe_speculate(node, now)

    def _maybe_speculate(self, node: int, now: float) -> None:
        """Hadoop-style speculative re-execution of straggling maps."""
        if self.free_map(node) <= 0:
            return
        for job in self.sched.jobs.values():
            if job.finished or not job.map_durations:
                continue
            mean = sum(job.map_durations) / len(job.map_durations)
            for idx, vnode in list(job.running_map.items()):
                task = TaskId(job.spec.job_id, TaskKind.MAP, idx)
                key = (task, False)
                if key not in self.live or task in self.spec_launched:
                    continue
                rt = self.live[key]
                if now - rt.start > self.spec_threshold * mean:
                    self.spec_launched.add(task)
                    self.n_speculative += 1
                    local = node in job.spec.block_placement[idx]
                    self._launch(Launch(task, node, local=local), now,
                                 speculative=True)
                    return
