"""Discrete-event simulator of the virtualized MapReduce cluster (paper §5).

Models: physical machines hosting VMs, per-VM map/reduce slots, HDFS-style
replicated block placement, remote-read penalty for non-local map tasks,
heartbeats (3 s), vCPU hot-plug latency, per-task duration jitter,
stragglers + speculative re-execution.

The simulator is scheduler-agnostic: any ``SchedulerBase`` subclass plugs in.
For ``CompletionTimeScheduler`` the per-VM map capacity follows the
reconfigurator's live vCPU counts (Algorithm 1); baselines keep the static
slot configuration — exactly the comparison of paper §5.

Engine notes (vs. the frozen seed engine in ``repro.simcluster._legacy``):

* **Speculation is incremental.**  The seed rescanned every running map of
  every job on every heartbeat.  Here each job keeps an insertion-ordered
  run queue (same order as ``running_map`` dict insertion, which the seed
  iterated) plus a lazy wake-time heap: a job is only examined once
  ``head_start + threshold × mean`` has passed.  Every event that can make
  a job eligible earlier (new sample changing the mean, new running task)
  pushes a fresh wake entry, so no eligibility point is missed.  The chosen
  (job, task) is identical to the seed scan: first job in submission order,
  first running map in insertion order.
* **Heartbeats stop when idle and re-arm on submit.**  The seed re-armed a
  node's heartbeat only while some *current* job was unfinished — a job
  submitted after an idle gap was never scheduled (deadlock), while a run
  with no jobs ticked forever.  Heartbeat chains now die when there is no
  active job, and every ``submit`` event revives dead chains.
* ``events_processed`` counts processed events for benchmarking.
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler, Launch, SchedulerBase
from repro.core.types import ClusterSpec, JobRuntime, JobSpec, TaskId, TaskKind


@dataclass
class RunningTask:
    task: TaskId
    node: int
    start: float
    finish: float
    local: bool
    speculative: bool = False


@dataclass
class SimResult:
    scheduler: str
    jobs: Dict[str, JobRuntime]
    makespan: float
    reconfig_stats: Dict[str, float] = field(default_factory=dict)
    speculative_launches: int = 0
    events_processed: int = 0

    # -- derived metrics ----------------------------------------------------
    def completion_time(self, job_id: str) -> float:
        j = self.jobs[job_id]
        return (j.finish_time or math.inf) - j.spec.submit_time

    def throughput_jobs_per_hour(self) -> float:
        done = [j for j in self.jobs.values() if j.finish_time is not None]
        if not done or self.makespan <= 0:
            return 0.0
        return len(done) * 3600.0 / self.makespan

    def deadlines_met(self) -> int:
        return sum(1 for j in self.jobs.values()
                   if j.finish_time is not None
                   and j.finish_time <= j.absolute_deadline + 1e-9)

    def locality_rate(self) -> float:
        loc = sum(j.local_map_launches for j in self.jobs.values())
        tot = loc + sum(j.remote_map_launches for j in self.jobs.values())
        return loc / tot if tot else 0.0


class _SpecQueue:
    """Insertion-ordered running-map queue of one job, for speculation.

    Mirrors ``running_map`` dict-key order exactly: a re-launch of an index
    already present (parked task also launched directly) keeps its original
    position, like a dict key re-assignment.  Entries are (idx, append-time
    start); the *live* RunningTask's start is authoritative — a later
    re-launch refreshes it, which the eligibility walk accounts for.
    """

    __slots__ = ("entries", "head", "present")

    def __init__(self) -> None:
        self.entries: List[Tuple[int, float]] = []
        self.head = 0
        self.present: Set[int] = set()

    def append(self, idx: int, start: float) -> None:
        if idx not in self.present:
            self.present.add(idx)
            self.entries.append((idx, start))

    def compact(self) -> None:
        if self.head > 64 and self.head * 2 > len(self.entries):
            self.entries = self.entries[self.head:]
            self.head = 0


class ClusterSim:
    def __init__(self, spec: ClusterSpec, scheduler: SchedulerBase, *,
                 seed: int = 0, straggler_prob: float = 0.03,
                 straggler_factor: float = 3.0, speculative: bool = True,
                 speculation_threshold: float = 2.0):
        self.spec = spec
        self.sched = scheduler
        self.rng = random.Random(seed)
        self.straggler_prob = straggler_prob
        self.straggler_factor = straggler_factor
        self.speculative = speculative
        self.spec_threshold = speculation_threshold

        n = spec.num_nodes
        self.map_running: List[List[RunningTask]] = [[] for _ in range(n)]
        self.red_running: List[List[RunningTask]] = [[] for _ in range(n)]
        self.live: Dict[Tuple[TaskId, bool], RunningTask] = {}
        self.finished_tasks: set = set()
        self.spec_launched: set = set()
        self.n_speculative = 0
        self.events: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self.events_processed = 0
        # -- heartbeat liveness (deadlock/churn fix) -------------------------
        self._hb_dead: Set[int] = set()
        self._pending_submits = 0
        # -- incremental speculation state -----------------------------------
        self._spec_q: Dict[str, _SpecQueue] = {}
        self._job_seq: Dict[str, int] = {}
        # (wake_time, job_seq, job_id): job may have an eligible straggler
        # at wake_time; lazy — revalidated on pop
        self._spec_wake: List[Tuple[float, int, str]] = []
        # (job_seq, job_id): jobs whose wake time has passed
        self._spec_ready: List[Tuple[int, str]] = []
        self._spec_ready_set: Set[str] = set()
        self.reconfig: Optional[Reconfigurator] = getattr(
            scheduler, "reconfig", None) if scheduler.uses_reconfig else None
        if self.reconfig is not None:
            self.reconfig.validator = lambda vm: self.free_map(vm) > 0

    # -- capacities ----------------------------------------------------------
    def map_capacity(self, node: int) -> int:
        if self.reconfig is not None:
            return self.reconfig.vcpus[node]
        return self.spec.base_map_slots

    def free_map(self, node: int) -> int:
        return self.map_capacity(node) - len(self.map_running[node])

    def free_reduce(self, node: int) -> int:
        return self.spec.base_reduce_slots - len(self.red_running[node])

    # -- event machinery ----------------------------------------------------
    def _push(self, t: float, kind: str, data=None) -> None:
        self._seq += 1
        heapq.heappush(self.events, (t, self._seq, kind, data))

    # -- duration model -------------------------------------------------------
    def _jitter(self, cv: float) -> float:
        if cv <= 0:
            return 1.0
        sigma = math.sqrt(math.log(1 + cv * cv))
        return self.rng.lognormvariate(-sigma * sigma / 2, sigma)

    def task_duration(self, job: JobRuntime, task: TaskId, local: bool) -> float:
        prof = job.spec.profile
        if task.kind == TaskKind.MAP:
            base = prof.map_time
            if not local:
                # remote_penalty_scale calibrates the fabric (1GbE -> 40GbE);
                # at the default 1.0 the product is bit-identical to the
                # seed's bare `prof.remote_penalty` (x * 1.0 == x in IEEE754)
                base *= 1.0 + prof.remote_penalty * self.spec.remote_penalty_scale
        else:
            # reduce = copy (one stream per mapper) + sort/reduce compute
            base = prof.reduce_time + job.spec.u_m * prof.shuffle_time_per_pair
        d = base * self._jitter(prof.time_cv)
        if self.rng.random() < self.straggler_prob:
            d *= self.straggler_factor
        return d

    # -- main loop --------------------------------------------------------------
    def run(self, jobs: List[JobSpec], until: float = 10_000_000.0) -> SimResult:
        self._pending_submits = len(jobs)
        for job in jobs:
            self._push(job.submit_time, "submit", job)
        for node in range(self.spec.num_nodes):
            self._push(self.spec.heartbeat_interval * (1 + node / self.spec.num_nodes),
                       "heartbeat", node)
        now = 0.0
        while self.events:
            now, _, kind, data = heapq.heappop(self.events)
            if now > until:
                break
            self.events_processed += 1
            if kind == "submit":
                self._pending_submits -= 1
                self._job_seq[data.job_id] = len(self._job_seq)
                self.sched.job_added(data, now)
                if self._hb_dead:
                    # revive heartbeat chains that stopped while the cluster
                    # was idle — without this, a job submitted after an idle
                    # gap would never be scheduled (seed deadlock)
                    for node in sorted(self._hb_dead):
                        self._push(
                            now + self.spec.heartbeat_interval
                            * (1 + node / self.spec.num_nodes),
                            "heartbeat", node)
                    self._hb_dead.clear()
            elif kind == "finish":
                self._on_finish(data, now)
            elif kind == "plug":
                self._on_plug_ready(now)
            elif kind == "heartbeat":
                node = data
                self._heartbeat(node, now)
                if self.sched.has_active_jobs() or (
                        not self.sched.jobs and self._pending_submits > 0):
                    self._push(now + self.spec.heartbeat_interval, "heartbeat",
                               node)
                else:
                    # idle: let this chain die instead of ticking forever;
                    # the next submit revives it
                    self._hb_dead.add(node)
        result = SimResult(
            scheduler=self.sched.name,
            jobs=self.sched.jobs,
            makespan=max((j.finish_time or now) for j in self.sched.jobs.values())
            if self.sched.jobs else 0.0,
            reconfig_stats=dict(self.reconfig.stats) if self.reconfig else {},
            speculative_launches=self.n_speculative,
            events_processed=self.events_processed,
        )
        return result

    # -- handlers -------------------------------------------------------------
    def _launch(self, launch: Launch, now: float, speculative: bool = False) -> None:
        job = self.sched.jobs[launch.task.job_id]
        dur = self.task_duration(job, launch.task, launch.local)
        rt = RunningTask(launch.task, launch.node, now, now + dur,
                         launch.local, speculative)
        if launch.task.kind == TaskKind.MAP:
            self.map_running[launch.node].append(rt)
            if not speculative:
                jid = launch.task.job_id
                q = self._spec_q.get(jid)
                if q is None:
                    q = self._spec_q[jid] = _SpecQueue()
                q.append(launch.task.index, now)
                if job.map_durations:
                    mean = job.map_duration_sum / len(job.map_durations)
                    self._spec_push_wake(
                        jid, now + self.spec_threshold * mean)
        else:
            self.red_running[launch.node].append(rt)
        self.live[(launch.task, speculative)] = rt
        self._push(rt.finish, "finish", rt)

    def _on_finish(self, rt: RunningTask, now: float) -> None:
        if (rt.task, rt.speculative) not in self.live:
            return                      # cancelled duplicate
        del self.live[(rt.task, rt.speculative)]
        lst = (self.map_running if rt.task.kind == TaskKind.MAP
               else self.red_running)[rt.node]
        if rt in lst:
            lst.remove(rt)
        if rt.task in self.finished_tasks:
            return
        self.finished_tasks.add(rt.task)
        # cancel the twin if speculation duplicated this task
        twin_key = (rt.task, not rt.speculative)
        if twin_key in self.live:
            twin = self.live.pop(twin_key)
            tl = (self.map_running if rt.task.kind == TaskKind.MAP
                  else self.red_running)[twin.node]
            if twin in tl:
                tl.remove(twin)
        self.sched.task_finished(rt.task, rt.node, now, now - rt.start)
        if rt.task.kind == TaskKind.MAP:
            # the job's mean map duration changed: its head straggler may
            # now cross the speculation threshold earlier (or at all)
            jid = rt.task.job_id
            job = self.sched.jobs[jid]
            q = self._spec_q.get(jid)
            if q is not None and job.running_map and job.map_durations:
                mean = job.map_duration_sum / len(job.map_durations)
                head = self._spec_head_start(q, job)
                if head is not None:
                    self._spec_push_wake(
                        jid, max(now, head + self.spec_threshold * mean))
        # Paper §4.1: "the target system will soon have a free core, as a
        # task finishes in one of the VMs, and a local task is not found for
        # the VM" — on every map finish, a VM with no local pending work
        # offers its freed core if a neighbour VM has a parked task waiting.
        if self.reconfig is not None and rt.task.kind == TaskKind.MAP:
            vm = rt.node
            if self.reconfig.adaptive.enabled:
                # release-interval hook: every map finish frees a core on vm
                # (whether or not it is offered below) — feed the machine's
                # core-free EWMA so park_decision can price the wait
                self.reconfig.observe_core_free(vm, now)
            if (self.free_map(vm) > 0
                    and (self.reconfig.vcpus[vm] > self.spec.base_map_slots
                         or (isinstance(self.sched, CompletionTimeScheduler)
                             and not self.sched.has_local_pending(vm)))):
                self.reconfig.release_core(vm, now)
            self._match_reconfig(now)

    def _on_plug_ready(self, now: float) -> None:
        if self.reconfig is None:
            return
        for plug in self.reconfig.complete_plugs(now):
            task = plug.task
            job = self.sched.jobs.get(task.job_id)
            if job is None or task.index in job.completed_map:
                continue
            self.sched.parked_task_launched(task, plug.to_vm, now)
            self._launch(Launch(task, plug.to_vm, local=True,
                                via_reconfig=True), now)

    def _match_reconfig(self, now: float) -> None:
        if self.reconfig is None:
            return
        started = self.reconfig.match(now, donor_ok=lambda vm: self.free_map(vm) > 0)
        for plug in started:
            self._push(plug.ready_at, "plug", None)

    def _heartbeat(self, node: int, now: float) -> None:
        # expire stale parked tasks back to the scheduler for remote launch
        if self.reconfig is not None:
            for parked in self.reconfig.expire_stale(now):
                if isinstance(self.sched, CompletionTimeScheduler):
                    self.sched.parked_task_expired(parked.task, now)
            self._match_reconfig(now)
        fm, fr = self.free_map(node), self.free_reduce(node)
        if fm > 0 or fr > 0:
            for launch in self.sched.select(node, fm, fr, now):
                self._launch(launch, now)
            self._match_reconfig(now)   # pair fresh AQ entries immediately
        if self.speculative:
            self._maybe_speculate(node, now)

    # -- incremental speculative execution ------------------------------------
    def _spec_push_wake(self, jid: str, wake: float) -> None:
        # nudge the wake a hair early: `start + θ·mean` can round *above* the
        # exact eligibility boundary `now - start > θ·mean`; waking early is
        # harmless (candidates are revalidated with the exact expression),
        # waking late would miss the seed's pick
        heapq.heappush(self._spec_wake,
                       (wake - 1e-6, self._job_seq.get(jid, 0), jid))

    def _spec_head_start(self, q: _SpecQueue, job: JobRuntime) -> Optional[float]:
        """Drop permanently-dead head entries; return the head's *recorded*
        (append-time) start.  Recorded starts are non-decreasing along the
        queue and never exceed the live start, so a wake computed from the
        head's recorded start lower-bounds every entry's eligibility time —
        even when a re-launch refreshed some entry's live start.  An early
        wake only costs one extra revalidation."""
        entries, running = q.entries, job.running_map
        while q.head < len(entries):
            idx, start = entries[q.head]
            if idx not in running or TaskId(
                    job.spec.job_id, TaskKind.MAP, idx) in self.spec_launched:
                q.present.discard(idx)
                q.head += 1
                continue
            q.compact()
            return start
        q.compact()
        return None

    def _spec_candidate(self, job: JobRuntime, q: _SpecQueue,
                        now: float) -> Optional[TaskId]:
        """First speculation-eligible running map in insertion order.

        Append-time starts are non-decreasing, so once an entry whose live
        start equals its recorded start is ineligible, every later entry is
        too, and the walk stops.  An entry whose start was *refreshed* by a
        re-launch (live start > recorded) does not bound its successors, so
        the walk continues past it — matching the seed's full dict scan.
        """
        if not job.map_durations:
            return None
        threshold = (self.spec_threshold
                     * (job.map_duration_sum / len(job.map_durations)))
        entries, running = q.entries, job.running_map
        jid = job.spec.job_id
        i = q.head
        while i < len(entries):
            idx, rec_start = entries[i]
            task = TaskId(jid, TaskKind.MAP, idx)
            if idx not in running or task in self.spec_launched:
                if i == q.head:           # permanently dead: drop from head
                    q.present.discard(idx)
                    q.head += 1
                i += 1
                continue
            rt = self.live.get((task, False))
            if rt is None:
                i += 1                    # running but not live: seed skips it
                continue
            if now - rt.start > threshold:
                return task
            if rt.start <= rec_start:
                return None               # unrefreshed + ineligible: walk ends
            i += 1                        # refreshed entry: keep scanning
        return None

    def _maybe_speculate(self, node: int, now: float) -> None:
        """Hadoop-style speculative re-execution of straggling maps.

        Identical decisions to the seed's per-heartbeat full rescan, found
        via the lazy wake heap: first submitted job with an eligible
        straggler, earliest-launched eligible map of that job."""
        if self.free_map(node) <= 0:
            return
        wake, ready, ready_set = (self._spec_wake, self._spec_ready,
                                  self._spec_ready_set)
        while wake and wake[0][0] <= now:
            _, seq, jid = heapq.heappop(wake)
            if jid not in ready_set:
                ready_set.add(jid)
                heapq.heappush(ready, (seq, jid))
        while ready:
            seq, jid = ready[0]
            job = self.sched.jobs[jid]
            q = self._spec_q.get(jid)
            task = (None if (job.finished or q is None)
                    else self._spec_candidate(job, q, now))
            if task is not None:
                self.spec_launched.add(task)
                self.n_speculative += 1
                idx = task.index
                local = node in job.spec.block_placement[idx]
                self._launch(Launch(task, node, local=local), now,
                             speculative=True)
                return
            # not eligible now: drop from the ready set and, if the job still
            # has a live head, schedule its next possible eligibility time
            heapq.heappop(ready)
            ready_set.discard(jid)
            if q is not None and not job.finished and job.map_durations:
                head = self._spec_head_start(q, job)
                if head is not None:
                    mean = job.map_duration_sum / len(job.map_durations)
                    self._spec_push_wake(
                        jid, max(now, head + self.spec_threshold * mean))
