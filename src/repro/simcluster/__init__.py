from repro.simcluster.sim import ClusterSim, SimResult
from repro.simcluster.largescale import SCENARIOS, Scenario, run_scenario
from repro.simcluster.workloads import (WORKLOADS, make_job, paper_cluster,
                                        paper_job_mix, paper_table2_jobs)
