from repro.simcluster.sim import ClusterSim, SimResult
from repro.simcluster.largescale import SCENARIOS, Scenario, run_scenario
from repro.simcluster.traces import (PRESETS, ArrivalConfig, SizeConfig,
                                     Trace, TraceConfig, TraceJob,
                                     generate_trace, paper_trace,
                                     trace_from_rows)
from repro.simcluster.workloads import (PAPER_TABLE2_ROWS, WORKLOADS, make_job,
                                        paper_cluster, paper_job_mix,
                                        paper_table2_jobs)
