"""SWIM-style synthetic workload traces + a versioned JSONL trace format.

A *trace* is a cluster-shape-independent list of job arrivals: for each job
its workload, input size, submit time, deadline and a ``placement_seed``.
Replaying a trace against a concrete ``ClusterSpec`` regenerates the HDFS
block placement deterministically from the stored seed, so the same trace
file drives any cluster shape while two replays against the same shape are
identical.

The generator follows the facebook/SWIM recipe adapted to the paper's five
workloads (arXiv:1808.08040 and the survey arXiv:1704.02632 both evaluate
virtual-cluster schedulers on exactly this kind of synthetic trace):

* **job sizes** are heavy-tailed — lognormal (median/sigma) or Pareto
  (alpha over a minimum size), clamped to a [min, max] GB window;
* **arrivals** are a non-homogeneous Poisson process: a base rate with an
  optional diurnal sinusoid, sampled by thinning, plus Poisson-seeded
  *bursts* (a geometric number of extra jobs at a short stagger) for the
  flash-crowd patterns the ROADMAP scenarios model;
* **workload mix** is a weighted draw over the five paper workloads.

File format (``repro-trace/v1``): line 1 is a JSON header
``{"format": "repro-trace/v1", "name": ..., "seed": ..., "num_jobs": ...,
"config": {...}|null}``; each subsequent line is one job object.  All JSON
is dumped with sorted keys and no whitespace, so generation is byte-stable
per seed and ``save -> load -> save`` round-trips bit-exactly (floats
survive JSON via ``repr`` round-tripping).
"""
from __future__ import annotations

import hashlib
import json
import math
import random
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.types import ClusterSpec, JobSpec
from repro.simcluster.workloads import (PAPER_SKEW, PAPER_TABLE2_ROWS,
                                        WORKLOADS, default_deadline,
                                        n_map_tasks, n_reduce_tasks,
                                        place_blocks)

TRACE_FORMAT = "repro-trace/v1"


def _dumps(obj) -> str:
    """Canonical JSON: sorted keys, no whitespace — byte-stable output."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def _stable_seed(*parts) -> int:
    """Process-stable integer seed from arbitrary JSON-able parts."""
    digest = hashlib.sha256(_dumps(list(parts)).encode()).digest()
    return int.from_bytes(digest[:8], "big")


# ---------------------------------------------------------------------------
# generator configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArrivalConfig:
    """Non-homogeneous Poisson arrivals with diurnal modulation + bursts.

    Instantaneous rate: ``rate_per_hour * (1 + diurnal_amplitude *
    sin(2*pi*(t + diurnal_phase_s)/diurnal_period_s))``, sampled by
    thinning.  Each accepted arrival seeds, with probability ``burst_prob``,
    a geometric number of follow-on jobs (mean ``burst_size_mean``) spaced
    ``burst_stagger_s`` apart — a flash crowd."""

    rate_per_hour: float = 240.0
    diurnal_amplitude: float = 0.0      # 0..1
    diurnal_period_s: float = 3600.0
    diurnal_phase_s: float = 0.0
    burst_prob: float = 0.0
    burst_size_mean: float = 4.0
    burst_stagger_s: float = 2.0

    def __post_init__(self) -> None:
        if self.rate_per_hour <= 0:
            raise ValueError("rate_per_hour must be positive")
        if not 0.0 <= self.diurnal_amplitude <= 1.0:
            # the thinning envelope assumes the sinusoid only adds to the
            # base rate; out-of-range amplitudes would silently clip peaks
            raise ValueError("diurnal_amplitude must be in [0, 1]")
        if self.diurnal_period_s <= 0:
            raise ValueError("diurnal_period_s must be positive")
        if not 0.0 <= self.burst_prob <= 1.0:
            raise ValueError("burst_prob must be in [0, 1]")
        if self.burst_stagger_s <= 0:
            raise ValueError("burst_stagger_s must be positive")

    def to_dict(self) -> Dict[str, float]:
        return {
            "rate_per_hour": self.rate_per_hour,
            "diurnal_amplitude": self.diurnal_amplitude,
            "diurnal_period_s": self.diurnal_period_s,
            "diurnal_phase_s": self.diurnal_phase_s,
            "burst_prob": self.burst_prob,
            "burst_size_mean": self.burst_size_mean,
            "burst_stagger_s": self.burst_stagger_s,
        }

    @classmethod
    def from_dict(cls, d) -> "ArrivalConfig":
        return cls(**d)

    def rate_at(self, t: float) -> float:
        base = self.rate_per_hour / 3600.0
        if self.diurnal_amplitude <= 0:
            return base
        return base * (1.0 + self.diurnal_amplitude * math.sin(
            2.0 * math.pi * (t + self.diurnal_phase_s) / self.diurnal_period_s))


@dataclass(frozen=True)
class SizeConfig:
    """Heavy-tailed input-size distribution (GB)."""

    distribution: str = "lognormal"     # "lognormal" | "pareto"
    median_gb: float = 2.0              # lognormal location (exp(mu))
    sigma: float = 1.0                  # lognormal shape
    alpha: float = 1.6                  # pareto tail index
    min_gb: float = 0.25
    max_gb: float = 32.0

    def __post_init__(self) -> None:
        if self.distribution not in ("lognormal", "pareto"):
            raise ValueError(f"unknown size distribution {self.distribution!r}")

    def to_dict(self) -> Dict[str, object]:
        return {
            "distribution": self.distribution,
            "median_gb": self.median_gb,
            "sigma": self.sigma,
            "alpha": self.alpha,
            "min_gb": self.min_gb,
            "max_gb": self.max_gb,
        }

    @classmethod
    def from_dict(cls, d) -> "SizeConfig":
        return cls(**d)

    def draw(self, rng: random.Random) -> float:
        if self.distribution == "lognormal":
            gb = rng.lognormvariate(math.log(self.median_gb), self.sigma)
        else:
            gb = self.min_gb * rng.paretovariate(self.alpha)
        return round(min(self.max_gb, max(self.min_gb, gb)), 3)


@dataclass(frozen=True)
class TraceConfig:
    """Declarative recipe for one synthetic trace."""

    name: str = "mix"
    num_jobs: int = 50
    mix: Tuple[Tuple[str, float], ...] = tuple((w, 1.0) for w in WORKLOADS)
    arrival: ArrivalConfig = ArrivalConfig()
    sizes: SizeConfig = SizeConfig()
    deadline_slack: float = 2.2
    skew: float = PAPER_SKEW

    def __post_init__(self) -> None:
        if self.num_jobs <= 0:
            raise ValueError("num_jobs must be positive")
        for w, weight in self.mix:
            if w not in WORKLOADS:
                raise ValueError(f"unknown workload {w!r} in mix")
            if weight < 0:
                raise ValueError("mix weights must be non-negative")

    def to_dict(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "num_jobs": self.num_jobs,
            "mix": [[w, weight] for w, weight in self.mix],
            "arrival": self.arrival.to_dict(),
            "sizes": self.sizes.to_dict(),
            "deadline_slack": self.deadline_slack,
            "skew": self.skew,
        }

    @classmethod
    def from_dict(cls, d) -> "TraceConfig":
        d = dict(d)
        d["mix"] = tuple((w, float(weight)) for w, weight in d["mix"])
        d["arrival"] = ArrivalConfig.from_dict(d["arrival"])
        d["sizes"] = SizeConfig.from_dict(d["sizes"])
        return cls(**d)


# ---------------------------------------------------------------------------
# the trace itself
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TraceJob:
    """One arrival.  ``placement_seed`` makes block placement reproducible
    at replay time against any cluster shape."""

    job_id: str
    workload: str
    input_gb: float
    submit_time: float
    deadline: float
    placement_seed: int
    skew: float = PAPER_SKEW

    def to_dict(self) -> Dict[str, object]:
        return {
            "job_id": self.job_id,
            "workload": self.workload,
            "input_gb": self.input_gb,
            "submit_time": self.submit_time,
            "deadline": self.deadline,
            "placement_seed": self.placement_seed,
            "skew": self.skew,
        }

    @classmethod
    def from_dict(cls, d) -> "TraceJob":
        return cls(**d)

    def to_job_spec(self, spec: ClusterSpec) -> JobSpec:
        rng = random.Random(self.placement_seed)
        u_m = n_map_tasks(self.input_gb)
        return JobSpec(
            job_id=self.job_id,
            profile=WORKLOADS[self.workload],
            u_m=u_m,
            v_r=n_reduce_tasks(self.workload, self.input_gb),
            deadline=self.deadline,
            submit_time=self.submit_time,
            input_size_gb=self.input_gb,
            block_placement=place_blocks(u_m, spec, rng, skew=self.skew),
        )


@dataclass
class Trace:
    name: str
    seed: int
    jobs: List[TraceJob]
    config: Optional[Dict[str, object]] = None   # generator config, if any

    # -- serialization ------------------------------------------------------
    def header(self) -> Dict[str, object]:
        return {
            "format": TRACE_FORMAT,
            "name": self.name,
            "seed": self.seed,
            "num_jobs": len(self.jobs),
            "config": self.config,
        }

    def to_jsonl(self) -> str:
        lines = [_dumps(self.header())]
        lines.extend(_dumps(j.to_dict()) for j in self.jobs)
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "Trace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace")
        header = json.loads(lines[0])
        fmt = header.get("format")
        if fmt != TRACE_FORMAT:
            raise ValueError(
                f"unsupported trace format {fmt!r} (expected {TRACE_FORMAT})")
        jobs = [TraceJob.from_dict(json.loads(ln)) for ln in lines[1:]]
        if header.get("num_jobs") != len(jobs):
            raise ValueError(
                f"trace truncated: header says {header.get('num_jobs')} jobs, "
                f"found {len(jobs)}")
        return cls(name=header["name"], seed=header["seed"], jobs=jobs,
                   config=header.get("config"))

    def save(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_jsonl())
        return path

    @classmethod
    def load(cls, path: Union[str, Path]) -> "Trace":
        return cls.from_jsonl(Path(path).read_text())

    # -- replay / inspection ------------------------------------------------
    def job_specs(self, spec: ClusterSpec) -> List[JobSpec]:
        return [j.to_job_spec(spec) for j in self.jobs]

    def duration(self) -> float:
        # max, not jobs[-1]: hand-built traces need not be time-sorted
        return max(j.submit_time for j in self.jobs) if self.jobs else 0.0

    def workload_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for j in self.jobs:
            out[j.workload] = out.get(j.workload, 0) + 1
        return out

    def total_input_gb(self) -> float:
        return sum(j.input_gb for j in self.jobs)


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

def _arrival_times(cfg: ArrivalConfig, rng: random.Random, n: int) -> List[float]:
    """First ``n`` arrivals of the thinned non-homogeneous Poisson process,
    with geometric bursts riding on accepted arrivals."""
    lam_max = (cfg.rate_per_hour / 3600.0) * (1.0 + cfg.diurnal_amplitude)
    times: List[float] = []
    t = 0.0
    while len(times) < n:
        t += rng.expovariate(lam_max)
        if rng.random() * lam_max > cfg.rate_at(t):
            continue                      # thinned out
        times.append(t)
        if cfg.burst_prob > 0 and rng.random() < cfg.burst_prob:
            p = 1.0 / max(1.0, cfg.burst_size_mean)
            extra = 0
            while rng.random() > p:       # geometric, mean ~ burst_size_mean-1
                extra += 1
            for k in range(extra):
                if len(times) >= n:
                    break
                times.append(t + (k + 1) * cfg.burst_stagger_s)
    times.sort()                          # bursts can leapfrog base arrivals
    return times[:n]


def generate_trace(config: TraceConfig, seed: int = 0) -> Trace:
    """Deterministic per (config, seed): same inputs => byte-identical trace."""
    rng = random.Random(_stable_seed("repro-trace", config.to_dict(), seed))
    names = [w for w, _ in config.mix]
    weights = [weight for _, weight in config.mix]
    arrivals = _arrival_times(config.arrival, rng, config.num_jobs)
    jobs = []
    for i, t in enumerate(arrivals):
        w = rng.choices(names, weights=weights)[0]
        gb = config.sizes.draw(rng)
        jobs.append(TraceJob(
            job_id=f"{config.name}-{i:04d}-{w}",
            workload=w,
            input_gb=gb,
            submit_time=round(t, 3),
            deadline=round(default_deadline(w, gb, slack=config.deadline_slack), 3),
            placement_seed=rng.randrange(1 << 31),
            skew=config.skew,
        ))
    return Trace(name=config.name, seed=seed, jobs=jobs,
                 config=config.to_dict())


def trace_from_rows(name: str,
                    rows: Sequence[Tuple[str, float, float, float]],
                    seed: int = 0, skew: float = PAPER_SKEW) -> Trace:
    """Hand-built trace from explicit (workload, input_gb, deadline,
    submit_time) rows — for fixed experiment mixes like the paper's Table 2."""
    rng = random.Random(_stable_seed("repro-trace-rows", name, seed))
    jobs = [TraceJob(
        job_id=f"{name}-{i:04d}-{w}",
        workload=w,
        input_gb=float(gb),
        submit_time=float(t),
        deadline=float(dl),
        placement_seed=rng.randrange(1 << 31),
        skew=skew,
    ) for i, (w, gb, dl, t) in enumerate(rows)]
    return Trace(name=name, seed=seed, jobs=jobs, config=None)


def paper_trace(seed: int = 0) -> Trace:
    """The paper's §5 evaluation mix (Table-2 rows, all submitted at t=0)
    as a trace; each seed re-rolls the skewed VM-level block placement."""
    rows = [(w, float(gb), dl, 0.0) for (w, gb, dl) in PAPER_TABLE2_ROWS]
    return trace_from_rows("paper-table2", rows, seed=seed, skew=PAPER_SKEW)


# ---------------------------------------------------------------------------
# real-trace import: SWIM / Facebook-format cluster logs
# ---------------------------------------------------------------------------

SWIM_FORMAT = "swim/v1"

# Per-workload (shuffle/input, output/input) byte-ratio signatures, from the
# profile calibration above: grep emits almost nothing, wordcount compresses
# moderately, sort is identity map/reduce, permutation blows intermediate
# data up ~4x, inverted_index is moderate-heavy.  An imported job is tagged
# with the nearest signature in log-ratio space — the same features SWIM
# itself uses to cluster jobs (k-means over per-job byte counts).
SWIM_SIGNATURES: Dict[str, Tuple[float, float]] = {
    "grep": (0.05, 0.01),
    "wordcount": (0.8, 0.2),
    "sort": (1.0, 1.0),
    "permutation": (4.0, 1.5),
    "inverted_index": (1.2, 0.4),
}

# Ratios are clamped here before the log so zero-byte columns (common in real
# logs: map-only jobs, empty outputs) classify as the smallest signature
# instead of crashing.
_RATIO_FLOOR = 1e-4


class TraceImportError(ValueError):
    """A cluster log could not be parsed into a trace."""


def classify_swim_workload(input_bytes: float, shuffle_bytes: float,
                           output_bytes: float) -> str:
    """Nearest paper workload for one logged job, by squared distance over
    (log shuffle/input, log output/input).  Deterministic: ties break on the
    sorted workload name, and the inputs are already normalized floats."""
    inp = max(float(input_bytes), 1.0)
    s_ratio = max(float(shuffle_bytes) / inp, _RATIO_FLOOR)
    o_ratio = max(float(output_bytes) / inp, _RATIO_FLOOR)
    ls, lo = math.log10(s_ratio), math.log10(o_ratio)
    best, best_d = None, math.inf
    for w in sorted(SWIM_SIGNATURES):
        sig_s, sig_o = SWIM_SIGNATURES[w]
        d = (ls - math.log10(sig_s)) ** 2 + (lo - math.log10(sig_o)) ** 2
        if d < best_d:
            best, best_d = w, d
    return best


def _parse_swim_line(line_no: int, line: str) -> Tuple[str, float, float, float, float]:
    """One SWIM row: job_id, submit_time_s, inter_arrival_gap_s,
    map_input_bytes, shuffle_bytes, reduce_output_bytes (whitespace- or
    tab-separated; the gap column is redundant and ignored)."""
    cols = line.split()
    if len(cols) != 6:
        raise TraceImportError(
            f"line {line_no}: expected 6 whitespace-separated columns "
            f"(job_id, submit_time, gap, input_bytes, shuffle_bytes, "
            f"output_bytes), got {len(cols)}: {line[:80]!r}")
    job_id = cols[0]
    try:
        submit = float(cols[1])
        inp, shuf, out = (float(cols[3]), float(cols[4]), float(cols[5]))
    except ValueError as e:
        raise TraceImportError(f"line {line_no}: non-numeric field: {e}") from None
    if submit < 0:
        raise TraceImportError(f"line {line_no}: negative submit time {submit}")
    if min(inp, shuf, out) < 0:
        raise TraceImportError(f"line {line_no}: negative byte count")
    return job_id, submit, inp, shuf, out


def import_swim(text: str, *, name: str = "swim",
                deadline_slack: float = 2.2, skew: float = PAPER_SKEW,
                min_input_gb: float = 0.125, max_input_gb: float = 64.0,
                max_jobs: Optional[int] = None) -> Trace:
    """Convert a SWIM/Facebook-format cluster log into a ``repro-trace/v1``
    trace.

    Normalization is byte-stable: arrivals are shifted so the first job
    submits at t=0 and rounded to milliseconds, input sizes are converted to
    GB, clamped to [min_input_gb, max_input_gb] and rounded to 3 decimals,
    deadlines come from the calibrated ``default_deadline`` of the
    classified workload, and every ``placement_seed`` is a stable hash of
    (name, row index, normalized fields) — importing the same log twice
    yields byte-identical JSONL.
    """
    rows = []
    for line_no, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("{"):
            raise TraceImportError(
                f"line {line_no}: looks like JSON, not a SWIM log — if this "
                f"is already a {TRACE_FORMAT} trace, load it with "
                "Trace.load() instead of importing")
        rows.append(_parse_swim_line(line_no, line))
        if max_jobs is not None and len(rows) >= max_jobs:
            break
    if not rows:
        raise TraceImportError("empty trace: no job rows found")
    t0 = min(r[1] for r in rows)
    rows.sort(key=lambda r: (r[1], r[0]))   # stable: arrival, then source id
    jobs: List[TraceJob] = []
    for i, (src_id, submit, inp, shuf, out) in enumerate(rows):
        w = classify_swim_workload(inp, shuf, out)
        gb = round(min(max_input_gb, max(min_input_gb, inp / 1e9)), 3)
        t = round(submit - t0, 3)
        jobs.append(TraceJob(
            job_id=f"{name}-{i:04d}-{w}",
            workload=w,
            input_gb=gb,
            submit_time=t,
            deadline=round(default_deadline(w, gb, slack=deadline_slack), 3),
            placement_seed=_stable_seed("swim-import", name, i, src_id, t, gb, w)
            % (1 << 31),
            skew=skew,
        ))
    config = {
        "importer": SWIM_FORMAT,
        "deadline_slack": deadline_slack,
        "skew": skew,
        "min_input_gb": min_input_gb,
        "max_input_gb": max_input_gb,
        "jobs_in": len(rows),
    }
    return Trace(name=name, seed=0, jobs=jobs, config=config)


def import_swim_file(path: Union[str, Path], **kwargs) -> Trace:
    """``import_swim`` over a log file; the default trace name is the stem."""
    path = Path(path)
    kwargs.setdefault("name", path.stem)
    try:
        text = path.read_text()
    except OSError as e:
        raise TraceImportError(f"cannot read {path}: {e}") from None
    return import_swim(text, **kwargs)


# ---------------------------------------------------------------------------
# named presets (CLI: `python -m repro.experiments generate --preset ...`)
# ---------------------------------------------------------------------------

PRESETS: Dict[str, TraceConfig] = {
    "mix_small": TraceConfig(
        name="mix_small", num_jobs=12,
        arrival=ArrivalConfig(rate_per_hour=360.0),
        sizes=SizeConfig(median_gb=1.0, sigma=0.6, max_gb=4.0)),
    "mix": TraceConfig(
        name="mix", num_jobs=60,
        arrival=ArrivalConfig(rate_per_hour=240.0),
        sizes=SizeConfig(median_gb=2.0, sigma=0.9, max_gb=16.0)),
    "heavy_tail": TraceConfig(
        name="heavy_tail", num_jobs=80,
        arrival=ArrivalConfig(rate_per_hour=300.0),
        sizes=SizeConfig(distribution="pareto", alpha=1.3, min_gb=0.5,
                         max_gb=48.0)),
    "diurnal": TraceConfig(
        name="diurnal", num_jobs=100,
        arrival=ArrivalConfig(rate_per_hour=180.0, diurnal_amplitude=0.9,
                              diurnal_period_s=7200.0),
        sizes=SizeConfig(median_gb=1.5, sigma=0.8, max_gb=12.0)),
    "bursty": TraceConfig(
        name="bursty", num_jobs=90,
        arrival=ArrivalConfig(rate_per_hour=90.0, burst_prob=0.35,
                              burst_size_mean=6.0, burst_stagger_s=2.0),
        sizes=SizeConfig(median_gb=1.5, sigma=0.7, max_gb=8.0)),
    "shuffle_heavy": TraceConfig(
        name="shuffle_heavy", num_jobs=40,
        mix=(("sort", 2.0), ("permutation", 2.0), ("wordcount", 1.0),
             ("inverted_index", 1.0), ("grep", 0.5)),
        arrival=ArrivalConfig(rate_per_hour=200.0),
        sizes=SizeConfig(median_gb=2.0, sigma=0.8, max_gb=10.0)),
    # the closed-mix bridge to the paper's §5 setting: every job submitted
    # within the first fraction of a second (arrival gaps ~5 ms), so the
    # cluster is saturated end-to-end and makespan is policy-dominated —
    # the regime where the paper measures its headline throughput gain
    "saturated": TraceConfig(
        name="saturated", num_jobs=40,
        arrival=ArrivalConfig(rate_per_hour=720_000.0),
        sizes=SizeConfig(median_gb=3.0, sigma=0.6, min_gb=1.0, max_gb=12.0)),
}
